"""Token sampling: greedy / temperature / top-p (nucleus).

Two entry points share the same math:

* ``sample(logits, cfg, key)`` — the host-driven batch sampler (static
  scheduler, synchronous reference path). One key per call.
* ``sample_step(logits, cfg, keys)`` — the on-device per-slot sampler fused
  into the jitted decode step (``models.model.serve_step_sampled``). ``keys``
  carries ONE PRNG key per batch slot, so a request's sample stream depends
  only on its own key stream — not on which slot it landed in, which
  requests it was co-scheduled with, or how many steps the engine dispatches
  per host sync. The greedy path is a plain argmax, bit-identical to the
  host-side sampler.

Per-request key streams: ``request_key(seed, uid)`` seeds the stream and
token ``i`` of the request is sampled with ``fold_in(request_key, i)``
(``step_keys`` vectorizes the fold over slots). Slot turnover re-seeds the
slot's lane from the incoming request's uid, so streams are stable across
scheduling decisions (tests/test_async_decode.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0     # 0 => greedy
    top_p: float = 1.0


def _filter_logits(logits, cfg: SamplerConfig):
    """Temperature + nucleus filtering shared by both samplers."""
    logits = logits / cfg.temperature
    if cfg.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < cfg.top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits >= cutoff, logits, -jnp.inf)
    return logits


def sample(logits, cfg: SamplerConfig, key):
    """logits (B, V) -> tokens (B,) int32. One key for the whole batch."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, _filter_logits(logits, cfg), axis=-1).astype(jnp.int32)


def sample_step(logits, cfg: SamplerConfig, keys):
    """Per-slot sampling: logits (B, V), keys (B,) PRNG keys -> (B,) int32.

    Safe to call inside jit (the fused decode step) or outside (the
    synchronous reference path) — identical results either way."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = _filter_logits(logits, cfg)
    return jax.vmap(
        lambda k, lg: jax.random.categorical(k, lg))(keys, logits
                                                     ).astype(jnp.int32)


# ---------------------------------------------------------------------------
# per-request key streams
# ---------------------------------------------------------------------------
def request_key(seed: int, uid: int):
    """The PRNG key seeding request ``uid``'s sample stream for one run."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), uid)


def step_keys(slot_keys, counts):
    """Per-slot step keys: fold each slot's request key by its per-request
    token index. slot_keys (B, 2) uint32, counts (B,) int32 -> (B, 2)."""
    return jax.vmap(jax.random.fold_in)(slot_keys, counts)
