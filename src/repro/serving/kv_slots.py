"""Paged KV slot pool: maps logical requests onto physical batch slots.

The FreeKV decode state is one pytree with a fixed batch dimension (the slot
count) — ``core/paging.py`` page tables, window rings, selection buffers and
per-row lengths. A jitted ``serve_step`` over that state never recompiles as
requests come and go; admission and completion are per-slot functional
updates:

  * ``insert(src_state, slot)`` splices a freshly prefilled B=1 state into a
    physical slot (prelude layers batch on axis 0, period-stacked pattern
    layers on axis 1, ``pos`` on axis 0 — see ``paging.slot_write_leaf``).
  * ``free(slot)`` returns the slot and marks it dirty; the reset to the
    empty template is LAZY (``flush_resets``, called by the scheduler right
    before a decode step) so a slot refilled at the same step boundary — the
    common case — pays one splice, not two. Slots that stay idle are reset
    once so their ring/page writes stay bounded until the next refill.

The slot index is a traced scalar, so one compiled insert serves every slot.
"""
from __future__ import annotations

from typing import List, Optional, Set

import jax
import jax.numpy as jnp

from repro.core import paging
from repro.models.model import init_decode_state


def _splice(dst, src, slot):
    out = dict(dst)
    out["prelude"] = tuple(
        jax.tree.map(lambda a, b: paging.slot_write_leaf(a, b, slot, axis=0),
                     d, s)
        for d, s in zip(dst["prelude"], src["prelude"]))
    out["pattern"] = tuple(
        jax.tree.map(lambda a, b: paging.slot_write_leaf(a, b, slot, axis=1),
                     d, s)
        for d, s in zip(dst["pattern"], src["pattern"]))
    out["pos"] = paging.slot_write_leaf(dst["pos"], src["pos"], slot, axis=0)
    # any extra top-level lane (e.g. the spec-decode draft_tab) batches on
    # axis 0, like pos
    for key in dst:
        if key not in ("prelude", "pattern", "pos"):
            out[key] = paging.slot_write_leaf(dst[key], src[key], slot, axis=0)
    return out


def _extract(state, slot):
    out = {
        "prelude": tuple(
            jax.tree.map(lambda a: paging.slot_read_leaf(a, slot, axis=0), d)
            for d in state["prelude"]),
        "pattern": tuple(
            jax.tree.map(lambda a: paging.slot_read_leaf(a, slot, axis=1), d)
            for d in state["pattern"]),
        "pos": paging.slot_read_leaf(state["pos"], slot, axis=0),
    }
    for key in state:
        if key not in ("prelude", "pattern", "pos"):
            out[key] = paging.slot_read_leaf(state[key], slot, axis=0)
    return out


class SlotPool:
    """Fixed-capacity pool of physical batch slots over one decode state.

    With a ``mesh`` (tensor-parallel serving), every state leaf is stored
    under the sharding ``sharding/rules.decode_state_shardings`` assigns —
    KV-head dim over 'model' for pools/summaries/rings/selection buffers —
    so the shard_map'ped decode step consumes its inputs without any
    resharding, and per-slot splices stay slot-local per shard. The host
    pool leaves (+ quant scales) additionally move to host memory when
    ``fkv.offload == 'host'`` (``core/offload.place_decode_state``)."""

    def __init__(self, cfg, fkv, num_slots: int, max_len: int,
                 state_dtype=jnp.float32, mesh=None):
        self.cfg, self.fkv = cfg, fkv
        self.num_slots = num_slots
        self.max_len = max_len
        self.state_dtype = state_dtype
        self.mesh = mesh

        def _mk_init(batch):
            fn = lambda: init_decode_state(cfg, fkv, batch, max_len,  # noqa: E731
                                           state_dtype)
            if mesh is None:
                return jax.jit(fn)
            from repro.sharding.rules import decode_state_shardings
            shardings = decode_state_shardings(cfg, mesh, jax.eval_shape(fn))
            return jax.jit(fn, out_shardings=shardings)

        self._init_full = _mk_init(num_slots)
        self._template = self._place(_mk_init(1)())
        # the destination state is DONATED: a splice updates the pool in
        # place instead of copying every leaf (the pool dominates the state
        # footprint). The B=1 source (arg 1) is NOT donated — the reset
        # template is spliced in repeatedly. Callers reassign ``self.state``
        # immediately, so the consumed buffers are never read again.
        self._splice = jax.jit(_splice, donate_argnums=(0,))
        self._extract = jax.jit(_extract)
        self.state = self._place(self._init_full())
        self._free: List[int] = list(range(num_slots - 1, -1, -1))
        self._dirty: Set[int] = set()
        self.owner: List[Optional[int]] = [None] * num_slots
        self.allocs = 0

    def _place(self, state):
        """Move pool leaves (+ quant scales) to host memory under
        ``fkv.offload == 'host'`` — sharding-preserving under a mesh (each
        shard's KV-head-group slice is host-resident on its own device).
        No-op otherwise."""
        from repro.core.offload import place_decode_state
        return place_decode_state(state, self.fkv, mesh=self.mesh,
                                  cfg=self.cfg)

    # -- bookkeeping ---------------------------------------------------
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def active_slots(self) -> List[int]:
        return [s for s, o in enumerate(self.owner) if o is not None]

    def alloc(self, owner_uid: int) -> int:
        slot = self._free.pop()
        assert self.owner[slot] is None, \
            f"slot {slot} already owned by request {self.owner[slot]}"
        self._dirty.discard(slot)       # insert() will overwrite every leaf
        self.owner[slot] = owner_uid
        self.allocs += 1
        return slot

    def free(self, slot: int):
        assert self.owner[slot] is not None, f"slot {slot} already free"
        self.owner[slot] = None
        self._free.append(slot)
        self._dirty.add(slot)

    def flush_resets(self):
        """Reset slots freed since the last flush that were not refilled —
        call before stepping so idle slots carry the empty template."""
        for slot in sorted(self._dirty):
            self.state = self._splice(self.state, self._template,
                                      jnp.int32(slot))
        self._dirty.clear()

    def pool_bytes(self) -> int:
        """Physical host-tier bytes (packed pool payload + quant scales)
        across every slot and layer — what the host actually holds."""
        from repro.core.offload import pool_bytes
        return pool_bytes(self.state)

    def pool_bytes_detail(self) -> dict:
        """Payload/scales/physical/dense breakdown of the pool footprint;
        ``ratio`` is the effective host-capacity multiplier the quantized
        tier buys (1.0 when kv_quant='none')."""
        from repro.quant import pool_bytes_detail
        return pool_bytes_detail(
            self.state, self.cfg.d_head,
            dense_itemsize=jnp.dtype(self.state_dtype).itemsize)

    # -- state surgery -------------------------------------------------
    def insert(self, src_state, slot: int):
        """Splice a B=1 prefilled decode state into physical slot ``slot``."""
        self.state = self._splice(self.state, src_state, jnp.int32(slot))

    def extract(self, slot: int):
        """Read one slot back out as a B=1 state (testing / migration)."""
        return self._extract(self.state, jnp.int32(slot))

    # -- preemption swap (scheduler priority preemption) -----------------
    def swap_out(self, slot: int):
        """Pull slot ``slot``'s entire decode state to host numpy and return
        it (the caller frees the slot separately).

        The swap unit is the slot's full B=1 pytree — paged pool (at its
        PACKED width under the quantized host tier: the int8/int4 payload and
        fp32 scales move as stored, never dequantized), page summaries, sink
        + window rings, selection buffers ``sel_k/sel_v/sel_idx``, ``qprev``,
        lengths and ``pos`` — so ``swap_in`` restores a bit-identical slot:
        mid-decode generation resumes exactly where it left off, including
        the staged speculative recall buffer the overlap pipeline carries
        across steps."""
        from repro.core.offload import swap_state_to_host
        return swap_state_to_host(self._extract(self.state, jnp.int32(slot)))

    def swap_in(self, host_state, slot: int):
        """Splice a ``swap_out`` host state back into physical slot ``slot``
        (allocated by the caller). Leaves upload at their stored dtypes —
        the packed pool representation round-trips exactly — and reuse the
        same compiled splice as ``insert`` (shapes match the template)."""
        self.state = self._splice(self.state,
                                  jax.tree.map(jnp.asarray, host_state),
                                  jnp.int32(slot))

    def reset_all(self):
        self.state = self._place(self._init_full())
        self._free = list(range(self.num_slots - 1, -1, -1))
        self._dirty = set()
        self.owner = [None] * self.num_slots
