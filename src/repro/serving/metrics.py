"""Serving telemetry: per-request lifecycle timings + engine-level counters.

All timestamps are ``time.perf_counter()`` values relative to the scheduler
run's start; derived quantities (queue wait, TTFT, inter-token latency) are
exposed as properties so callers never recompute them inconsistently.

``EngineMetrics.summary()`` is the single dict consumed by
``benchmarks/serving_throughput.py`` and the serving launcher.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class RequestMetrics:
    uid: int
    prompt_tokens: int = 0            # raw prompt length
    padded_prompt_tokens: int = 0     # after bucket padding
    prefix_hit_tokens: int = 0        # prompt tokens served from the prefix cache
    max_new_tokens: int = 0
    enqueue_t: float = 0.0
    prefill_start_t: Optional[float] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    finish_step: Optional[int] = None  # engine step index at completion
    new_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.prefill_start_t is None:
            return None
        return self.prefill_start_t - self.enqueue_t

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.enqueue_t

    @property
    def itl_s(self) -> Optional[float]:
        """Mean inter-token latency after the first token."""
        if self.finish_t is None or self.first_token_t is None \
                or self.new_tokens < 2:
            return None
        return (self.finish_t - self.first_token_t) / (self.new_tokens - 1)


def _mean(xs: List[float]) -> float:
    return sum(xs) / len(xs) if xs else 0.0


@dataclass
class EngineMetrics:
    """Engine-level aggregation across one scheduler run."""
    num_slots: int = 0
    requests: List[RequestMetrics] = field(default_factory=list)
    steps: int = 0
    active_slot_steps: int = 0        # sum over steps of active slots
    wall_s: float = 0.0
    # retrieval traffic (counts of (kv-head, page) blocks; see core/retrieval
    # and core/recall_pipeline): sync = blocking/exposed on the decode
    # critical path, async = staged/hidden behind compute, reused = served
    # from the resident double buffer (no transfer), dropped = staged
    # in-flight when the slot turned over (wasted transfer)
    sync_pages: float = 0.0
    async_pages: float = 0.0
    reused_pages: float = 0.0
    dropped_pages: float = 0.0
    page_block_bytes: int = 0         # bytes of one (kv-head, page) K+V block
    # quantized host KV tier (src/repro/quant): with kv_quant != "none",
    # page_block_bytes is the *packed* transfer unit (payload + fp32 scales)
    # and these carry the dense-equivalent comparison + dequant accounting
    kv_quant: str = "none"
    dense_block_bytes: int = 0        # unquantized block bytes (same dtype)
    dequant_elems_per_block: int = 0  # elements dequantized per moved block
    pool_bytes_physical: float = 0.0  # slot-pool host-tier bytes (packed)
    pool_bytes_dense: float = 0.0     # same capacity unquantized
    # True when the pool lives in pinned_host memory (real host->device DMA);
    # False under offload='sim' (transfers are cost-model-accounted only)
    transfer_is_dma: bool = False
    prefix_cache: Dict = field(default_factory=dict)
    scheduler: str = "continuous"
    # tensor-parallel serving: page counts above are GLOBAL (psum'ed across
    # the KV-head-group shards); each shard moves 1/tp of them over its own
    # host link — see summary()["tp"] for the per-shard view
    tp: int = 1
    # host-sync-free decode loop (models.decode_window): the scheduler
    # dispatches up to sync_interval fused steps per host synchronization
    # and tallies every byte it moves across the host boundary during
    # decode. With sample_on_device, NOTHING moves between syncs (tokens,
    # finished masks and stats accumulate in device blocks pulled once per
    # sync), so nonsync_host_bytes stays 0 by construction; the synchronous
    # reference path (sample_on_device=False) syncs every step.
    sync_interval: int = 1
    sample_on_device: bool = True
    host_syncs: int = 0               # host bookkeeping boundaries hit
    sync_bytes_to_host: float = 0.0   # token/valid/stat blocks pulled at syncs
    sync_bytes_to_device: float = 0.0  # loop-lane pushes at syncs
    nonsync_host_bytes: float = 0.0   # decode-loop transfers BETWEEN syncs

    def record_step(self, n_active: int):
        self.steps += 1
        self.active_slot_steps += n_active

    @property
    def slot_occupancy(self) -> float:
        total = self.steps * self.num_slots
        return self.active_slot_steps / total if total else 0.0

    @property
    def generated_tokens(self) -> int:
        return sum(r.new_tokens for r in self.requests)

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / self.wall_s if self.wall_s else 0.0

    @property
    def recall_bytes(self) -> Dict[str, float]:
        return {"sync": self.sync_pages * self.page_block_bytes,
                "async": self.async_pages * self.page_block_bytes,
                "dropped": self.dropped_pages * self.page_block_bytes}

    @property
    def exposed_transfer_bytes(self) -> float:
        """Bytes whose transfer latency the decode critical path saw."""
        return self.sync_pages * self.page_block_bytes

    @property
    def hidden_transfer_bytes(self) -> float:
        """Bytes streamed behind decode compute (staged double buffer)."""
        return self.async_pages * self.page_block_bytes

    @property
    def moved_page_blocks(self) -> float:
        """(kv-head, page) blocks that actually transferred (sync + async —
        reused blocks moved nothing)."""
        return self.sync_pages + self.async_pages

    @property
    def transfer_bytes_saved(self) -> float:
        """Host->device bytes the quantized tier removed vs a dense pool of
        the same dtype (moved blocks x per-block shrink). 0 when off."""
        if self.kv_quant == "none" or not self.dense_block_bytes:
            return 0.0
        return self.moved_page_blocks * (self.dense_block_bytes
                                         - self.page_block_bytes)

    @property
    def dequant_overhead_s(self) -> float:
        """Cost-model estimate of cumulative fused-dequant time (every moved
        block is dequantized exactly once on recall). Measured per-step
        overhead comes from ``benchmarks/quant_quality.py``."""
        if self.kv_quant == "none":
            return 0.0
        from repro.quant import DEQUANT_ELEMS_PER_S
        return (self.moved_page_blocks * self.dequant_elems_per_block
                / DEQUANT_ELEMS_PER_S)

    @property
    def per_shard_transfer_bytes(self) -> Dict[str, float]:
        """Host->device bytes each tensor-parallel shard moves over its own
        link. Page counts are global; the KV-head-group sharding splits
        every transfer class evenly across the tp shards (each page block
        belongs to exactly one KV head, hence one shard)."""
        tp = max(self.tp, 1)
        return {"sync": self.exposed_transfer_bytes / tp,
                "async": self.hidden_transfer_bytes / tp,
                "dropped": self.dropped_pages * self.page_block_bytes / tp}

    @property
    def steps_per_sync(self) -> float:
        """Decode steps executed per host synchronization (the k-step-ahead
        dispatch depth actually realized, early exits included)."""
        return self.steps / self.host_syncs if self.host_syncs else 0.0

    @property
    def host_bytes_per_step(self) -> float:
        """Mean decode-loop host-boundary traffic per executed step."""
        total = (self.sync_bytes_to_host + self.sync_bytes_to_device
                 + self.nonsync_host_bytes)
        return total / self.steps if self.steps else 0.0

    @property
    def nonsync_bytes_per_step(self) -> float:
        """Host-boundary bytes moved per step OUTSIDE sync points — 0 under
        the host-sync-free loop (its defining property)."""
        return self.nonsync_host_bytes / self.steps if self.steps else 0.0

    @property
    def hidden_fraction(self) -> float:
        """Fraction of transferred recall bytes hidden behind compute.

        Buffer-reuse hits move no bytes at all, so they appear in neither
        numerator nor denominator — see ``reused_pages`` for that saving."""
        moved = self.hidden_transfer_bytes + self.exposed_transfer_bytes
        return self.hidden_transfer_bytes / moved if moved else 0.0

    def summary(self) -> dict:
        done = [r for r in self.requests if r.finish_t is not None]
        return {
            "scheduler": self.scheduler,
            "requests": len(self.requests),
            "completed": len(done),
            "generated_tokens": self.generated_tokens,
            "wall_s": self.wall_s,
            "tokens_per_s": self.tokens_per_s,
            "steps": self.steps,
            "slot_occupancy": self.slot_occupancy,
            "queue_wait_s_mean": _mean([r.queue_wait_s for r in done
                                        if r.queue_wait_s is not None]),
            "ttft_s_mean": _mean([r.ttft_s for r in done
                                  if r.ttft_s is not None]),
            "itl_s_mean": _mean([r.itl_s for r in done
                                 if r.itl_s is not None]),
            "recall_bytes_sync": self.recall_bytes["sync"],
            "recall_bytes_async": self.recall_bytes["async"],
            "recall_overlap": {
                "hidden_bytes": self.hidden_transfer_bytes,
                "exposed_bytes": self.exposed_transfer_bytes,
                "hidden_fraction": self.hidden_fraction,
                "reused_pages": self.reused_pages,
                "dropped_in_flight_bytes":
                    self.dropped_pages * self.page_block_bytes,
                "transfer_is_dma": self.transfer_is_dma,
            },
            "tp": {
                "tp": self.tp,
                "per_shard_transfer_bytes": self.per_shard_transfer_bytes,
            },
            "dispatch": {
                "sync_interval": self.sync_interval,
                "sample_on_device": self.sample_on_device,
                "host_syncs": self.host_syncs,
                "steps_per_sync": self.steps_per_sync,
                "sync_bytes_to_host": self.sync_bytes_to_host,
                "sync_bytes_to_device": self.sync_bytes_to_device,
                "nonsync_host_bytes": self.nonsync_host_bytes,
                "nonsync_bytes_per_step": self.nonsync_bytes_per_step,
                "host_bytes_per_step": self.host_bytes_per_step,
            },
            "kv_quant": {
                "mode": self.kv_quant,
                "page_block_bytes": self.page_block_bytes,
                "dense_block_bytes": self.dense_block_bytes,
                "moved_page_blocks": self.moved_page_blocks,
                "bytes_saved": self.transfer_bytes_saved,
                "dequant_overhead_s": self.dequant_overhead_s,
                "pool_bytes_physical": self.pool_bytes_physical,
                "pool_bytes_dense": self.pool_bytes_dense,
                "pool_compression": (self.pool_bytes_dense
                                     / self.pool_bytes_physical
                                     if self.pool_bytes_physical else 1.0),
            },
            "prefix_cache": dict(self.prefix_cache),
        }
