"""Serving telemetry: per-request lifecycle timings + engine-level counters.

All timestamps are ``time.perf_counter()`` values relative to the scheduler
run's start; derived quantities (queue wait, TTFT, inter-token latency) are
exposed as properties so callers never recompute them inconsistently.

Since the observability PR, ``EngineMetrics`` is a *view* over a
per-run :class:`repro.obs.MetricsRegistry` — every accumulator that used
to be an ad-hoc dataclass field (steps, page counts, host-boundary
bytes, ...) is a named registry counter/gauge exposed through
attribute-style properties, so existing callers (scheduler, tests,
benchmarks) keep reading/writing ``em.steps`` etc. while exporters
(``launch/serve.py --metrics-out/--prom-out``) get the full registry:
the same scalars plus TTFT/ITL/queue-wait/decode-step latency histograms
and the speculation-quality histograms fed from ``decode_window``'s
device-side stat blocks. Metric names are cataloged in
docs/observability.md.

``EngineMetrics.summary()`` is the single dict consumed by
``benchmarks/serving_throughput.py`` and the serving launcher.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.obs.registry import (COUNT_BUCKETS, LATENCY_BUCKETS, RATE_BUCKETS,
                                MetricsRegistry)


@dataclass
class RequestMetrics:
    uid: int
    prompt_tokens: int = 0            # raw prompt length
    padded_prompt_tokens: int = 0     # after bucket padding
    prefix_hit_tokens: int = 0        # prompt tokens served from the prefix cache
    max_new_tokens: int = 0
    enqueue_t: float = 0.0
    prefill_start_t: Optional[float] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    finish_step: Optional[int] = None  # engine step index at completion
    new_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    priority: int = 0
    preemptions: int = 0              # times this request was swapped out
    max_token_gap_s: float = 0.0      # worst observed inter-token gap
    cancelled: bool = False           # client-cancelled mid-flight
    # per-request SLO tags (milliseconds); None inherits the engine-level
    # defaults (EngineMetrics.slo_ttft_ms / slo_itl_ms)
    slo_ttft_ms: Optional[float] = None
    slo_itl_ms: Optional[float] = None

    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.prefill_start_t is None:
            return None
        return self.prefill_start_t - self.enqueue_t

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.enqueue_t

    @property
    def itl_s(self) -> Optional[float]:
        """Mean inter-token latency after the first token."""
        if self.finish_t is None or self.first_token_t is None \
                or self.new_tokens < 2:
            return None
        return (self.finish_t - self.first_token_t) / (self.new_tokens - 1)


def _mean(xs: List[float]) -> float:
    return sum(xs) / len(xs) if xs else 0.0


# attribute name -> (registry metric name, cast, help); attached as
# properties below so ``em.steps += 1`` / ``em.steps = 0`` keep working
_COUNTER_ATTRS = {
    "steps": ("engine_steps_total", int,
              "decode steps executed"),
    "active_slot_steps": ("engine_active_slot_steps_total", int,
                          "sum over steps of active slots"),
    "sync_pages": ("recall_sync_pages_total", float,
                   "blocking (kv-head, page) blocks on the critical path"),
    "async_pages": ("recall_async_pages_total", float,
                    "staged blocks hidden behind compute"),
    "reused_pages": ("recall_reused_pages_total", float,
                     "blocks served from the resident double buffer"),
    "host_syncs": ("dispatch_host_syncs_total", int,
                   "host bookkeeping boundaries hit"),
    "sync_bytes_to_host": ("dispatch_sync_bytes_to_host_total", float,
                           "token/valid/stat blocks pulled at syncs"),
    "sync_bytes_to_device": ("dispatch_sync_bytes_to_device_total", float,
                             "loop-lane pushes at syncs"),
    "nonsync_host_bytes": ("dispatch_nonsync_host_bytes_total", float,
                           "decode-loop transfers BETWEEN syncs"),
    "sel_pages": ("spec_sel_pages_total", float,
                  "speculatively selected (kv-head, page) slots"),
    "spec_hit_pages": ("spec_hit_pages_total", float,
                       "selected pages already resident from the previous "
                       "step's speculation"),
    "churn_pages": ("spec_churn_pages_total", float,
                    "pages entering the top-k selection this step"),
    "corrected_heads": ("spec_corrected_heads_total", float,
                        "kv heads that triggered fine-grained correction"),
    "kv_head_steps": ("spec_kv_head_steps_total", float,
                      "kv-head decision opportunities (heads x steps)"),
    # speculative decoding (models.serve_step_spec): one "verify step" is a
    # drafted-block target pass; tokens it commits all share that step's
    # compute, which is where the speedup comes from
    "spec_verify_steps": ("specdec_verify_steps_total", int,
                          "drafted-block verify iterations dispatched"),
    "spec_slot_steps": ("specdec_slot_steps_total", int,
                        "live slot participations in verify steps"),
    "spec_proposed_tokens": ("specdec_proposed_tokens_total", float,
                             "drafted tokens proposed to verification"),
    "spec_accepted_tokens": ("specdec_accepted_tokens_total", float,
                             "drafted tokens accepted by the target pass"),
    "spec_committed_tokens": ("specdec_committed_tokens_total", float,
                              "tokens committed by verify steps (base + "
                              "accepted)"),
    "prefill_chunks": ("sched_prefill_chunks_total", int,
                       "chunked-prefill chunks executed"),
    "prefill_chunk_tokens": ("sched_prefill_chunk_tokens_total", int,
                             "prompt tokens prefilled through chunks"),
    "preemptions": ("sched_preemptions_total", int,
                    "requests swapped out of their slot to host"),
    "resumes": ("sched_resumes_total", int,
                "swapped-out requests swapped back into a slot"),
    "swap_out_bytes": ("sched_swap_out_bytes_total", float,
                       "decode-state bytes pulled to host at preemption"),
    "swap_in_bytes": ("sched_swap_in_bytes_total", float,
                      "decode-state bytes pushed back at resume"),
    "cancellations": ("sched_cancellations_total", int,
                      "requests cancelled mid-flight (client disconnect)"),
    "slo_tagged": ("slo_tagged_requests_total", int,
                   "completed requests carrying an effective SLO tag"),
    "slo_attained": ("slo_attained_requests_total", int,
                     "tagged requests meeting their TTFT+ITL SLOs"),
    "slo_good_tokens": ("slo_good_tokens_total", int,
                        "tokens from SLO-attaining requests (goodput "
                        "numerator)"),
}
_GAUGE_ATTRS = {
    "dropped_pages": ("recall_dropped_in_flight_pages", float,
                      "staged blocks abandoned at slot turnover"),
    "wall_s": ("engine_wall_seconds", float, "scheduler run wall clock"),
}

# histogram metric names (buckets fixed at first touch)
H_QUEUE_WAIT = "request_queue_wait_seconds"
H_TTFT = "request_ttft_seconds"
H_ITL = "request_itl_seconds"
H_PREFILL = "request_prefill_seconds"
H_DECODE_STEP = "engine_decode_step_seconds"
H_TOKEN_GAP = "request_token_gap_seconds"
H_HIT_RATE = "spec_hit_rate"
H_CORRECTION_RATE = "spec_correction_rate"
H_CHURN = "spec_churn_pages"
H_SPEC_TOKENS = "specdec_tokens_per_step"


@dataclass
class EngineMetrics:
    """Engine-level aggregation across one scheduler run.

    Scalar accumulators live in ``registry`` (see module docstring);
    the dataclass fields below are run *configuration* and derived-state
    inputs that don't stream.
    """
    num_slots: int = 0
    requests: List[RequestMetrics] = field(default_factory=list)
    registry: MetricsRegistry = field(default_factory=MetricsRegistry)
    page_block_bytes: int = 0         # bytes of one (kv-head, page) K+V block
    # quantized host KV tier (src/repro/quant): with kv_quant != "none",
    # page_block_bytes is the *packed* transfer unit (payload + fp32 scales)
    # and these carry the dense-equivalent comparison + dequant accounting
    kv_quant: str = "none"
    dense_block_bytes: int = 0        # unquantized block bytes (same dtype)
    dequant_elems_per_block: int = 0  # elements dequantized per moved block
    pool_bytes_physical: float = 0.0  # slot-pool host-tier bytes (packed)
    pool_bytes_dense: float = 0.0     # same capacity unquantized
    # True when the pool lives in pinned_host memory (real host->device DMA);
    # False under offload='sim' (transfers are cost-model-accounted only)
    transfer_is_dma: bool = False
    prefix_cache: Dict = field(default_factory=dict)
    scheduler: str = "continuous"
    # tensor-parallel serving: page counts are GLOBAL (psum'ed across the
    # KV-head-group shards); each shard moves 1/tp of them over its own
    # host link — see summary()["tp"] for the per-shard view
    tp: int = 1
    # host-sync-free decode loop (models.decode_window): the scheduler
    # dispatches up to sync_interval fused steps per host synchronization
    # and tallies every byte it moves across the host boundary during
    # decode. With sample_on_device, NOTHING moves between syncs (tokens,
    # finished masks and stats accumulate in device blocks pulled once per
    # sync), so nonsync_host_bytes stays 0 by construction; the synchronous
    # reference path (sample_on_device=False) syncs every step.
    sync_interval: int = 1
    sample_on_device: bool = True
    # speculative decoding: drafted tokens per verify step (0 = off). When
    # on, a "step" in the ITL sense commits up to 1 + draft_len tokens; the
    # scheduler interpolates per-token timestamps inside a verify step and
    # flags them in the frontend event payload.
    draft_len: int = 0
    # engine-level SLO defaults (milliseconds; None = untagged). A request
    # whose RequestMetrics carries its own tag overrides these; requests
    # with NO effective tag are excluded from attainment/goodput.
    slo_ttft_ms: Optional[float] = None
    slo_itl_ms: Optional[float] = None

    # -- recording helpers ----------------------------------------------
    def record_step(self, n_active: int):
        self.steps += 1
        self.active_slot_steps += n_active

    def observe_decode_step(self, dt_s: float):
        self.registry.histogram(H_DECODE_STEP, LATENCY_BUCKETS,
                                "per-step decode latency").observe(dt_s)

    def observe_token_gap(self, gap_s: float):
        """One emitted token's gap since the request's previous token.

        Unlike ``itl_s`` (a per-request mean that averages stalls away),
        the gap distribution exposes the tail the scheduler work targets:
        a co-batched decoder stalled behind a whole-shot prefill shows up
        as one huge gap, and its p99 is what chunked prefill bounds to
        ~one chunk's compute. Always recorded (a histogram observe per
        token, same cost class as the per-request latency histograms)."""
        self.registry.histogram(H_TOKEN_GAP, LATENCY_BUCKETS,
                                "per-token inter-token gap").observe(gap_s)

    def observe_speculation(self, sel: float, hit: float, churn: float,
                            corrected: float, kv_heads: float):
        """One slot-step of speculation-quality histograms: values come
        from ``decode_window``'s device-side stat blocks, pulled at the
        sync boundary — recording them here adds no host traffic. (The
        matching run totals accumulate via the ``sel_pages``/... counter
        attributes, fed by the scheduler for every run, obs on or off.)"""
        reg = self.registry
        if sel > 0:
            reg.histogram(H_HIT_RATE, RATE_BUCKETS,
                          "per-step speculative page-hit rate").observe(
                              hit / sel)
            reg.histogram(H_CHURN, COUNT_BUCKETS,
                          "pages entering top-k per step").observe(churn)
        if kv_heads > 0:
            reg.histogram(H_CORRECTION_RATE, RATE_BUCKETS,
                          "per-step corrected-head fraction").observe(
                              corrected / kv_heads)

    def observe_spec_step(self, tokens_per_step: float):
        """One verify step's committed-tokens-per-live-slot (>= 1 while any
        slot is live; the multi-token-step analogue of the per-step ITL
        distributions — accepted counts per target step, from the same
        sync-boundary block pull)."""
        self.registry.histogram(H_SPEC_TOKENS, COUNT_BUCKETS,
                                "tokens committed per verify step per "
                                "slot").observe(tokens_per_step)

    def slo_check(self, rm: RequestMetrics):
        """Effective-SLO verdict for one finished request.

        Returns (tagged, attained): ``tagged`` iff the request carries an
        effective TTFT or ITL SLO (its own tag, else the engine default);
        ``attained`` iff every effective bound holds — TTFT against
        ``rm.ttft_s``, ITL against the request's *mean* inter-token latency
        (``rm.itl_s``; single-token requests have no ITL and pass that
        bound vacuously)."""
        t_slo = rm.slo_ttft_ms if rm.slo_ttft_ms is not None \
            else self.slo_ttft_ms
        i_slo = rm.slo_itl_ms if rm.slo_itl_ms is not None \
            else self.slo_itl_ms
        if t_slo is None and i_slo is None:
            return False, False
        ok = True
        if t_slo is not None and (rm.ttft_s is None
                                  or rm.ttft_s * 1e3 > t_slo):
            ok = False
        if i_slo is not None and rm.itl_s is not None \
                and rm.itl_s * 1e3 > i_slo:
            ok = False
        return True, ok

    def record_request(self, rm: RequestMetrics):
        """Observe a finished request's latency distributions."""
        reg = self.registry
        reg.counter("requests_completed_total").inc()
        reg.counter("request_tokens_generated_total").inc(rm.new_tokens)
        tagged, attained = self.slo_check(rm)
        if tagged:
            self.slo_tagged += 1
            if attained:
                self.slo_attained += 1
                self.slo_good_tokens += rm.new_tokens
        if rm.queue_wait_s is not None:
            reg.histogram(H_QUEUE_WAIT, LATENCY_BUCKETS,
                          "enqueue -> prefill start").observe(rm.queue_wait_s)
        if rm.ttft_s is not None:
            reg.histogram(H_TTFT, LATENCY_BUCKETS,
                          "enqueue -> first token").observe(rm.ttft_s)
        if rm.itl_s is not None:
            reg.histogram(H_ITL, LATENCY_BUCKETS,
                          "mean inter-token latency").observe(rm.itl_s)
        if rm.prefill_s > 0:
            reg.histogram(H_PREFILL, LATENCY_BUCKETS,
                          "prefill forward time").observe(rm.prefill_s)

    # -- derived views ---------------------------------------------------
    @property
    def slot_occupancy(self) -> float:
        total = self.steps * self.num_slots
        return self.active_slot_steps / total if total else 0.0

    @property
    def generated_tokens(self) -> int:
        return sum(r.new_tokens for r in self.requests)

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / self.wall_s if self.wall_s else 0.0

    @property
    def recall_bytes(self) -> Dict[str, float]:
        return {"sync": self.sync_pages * self.page_block_bytes,
                "async": self.async_pages * self.page_block_bytes,
                "dropped": self.dropped_pages * self.page_block_bytes}

    @property
    def exposed_transfer_bytes(self) -> float:
        """Bytes whose transfer latency the decode critical path saw."""
        return self.sync_pages * self.page_block_bytes

    @property
    def hidden_transfer_bytes(self) -> float:
        """Bytes streamed behind decode compute (staged double buffer)."""
        return self.async_pages * self.page_block_bytes

    @property
    def moved_page_blocks(self) -> float:
        """(kv-head, page) blocks that actually transferred (sync + async —
        reused blocks moved nothing)."""
        return self.sync_pages + self.async_pages

    @property
    def transfer_bytes_saved(self) -> float:
        """Host->device bytes the quantized tier removed vs a dense pool of
        the same dtype (moved blocks x per-block shrink). 0 when off."""
        if self.kv_quant == "none" or not self.dense_block_bytes:
            return 0.0
        return self.moved_page_blocks * (self.dense_block_bytes
                                         - self.page_block_bytes)

    @property
    def dequant_overhead_s(self) -> float:
        """Cost-model estimate of cumulative fused-dequant time (every moved
        block is dequantized exactly once on recall). Measured per-step
        overhead comes from ``benchmarks/quant_quality.py``."""
        if self.kv_quant == "none":
            return 0.0
        from repro.quant import DEQUANT_ELEMS_PER_S
        return (self.moved_page_blocks * self.dequant_elems_per_block
                / DEQUANT_ELEMS_PER_S)

    @property
    def per_shard_transfer_bytes(self) -> Dict[str, float]:
        """Host->device bytes each tensor-parallel shard moves over its own
        link. Page counts are global; the KV-head-group sharding splits
        every transfer class evenly across the tp shards (each page block
        belongs to exactly one KV head, hence one shard)."""
        tp = max(self.tp, 1)
        return {"sync": self.exposed_transfer_bytes / tp,
                "async": self.hidden_transfer_bytes / tp,
                "dropped": self.dropped_pages * self.page_block_bytes / tp}

    @property
    def steps_per_sync(self) -> float:
        """Decode steps executed per host synchronization (the k-step-ahead
        dispatch depth actually realized, early exits included)."""
        return self.steps / self.host_syncs if self.host_syncs else 0.0

    @property
    def host_bytes_per_step(self) -> float:
        """Mean decode-loop host-boundary traffic per executed step."""
        total = (self.sync_bytes_to_host + self.sync_bytes_to_device
                 + self.nonsync_host_bytes)
        return total / self.steps if self.steps else 0.0

    @property
    def nonsync_bytes_per_step(self) -> float:
        """Host-boundary bytes moved per step OUTSIDE sync points — 0 under
        the host-sync-free loop (its defining property)."""
        return self.nonsync_host_bytes / self.steps if self.steps else 0.0

    @property
    def hidden_fraction(self) -> float:
        """Fraction of transferred recall bytes hidden behind compute.

        Buffer-reuse hits move no bytes at all, so they appear in neither
        numerator nor denominator — see ``reused_pages`` for that saving."""
        moved = self.hidden_transfer_bytes + self.exposed_transfer_bytes
        return self.hidden_transfer_bytes / moved if moved else 0.0

    @property
    def spec_hit_rate_mean(self) -> float:
        """Run-level speculative hit rate: fraction of selected pages the
        previous step's speculation already made resident."""
        return self.spec_hit_pages / self.sel_pages if self.sel_pages else 0.0

    @property
    def correction_rate_mean(self) -> float:
        """Run-level corrected-head fraction (the paper's accuracy dial)."""
        return (self.corrected_heads / self.kv_head_steps
                if self.kv_head_steps else 0.0)

    @property
    def spec_accept_rate(self) -> float:
        """Fraction of drafted tokens the target pass accepted."""
        return (self.spec_accepted_tokens / self.spec_proposed_tokens
                if self.spec_proposed_tokens else 0.0)

    @property
    def spec_tokens_per_target_step(self) -> float:
        """Tokens committed per live slot per verify step (1.0 would be the
        non-drafted path; the decode speedup upper bound is this ratio)."""
        return (self.spec_committed_tokens / self.spec_slot_steps
                if self.spec_slot_steps else 0.0)

    def specdec_summary(self) -> dict:
        return {
            "draft_len": self.draft_len,
            "verify_steps": self.spec_verify_steps,
            "proposed_tokens": self.spec_proposed_tokens,
            "accepted_tokens": self.spec_accepted_tokens,
            "committed_tokens": self.spec_committed_tokens,
            "accept_rate": self.spec_accept_rate,
            "tokens_per_step": self.spec_tokens_per_target_step,
            "tokens_per_step_hist": self._hist_summary(H_SPEC_TOKENS,
                                                       COUNT_BUCKETS),
        }

    @property
    def slo_attainment(self) -> float:
        """Fraction of SLO-tagged completed requests meeting their SLOs
        (1.0 with no tagged traffic — nothing violated)."""
        return self.slo_attained / self.slo_tagged if self.slo_tagged else 1.0

    @property
    def goodput_tokens_per_s(self) -> float:
        """Tokens/s counting ONLY tokens from SLO-attaining requests — the
        serving metric the open-loop harness sweeps vs offered load. With
        no tagged traffic this equals plain tokens_per_s."""
        good = (self.slo_good_tokens if self.slo_tagged
                else self.generated_tokens)
        return good / self.wall_s if self.wall_s else 0.0

    def slo_summary(self) -> dict:
        return {
            "ttft_ms": self.slo_ttft_ms,
            "itl_ms": self.slo_itl_ms,
            "tagged": self.slo_tagged,
            "attained": self.slo_attained,
            "attainment": self.slo_attainment,
            "good_tokens": self.slo_good_tokens,
            "goodput_tokens_per_s": self.goodput_tokens_per_s,
            "cancelled": self.cancellations,
        }

    def _hist_summary(self, name: str, buckets) -> dict:
        return self.registry.histogram(name, buckets).summary()

    def summary(self) -> dict:
        done = [r for r in self.requests
                if r.finish_t is not None and not r.cancelled]
        return {
            "scheduler": self.scheduler,
            "requests": len(self.requests),
            "completed": len(done),
            "cancelled": self.cancellations,
            "generated_tokens": self.generated_tokens,
            "wall_s": self.wall_s,
            "tokens_per_s": self.tokens_per_s,
            "steps": self.steps,
            "slot_occupancy": self.slot_occupancy,
            "queue_wait_s_mean": _mean([r.queue_wait_s for r in done
                                        if r.queue_wait_s is not None]),
            "ttft_s_mean": _mean([r.ttft_s for r in done
                                  if r.ttft_s is not None]),
            "itl_s_mean": _mean([r.itl_s for r in done
                                 if r.itl_s is not None]),
            "slo": self.slo_summary(),
            "specdec": self.specdec_summary(),
            "latency": {
                "queue_wait_s": self._hist_summary(H_QUEUE_WAIT,
                                                   LATENCY_BUCKETS),
                "ttft_s": self._hist_summary(H_TTFT, LATENCY_BUCKETS),
                "itl_s": self._hist_summary(H_ITL, LATENCY_BUCKETS),
                "decode_step_s": self._hist_summary(H_DECODE_STEP,
                                                    LATENCY_BUCKETS),
            },
            "speculation": {
                "sel_pages": self.sel_pages,
                "spec_hit_pages": self.spec_hit_pages,
                "churn_pages": self.churn_pages,
                "hit_rate_mean": self.spec_hit_rate_mean,
                "correction_rate_mean": self.correction_rate_mean,
                "hit_rate": self._hist_summary(H_HIT_RATE, RATE_BUCKETS),
                "correction_rate": self._hist_summary(H_CORRECTION_RATE,
                                                      RATE_BUCKETS),
                "churn": self._hist_summary(H_CHURN, COUNT_BUCKETS),
            },
            # canonical byte accounting: recall_overlap (the old top-level
            # recall_bytes_sync/async duplicates were removed — readers use
            # exposed_bytes/hidden_bytes here)
            "recall_overlap": {
                "hidden_bytes": self.hidden_transfer_bytes,
                "exposed_bytes": self.exposed_transfer_bytes,
                "hidden_fraction": self.hidden_fraction,
                "reused_pages": self.reused_pages,
                "dropped_in_flight_bytes":
                    self.dropped_pages * self.page_block_bytes,
                "transfer_is_dma": self.transfer_is_dma,
            },
            "tp": {
                "tp": self.tp,
                "per_shard_transfer_bytes": self.per_shard_transfer_bytes,
            },
            "scheduling": {
                "prefill_chunks": self.prefill_chunks,
                "prefill_chunk_tokens": self.prefill_chunk_tokens,
                "preemptions": self.preemptions,
                "resumes": self.resumes,
                "swap_out_bytes": self.swap_out_bytes,
                "swap_in_bytes": self.swap_in_bytes,
                "token_gap_s": self._hist_summary(H_TOKEN_GAP,
                                                  LATENCY_BUCKETS),
            },
            "dispatch": {
                "sync_interval": self.sync_interval,
                "sample_on_device": self.sample_on_device,
                "host_syncs": self.host_syncs,
                "steps_per_sync": self.steps_per_sync,
                "sync_bytes_to_host": self.sync_bytes_to_host,
                "sync_bytes_to_device": self.sync_bytes_to_device,
                "nonsync_host_bytes": self.nonsync_host_bytes,
                "nonsync_bytes_per_step": self.nonsync_bytes_per_step,
                "host_bytes_per_step": self.host_bytes_per_step,
            },
            "kv_quant": {
                "mode": self.kv_quant,
                "page_block_bytes": self.page_block_bytes,
                "dense_block_bytes": self.dense_block_bytes,
                "moved_page_blocks": self.moved_page_blocks,
                "bytes_saved": self.transfer_bytes_saved,
                "dequant_overhead_s": self.dequant_overhead_s,
                "pool_bytes_physical": self.pool_bytes_physical,
                "pool_bytes_dense": self.pool_bytes_dense,
                "pool_compression": (self.pool_bytes_dense
                                     / self.pool_bytes_physical
                                     if self.pool_bytes_physical else 1.0),
            },
            "prefix_cache": dict(self.prefix_cache),
        }


def _attach_registry_attrs():
    """Expose registry counters/gauges as read/write EngineMetrics
    attributes so the scheduler's ``em.steps += 1`` bookkeeping and every
    existing reader keep working unchanged."""
    def make(metric, cast, help, kind):
        def fget(self):
            m = getattr(self.registry, kind)(metric, help)
            return cast(m.value)

        def fset(self, v):
            getattr(self.registry, kind)(metric, help).set(float(v))
        return property(fget, fset)

    for attr, (metric, cast, help) in _COUNTER_ATTRS.items():
        setattr(EngineMetrics, attr, make(metric, cast, help, "counter"))
    for attr, (metric, cast, help) in _GAUGE_ATTRS.items():
        setattr(EngineMetrics, attr, make(metric, cast, help, "gauge"))


_attach_registry_attrs()
