"""Continuous-batching scheduler: admission queue + per-slot request lifecycle.

Requests move QUEUED -> PREFILL -> DECODE -> DONE. Slots are refilled at every
host boundary, so a short request's completion immediately frees capacity for
the next queued request instead of idling until the longest co-scheduled
request drains (the static chunked engine's behavior). Finished slots stop
contributing tokens or statistics the moment they drain.

Decode dispatch is HOST-SYNC-FREE (``fkv.sample_on_device``, the default):
the scheduler ships a device-resident loop carry — current tokens, per-slot
PRNG key streams, generated counts, limits, eos ids, finished mask — into
``backend.decode_window``, which runs up to ``fkv.sync_interval`` fused
(decode + on-device sample) steps with the decode state *donated* (updated
in place, never copied) and zero host round trips. The device loop exits
early when every lane finishes or, when admissions are queued, at the first
slot turnover. At each sync the host pulls the (k, B) token / valid / stat
blocks once, appends tokens, detokenizes, frees + refills slots, and only
re-uploads the tiny per-slot lanes that changed. Between syncs nothing
crosses the host boundary (``EngineMetrics.summary()["dispatch"]``).

``fkv.sample_on_device = False`` keeps the synchronous reference path: one
host synchronization per decode step (sampled on the same per-request key
streams, so outputs are identical — and greedy is bit-identical across both
paths and every ``sync_interval``).

CHUNKED PREFILL (``backend.prefill_chunk_tokens > 0``): admission no longer
runs the whole prompt's prefill inline. The request takes a slot and opens a
``backend.start_prefill_job`` state machine; each scheduler round spends at
most ``prefill_chunk_tokens`` prompt tokens across the open jobs (oldest
first) before dispatching the next decode window, so co-batched decoders
stall for at most ~one chunk's compute instead of the whole prefill. The
final chunk builds the decode state from the full accumulated K/V — the
prefix-cache extension math — so outputs are bit-identical to whole-shot.

PREEMPTION (``backend.preempt``): admission stays FIFO, but when the pool is
full and a queued request's priority STRICTLY exceeds the lowest-priority
running (decode-state) request's, that victim's entire slot state — paged
pool at its packed quantized width, scales, rings, selection buffers — is
swapped to host (``SlotPool.swap_out``), the slot handed to the candidate,
and the victim re-queued as SWAPPED; on re-admission ``swap_in`` restores
the slot bit-exactly and its lane (current token, key stream position,
count) is rebuilt from host bookkeeping, so the victim's remaining tokens
are bit-identical to an uninterrupted run. Strict priority inequality means
equal-priority traffic never preempts (liveness: no swap cycles).

The scheduler is backend-agnostic: it drives any object exposing

    prefill_one(request) -> (logits (1, V), B=1 decode state, prefix_hit_tokens,
                             padded_prompt_tokens)
    step(state, tokens (B, 1)) -> (logits (B, V), state, stats)
    sample_slot(logits, req_key, count) -> tokens (1,)
    sample_lanes(logits, keys (B,2), counts (B,)) -> tokens (B,)
    decode_window(state, loop) -> (state, loop, toks, valid, stats, n)
    make_slot_pool(num_slots) -> kv_slots.SlotPool
    page_block_bytes -> int
    prefill_chunk_tokens -> int        (optional; 0 = whole-shot prefill)
    start_prefill_job(request) -> job  (optional; .advance/.done/.result)
    preempt -> bool                    (optional; pool needs swap_out/swap_in)

(``ServeEngine`` is the production backend; tests inject lightweight fakes.
A backend without ``decode_window`` falls back to the synchronous path.)
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.recall_pipeline import RecallFlightTracker
from repro.models.model import DECODE_STAT_KEYS as _STAT_KEYS
from repro.obs import Observability
from repro.obs.trace import (SPAN_DECODE_STEP, SPAN_DECODE_WINDOW,
                             SPAN_PREFILL_CHUNK, SPAN_SCHED_CANCEL,
                             SPAN_SCHED_PREEMPT, SPAN_SCHED_RESUME,
                             SPAN_SPEC_VERIFY)
from repro.serving.metrics import EngineMetrics, RequestMetrics
from repro.serving.sampling import request_key

# stat keys the engine-level counters accumulate (a subset of _STAT_KEYS;
# per-request aggregation keeps the full tuple)
_PAGE_KEYS = ("sync_pages", "async_pages", "reused_pages", "sel_pages",
              "spec_hit_pages", "churn_pages")

# request lifecycle states (SWAPPED = preempted, paged KV parked on host;
# CANCELLED = terminal, client abandoned the request mid-flight)
QUEUED, PREFILL, DECODE, DONE = "queued", "prefill", "decode", "done"
SWAPPED = "swapped"
CANCELLED = "cancelled"


def _prio(tr: "_Tracked") -> int:
    return getattr(tr.req, "priority", 0)


def _state_nbytes(host_state) -> float:
    return float(sum(leaf.nbytes for leaf in jax.tree.leaves(host_state)
                     if hasattr(leaf, "nbytes")))


@dataclass
class _Tracked:
    req: object                       # engine.Request (duck-typed)
    order: int                        # position in the submitted batch
    metrics: RequestMetrics
    state: str = QUEUED
    slot: int = -1
    tokens: List[int] = field(default_factory=list)
    prefill_s: float = 0.0
    decode_s: float = 0.0
    job: object = None                # open PrefillJob (chunked prefill)
    host_state: object = None         # swapped-out B=1 decode state (numpy)
    flight_pages: float = 0.0         # staged recall suspended with the swap
    last_tok_t: Optional[float] = None  # run-relative time of last token
    agg: Dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in _STAT_KEYS})

    def finished(self) -> bool:
        if len(self.tokens) >= self.req.max_new_tokens:
            return True
        eos = getattr(self.req, "eos_token", None)
        return bool(self.tokens) and eos is not None and self.tokens[-1] == eos


def _request_stats(agg: Dict[str, float]) -> dict:
    stats = dict(agg)
    if agg["kv_heads"] > 0:
        stats["correction_rate"] = agg["corrected"] / agg["kv_heads"]
        stats["mean_similarity"] = (agg["sim_sum"] / agg["sim_cnt"]
                                    if agg["sim_cnt"] else 0.0)
    if agg.get("sel_pages", 0) > 0:
        stats["spec_hit_rate"] = agg["spec_hit_pages"] / agg["sel_pages"]
    return stats


class _Lanes:
    """Host mirror of the device decode-loop carry: one lane per slot.

    The device copy is rebuilt (one tiny (B,)-vector upload) only when a
    lane changed at a sync boundary — admission, turnover — so steady-state
    decode re-uploads nothing, not even the token vector."""

    FIELDS = ("cur", "key", "count", "limit", "eos", "fin")

    def __init__(self, num_slots: int):
        self.cur = np.zeros(num_slots, np.int32)
        self.key = np.zeros((num_slots, 2), np.uint32)
        self.count = np.zeros(num_slots, np.int32)
        self.limit = np.ones(num_slots, np.int32)
        self.eos = np.full(num_slots, -1, np.int32)
        self.fin = np.ones(num_slots, bool)      # empty lanes are "finished"
        self.dirty = True
        self._dev = None

    def admit(self, slot: int, tok: int, key_np, count: int, limit: int,
              eos: Optional[int]):
        self.cur[slot] = tok
        self.key[slot] = key_np
        self.count[slot] = count
        self.limit[slot] = limit
        self.eos[slot] = -1 if eos is None else eos
        self.fin[slot] = False
        self.dirty = True

    def retire(self, slot: int):
        self.fin[slot] = True
        self.dirty = True

    def device_loop(self, stop_turnover: bool, em: EngineMetrics):
        """The loop carry to ship; uploads lanes only when dirty."""
        if self.dirty or self._dev is None:
            self._dev = {f: jnp.asarray(getattr(self, f)) for f in self.FIELDS}
            em.sync_bytes_to_device += sum(
                getattr(self, f).nbytes for f in self.FIELDS)
            self.dirty = False
        loop = dict(self._dev)
        loop["stop_turnover"] = jnp.asarray(stop_turnover)
        return loop

    def carry_back(self, loop):
        """Keep the donated device carry for the next window (the host
        mirrors are updated from the pulled blocks as tokens are applied)."""
        self._dev = {f: loop[f] for f in self.FIELDS}


class ContinuousScheduler:
    """Drives one run of requests to completion over a fixed slot pool."""

    def __init__(self, backend, pool):
        self.backend = backend
        self.pool = pool

    def run(self, requests, seed: int = 0, service=None):
        """Returns (tracked records in submission order, EngineMetrics).

        ``service`` (optional) switches the scheduler into live-serving
        mode: each host round drains ``service.poll()`` into the admission
        queue and ``service.drain_cancels()`` into the cancellation pass,
        per-token/terminal events stream back via ``service.emit_token`` /
        ``service.emit_finish``, and the run ends only once the service is
        ``closed`` and drained (see ``serving/frontend.EngineService``).
        """
        backend, pool = self.backend, self.pool
        on_device = (bool(getattr(backend, "sample_on_device", False))
                     and hasattr(backend, "decode_window"))
        obs = getattr(backend, "obs", None) or Observability.off()
        self._obs, self._trace = obs, obs.trace
        board = obs.timeseries          # None -> no windowed aggregation
        self._page_block_bytes = backend.page_block_bytes
        t0 = time.perf_counter()
        self._t0 = t0
        now = lambda: time.perf_counter() - t0  # noqa: E731
        abst = lambda rel: t0 + rel             # noqa: E731  (board clock)

        queue: deque = deque()
        by_uid: Dict[int, _Tracked] = {}
        next_order = 0

        def track(r) -> _Tracked:
            nonlocal next_order
            rm = RequestMetrics(uid=r.uid, prompt_tokens=len(r.tokens),
                                max_new_tokens=r.max_new_tokens,
                                priority=getattr(r, "priority", 0),
                                enqueue_t=now(),
                                slo_ttft_ms=getattr(r, "slo_ttft_ms", None),
                                slo_itl_ms=getattr(r, "slo_itl_ms", None))
            tr = _Tracked(req=r, order=next_order, metrics=rm)
            next_order += 1
            by_uid[r.uid] = tr
            return tr

        for r in requests:
            queue.append(track(r))

        em = EngineMetrics(num_slots=pool.num_slots, scheduler="continuous",
                           page_block_bytes=backend.page_block_bytes,
                           tp=getattr(backend, "tp", 1),
                           sync_interval=(getattr(backend, "sync_interval", 1)
                                          if on_device else 1),
                           sample_on_device=on_device,
                           draft_len=int(getattr(backend, "draft_len", 0)),
                           slo_ttft_ms=getattr(backend, "slo_ttft_ms", None),
                           slo_itl_ms=getattr(backend, "slo_itl_ms", None))
        svc = service
        if svc is not None:
            svc.attach(em, t0)
        # per-slot in-flight staged recall: the double buffer a slot carries
        # out of step t is consumed by step t+1 unless the slot turns over
        flight = getattr(backend, "recall_tracker", None) \
            or RecallFlightTracker()
        active: Dict[int, _Tracked] = {}
        prefilling: Dict[int, _Tracked] = {}   # slot -> open chunked prefill
        lanes = _Lanes(pool.num_slots)
        done: List[_Tracked] = []
        self._step_idx = 0
        chunk = int(getattr(backend, "prefill_chunk_tokens", 0) or 0)
        if chunk > 0 and not hasattr(backend, "start_prefill_job"):
            chunk = 0
        preempt_on = bool(getattr(backend, "preempt", False))

        def finish(tr: _Tracked, slot: Optional[int]):
            tr.state = DONE
            tr.metrics.finish_t = now()
            tr.metrics.finish_step = self._step_idx
            tr.metrics.new_tokens = len(tr.tokens)
            tr.metrics.prefill_s = tr.prefill_s
            tr.metrics.decode_s = tr.decode_s
            em.record_request(tr.metrics)       # latency histograms
            self._trace.request_lifecycle(tr.metrics)
            done.append(tr)
            if slot is not None:
                flight.invalidate(slot)   # staged buffer abandoned in flight
                pool.free(slot)
                lanes.retire(slot)
            if board is not None:
                board.event("completions", 1.0, abst(tr.metrics.finish_t))
            if svc is not None:
                svc.emit_finish(tr.req.uid, tr)

        def cancel_pass(uids):
            """Terminal CANCELLED path (client disconnect): release the
            slot, drop in-flight staged recall, park nothing — surviving
            requests never observe the cancellation (their lanes, key
            streams and paged KV are untouched, so outputs stay
            bit-identical). Cancelled requests are excluded from
            ``completed`` / latency / SLO accounting."""
            for uid in uids:
                tr = by_uid.get(uid)
                if tr is None or tr.state in (DONE, CANCELLED):
                    continue
                slot = tr.slot if tr.slot >= 0 else None
                if tr.state in (QUEUED, SWAPPED):
                    try:
                        queue.remove(tr)
                    except ValueError:      # pragma: no cover - defensive
                        pass
                    tr.host_state = None    # parked KV dropped with the req
                    tr.flight_pages = 0.0
                elif tr.state == PREFILL and slot is not None \
                        and slot in prefilling:
                    del prefilling[slot]
                    tr.job = None
                    pool.free(slot)
                    lanes.retire(slot)
                elif tr.state == DECODE and slot is not None \
                        and slot in active:
                    del active[slot]
                    flight.invalidate(slot)
                    pool.free(slot)
                    lanes.retire(slot)
                tr.state = CANCELLED
                tr.slot = -1
                tr.metrics.cancelled = True
                tr.metrics.finish_t = now()
                tr.metrics.finish_step = self._step_idx
                tr.metrics.new_tokens = len(tr.tokens)
                tr.metrics.prefill_s = tr.prefill_s
                tr.metrics.decode_s = tr.decode_s
                em.cancellations += 1
                self._trace.instant(
                    SPAN_SCHED_CANCEL, tr.metrics.finish_t,
                    args={"uid": uid, "slot": -1 if slot is None else slot,
                          "tokens": len(tr.tokens)})
                if board is not None:
                    board.event("cancellations", 1.0,
                                abst(tr.metrics.finish_t))
                done.append(tr)
                if svc is not None:
                    svc.emit_finish(uid, tr)

        def apply_step(stats_np, toks_np, live_slots, dt, ts=None,
                       interpolated=False):
            """Host bookkeeping for ONE decode step: telemetry, token
            append, finish detection. Shared by both dispatch modes.
            ``ts`` (run-relative seconds) anchors the step's trace spans;
            everything recorded here came out of the sync-boundary stat
            pull — no extra host traffic. ``interpolated`` marks per-token
            timestamps subdivided out of one dispatch (window mode and
            speculative verify rows) for downstream event consumers."""
            em.record_step(len(live_slots))
            for k in _PAGE_KEYS + ("corrected_heads", "kv_head_steps"):
                src = {"corrected_heads": "corrected",
                       "kv_head_steps": "kv_heads"}.get(k, k)
                setattr(em, k, getattr(em, k)
                        + float(sum(stats_np[src][s] for s in live_slots)))
            for s in live_slots:
                flight.note_step(s, float(stats_np["async_pages"][s]),
                                 float(stats_np["sync_pages"][s]),
                                 float(stats_np["reused_pages"][s]))
            if obs.enabled:
                em.observe_decode_step(dt)
                for s in live_slots:
                    em.observe_speculation(
                        float(stats_np["sel_pages"][s]),
                        float(stats_np["spec_hit_pages"][s]),
                        float(stats_np["churn_pages"][s]),
                        float(stats_np["corrected"][s]),
                        float(stats_np["kv_heads"][s]))
            if ts is not None and self._trace.enabled:
                self._trace_step(stats_np, live_slots, ts, dt)
            tok_t = (ts + dt) if ts is not None else now()
            if board is not None:
                board.observe("decode_step_s", dt, abst(tok_t))
                board.observe("slot_occupancy",
                              len(live_slots) / max(pool.num_slots, 1),
                              abst(tok_t))
                sel = float(sum(stats_np["sel_pages"][s]
                                for s in live_slots))
                if sel > 0:
                    board.observe(
                        "spec_hit_rate",
                        float(sum(stats_np["spec_hit_pages"][s]
                                  for s in live_slots)) / sel,
                        abst(tok_t))
            for s in live_slots:
                tr = active[s]
                tr.decode_s += dt
                for k in _STAT_KEYS:
                    tr.agg[k] += float(stats_np[k][s])
                tok = int(toks_np[s])
                tr.tokens.append(tok)
                lanes.cur[s] = tok
                lanes.count[s] += 1
                if tr.last_tok_t is not None:
                    gap = max(tok_t - tr.last_tok_t, 0.0)
                    em.observe_token_gap(gap)
                    if gap > tr.metrics.max_token_gap_s:
                        tr.metrics.max_token_gap_s = gap
                    if board is not None:
                        board.observe("itl_s", gap, abst(tok_t))
                tr.last_tok_t = tok_t
                if board is not None:
                    board.event("tokens", 1.0, abst(tok_t))
                if svc is not None:
                    svc.emit_token(tr.req.uid, len(tr.tokens) - 1, tok,
                                   tok_t, interpolated=interpolated)
                if tr.finished():
                    del active[s]
                    finish(tr, s)
            self._step_idx += 1

        def begin_decode(tr, slot, logits1, rkey):
            """First token out of a completed prefill -> decode lane."""
            tok = int(np.asarray(backend.sample_slot(logits1, rkey, 0))[0])
            tr.metrics.first_token_t = now()
            tr.last_tok_t = tr.metrics.first_token_t
            tr.tokens.append(tok)
            tr.state = DECODE
            tr.slot = slot
            if board is not None:
                t_abs = abst(tr.metrics.first_token_t)
                board.observe("ttft_s", tr.metrics.first_token_t
                              - tr.metrics.enqueue_t, t_abs)
                board.event("tokens", 1.0, t_abs)
            if svc is not None:
                svc.emit_token(tr.req.uid, 0, tok,
                               tr.metrics.first_token_t)
            if tr.finished():           # max_new_tokens == 1 or instant EOS
                finish(tr, slot)
            else:
                active[slot] = tr
                lanes.admit(slot, tok, np.asarray(rkey), 1,
                            tr.req.max_new_tokens,
                            getattr(tr.req, "eos_token", None))

        def resume(tr):
            """Swap a preempted request's parked KV back into a fresh slot;
            its lane (current token, key stream, count) rebuilds from host
            bookkeeping, so generation continues bit-identically."""
            slot = pool.alloc(tr.req.uid)
            nbytes = _state_nbytes(tr.host_state)
            pool.swap_in(tr.host_state, slot)
            tr.host_state = None
            flight.restore(slot, tr.flight_pages)
            tr.flight_pages = 0.0
            rkey = request_key(seed, tr.req.uid)
            lanes.admit(slot, tr.tokens[-1], np.asarray(rkey),
                        len(tr.tokens), tr.req.max_new_tokens,
                        getattr(tr.req, "eos_token", None))
            tr.state = DECODE
            tr.slot = slot
            active[slot] = tr
            em.resumes += 1
            em.swap_in_bytes += nbytes
            if board is not None:
                board.event("swap_bytes", nbytes, abst(now()))
            self._trace.instant(SPAN_SCHED_RESUME, now(),
                                args={"uid": tr.req.uid, "slot": slot,
                                      "bytes": nbytes})

        def admit_one(tr):
            """Give the request a slot (caller guarantees one is free)."""
            if tr.state == SWAPPED:
                resume(tr)
                return
            if tr.req.max_new_tokens <= 0:
                finish(tr, None)
                return
            tr.state = PREFILL
            tr.metrics.prefill_start_t = now()
            if board is not None:
                board.observe("queue_wait_s", tr.metrics.prefill_start_t
                              - tr.metrics.enqueue_t,
                              abst(tr.metrics.prefill_start_t))
            slot = pool.alloc(tr.req.uid)
            if chunk > 0:
                # chunked path: the slot is held while the job advances one
                # budgeted chunk per scheduler round (advance_prefill)
                tr.job = backend.start_prefill_job(tr.req)
                tr.slot = slot
                prefilling[slot] = tr
                return
            tp = time.perf_counter()
            logits1, state1, hit, padded = backend.prefill_one(tr.req)
            pool.insert(state1, slot)
            # per-request sample stream: token i <- fold_in(rkey, i),
            # independent of slot placement and co-scheduling
            rkey = request_key(seed, tr.req.uid)
            tr.prefill_s = time.perf_counter() - tp
            tr.metrics.prefix_hit_tokens = hit
            tr.metrics.padded_prompt_tokens = padded
            begin_decode(tr, slot, logits1, rkey)

        def preempt_pass():
            """Swap the lowest-priority running request out to host whenever
            a STRICTLY higher-priority request waits for a slot. Terminates:
            each admission removes one queue entry and re-queues only a
            strictly lower-priority victim."""
            while queue and active:
                cand = max(queue, key=lambda t: (_prio(t), -t.order))
                victim = min(active.values(),
                             key=lambda t: (_prio(t), -t.order))
                if _prio(cand) <= _prio(victim):
                    return
                slot = victim.slot
                host = pool.swap_out(slot)
                nbytes = _state_nbytes(host)
                victim.host_state = host
                victim.flight_pages = flight.suspend(slot)
                del active[slot]
                pool.free(slot)
                lanes.retire(slot)
                victim.state = SWAPPED
                victim.slot = -1
                victim.metrics.preemptions += 1
                em.preemptions += 1
                em.swap_out_bytes += nbytes
                if board is not None:
                    t_abs = abst(now())
                    board.event("preemptions", 1.0, t_abs)
                    board.event("swap_bytes", nbytes, t_abs)
                self._trace.instant(
                    SPAN_SCHED_PREEMPT, now(),
                    args={"uid": victim.req.uid, "slot": slot,
                          "bytes": nbytes, "by_uid": cand.req.uid})
                queue.append(victim)
                queue.remove(cand)
                admit_one(cand)

        def advance_prefill():
            """Spend at most one ``chunk`` token budget across the open
            prefill jobs (oldest first); completed jobs splice their decode
            state into the slot and join the decode lanes."""
            budget = chunk
            for tr in sorted(prefilling.values(), key=lambda t: t.order):
                while budget > 0 and not tr.job.done:
                    tc = time.perf_counter()
                    n = tr.job.advance(budget)
                    dt = time.perf_counter() - tc
                    tr.prefill_s += dt
                    budget -= n
                    em.prefill_chunks += 1
                    em.prefill_chunk_tokens += n
                    self._trace.complete(
                        SPAN_PREFILL_CHUNK, tc - t0, dt,
                        args={"uid": tr.req.uid, "tokens": n,
                              "pos": tr.job.pos, "total": len(tr.job.seq)})
                if tr.job.done:
                    slot = tr.slot
                    del prefilling[slot]
                    logits1, state1, hit, padded = tr.job.result
                    tr.job = None
                    pool.insert(state1, slot)
                    tr.metrics.prefix_hit_tokens = hit
                    tr.metrics.padded_prompt_tokens = padded
                    begin_decode(tr, slot, logits1,
                                 request_key(seed, tr.req.uid))
                if budget <= 0:
                    break

        while queue or active or prefilling \
                or (svc is not None and not svc.closed):
            # -- live serving: drain arrivals + disconnects ---------------
            if svc is not None:
                for r in svc.poll():
                    queue.append(track(r))
                cancels = svc.drain_cancels()
                if cancels:
                    cancel_pass(cancels)
                em.wall_s = now()       # keep live tokens/s meaningful
            # -- admission: refill freed slots at the host boundary (FIFO) -
            while queue and pool.free_count:
                admit_one(queue.popleft())
            # -- preemption: priority seizes slots from lower-priority work -
            if preempt_on and queue:
                preempt_pass()
            # -- chunked prefill: one token budget per round ---------------
            if prefilling:
                advance_prefill()
            if not active:
                if svc is not None and not (queue or prefilling):
                    svc.wait(0.002)     # idle: park until work arrives
                continue

            pool.flush_resets()          # lazily reset freed-but-idle slots
            if on_device:
                self._window_steps(backend, pool, em, lanes, apply_step,
                                   stop_turnover=bool(queue)
                                   or (svc is not None and svc.pending),
                                   flight=flight)
            else:
                self._sync_step(backend, pool, em, lanes, apply_step)

        em.wall_s = now()
        em.dropped_pages = flight.dropped_pages
        done.sort(key=lambda tr: tr.order)
        em.requests = [tr.metrics for tr in done]
        return done, em

    # ------------------------------------------------------------------
    # decode dispatch modes
    # ------------------------------------------------------------------
    def _trace_step(self, stats_np, live_slots, ts, dt):
        """One decode step's trace spans (run-relative ts/dt seconds):
        the step itself on the decode track, the recall-stage split
        (blocking top-up vs overlapped stage) via TraceRecorder, and the
        speculation counter track."""
        tr = self._trace
        agg = {k: float(sum(stats_np[k][s] for s in live_slots))
               for k in ("sync_pages", "async_pages", "reused_pages",
                         "sel_pages", "spec_hit_pages", "corrected",
                         "kv_heads")}
        tr.complete(SPAN_DECODE_STEP, ts, dt,
                    args={"live_slots": len(live_slots),
                          "sync_pages": agg["sync_pages"],
                          "async_pages": agg["async_pages"]})
        tr.recall_step(ts, dt, sync_pages=agg["sync_pages"],
                       async_pages=agg["async_pages"],
                       reused_pages=agg["reused_pages"],
                       page_block_bytes=self._page_block_bytes)
        tr.counter("speculation", ts, {
            "hit_rate": (agg["spec_hit_pages"] / agg["sel_pages"]
                         if agg["sel_pages"] else 0.0),
            "correction_rate": (agg["corrected"] / agg["kv_heads"]
                                if agg["kv_heads"] else 0.0)})

    def _window_steps(self, backend, pool, em, lanes, apply_step,
                      stop_turnover: bool, flight=None):
        """Host-sync-free mode: dispatch up to sync_interval fused steps,
        then sync once — pull the token/valid/stat blocks, apply them."""
        loop = lanes.device_loop(stop_turnover, em)
        ts = time.perf_counter()
        ts_rel = ts - self._t0
        state, loop, toks, valid, stats, n = backend.decode_window(
            pool.state, loop)
        pool.state = state
        lanes.carry_back(loop)
        n = int(n)                                  # the one host sync
        toks_np = np.asarray(toks)
        valid_np = np.asarray(valid)
        stats_np = {k: (np.asarray(stats[k]) if k in stats
                        else np.zeros(toks_np.shape, np.float32))
                    for k in _STAT_KEYS}
        dt = time.perf_counter() - ts
        em.host_syncs += 1
        pulled = (4 + toks_np.nbytes + valid_np.nbytes
                  + sum(v.nbytes for v in stats_np.values()))
        em.sync_bytes_to_host += pulled
        self._trace.complete(SPAN_DECODE_WINDOW, ts_rel, dt,
                             args={"steps": n, "bytes_to_host": pulled})
        per_dt = dt / max(n, 1)
        if toks_np.ndim == 3:
            # speculative blocks (n, S, B): iteration j committed, per slot,
            # the rows r with valid[j, r, slot] — an accept-longest prefix,
            # so row 0's live set is the iteration's live set. Each row is
            # applied as one logical decode step (per-token bookkeeping is
            # row-exact); timestamps subdivide the iteration's wall share.
            dl = toks_np.shape[1] - 1
            for j in range(n):
                rows = []
                for r in range(dl + 1):
                    live = [s for s in np.nonzero(valid_np[j, r])[0]]
                    if live:
                        rows.append((r, live))
                if not rows:
                    continue
                base = rows[0][1]
                committed = sum(len(live) for _, live in rows)
                em.spec_verify_steps += 1
                em.spec_slot_steps += len(base)
                em.spec_proposed_tokens += dl * len(base)
                em.spec_accepted_tokens += committed - len(base)
                em.spec_committed_tokens += committed
                ts_j = ts_rel + j * per_dt
                if self._obs.enabled:
                    em.observe_spec_step(committed / len(base))
                self._trace.complete(
                    SPAN_SPEC_VERIFY, ts_j, per_dt,
                    args={"live_slots": len(base),
                          "proposed": dl * len(base),
                          "accepted": committed - len(base),
                          "committed": committed})
                # rejected rows' recall traffic was streamed for a
                # continuation that never commits: dropped in flight (the
                # rollback recall re-stages from the last committed row)
                if flight is not None and dl:
                    rej = float(sum(
                        stats_np[k][j, r, s]
                        for k in ("async_pages", "sync_pages")
                        for r in range(1, dl + 1)
                        for s in base if not valid_np[j, r, s]))
                    if rej:
                        flight.drop(rej)
                sub = per_dt / len(rows)
                for i, (r, live) in enumerate(rows):
                    apply_step({k: stats_np[k][j, r] for k in _STAT_KEYS},
                               toks_np[j, r], live, sub, ts=ts_j + i * sub,
                               interpolated=True)
            return
        for j in range(n):
            live = [s for s in np.nonzero(valid_np[j])[0]]
            apply_step({k: stats_np[k][j] for k in _STAT_KEYS},
                       toks_np[j], live, per_dt, ts=ts_rel + j * per_dt,
                       interpolated=True)

    def _sync_step(self, backend, pool, em, lanes, apply_step):
        """Synchronous reference mode: one decode step, one host sync —
        tokens sampled outside the jitted step, stats pulled every step."""
        loop = lanes.device_loop(False, em)
        ts = time.perf_counter()
        ts_rel = ts - self._t0
        logits, state, stats = backend.step(pool.state, loop["cur"][:, None])
        toks = backend.sample_lanes(logits, loop["key"], loop["count"])
        toks_np = np.asarray(toks)
        stats_np = {k: (np.asarray(stats[k]) if k in stats
                        else np.zeros(pool.num_slots)) for k in _STAT_KEYS}
        dt = time.perf_counter() - ts
        pool.state = state
        em.host_syncs += 1
        em.nonsync_host_bytes += 0.0     # the sync IS the step boundary
        em.sync_bytes_to_host += toks_np.nbytes + sum(
            v.nbytes for v in stats_np.values())
        # lanes (cur/count) change every step on this path: mark dirty so
        # the next step re-uploads them — the per-step round trip the
        # host-sync-free loop exists to remove
        lanes.dirty = True
        apply_step(stats_np, toks_np, [s for s in np.nonzero(~lanes.fin)[0]],
                   dt, ts=ts_rel)
