"""Continuous-batching scheduler: admission queue + per-slot request lifecycle.

Requests move QUEUED -> PREFILL -> DECODE -> DONE. Slots are refilled at every
step boundary, so a short request's completion immediately frees capacity for
the next queued request instead of idling until the longest co-scheduled
request drains (the static chunked engine's behavior). Finished slots stop
being stepped the moment they drain: the slot is reset and refilled, and no
finished row ever contributes to the aggregated retrieval statistics.

The scheduler is backend-agnostic: it drives any object exposing

    prefill_one(request) -> (logits (1, V), B=1 decode state, prefix_hit_tokens,
                             padded_prompt_tokens)
    step(state, tokens (B, 1)) -> (logits (B, V), state, stats)
    sample(logits, key) -> tokens (B,)
    make_slot_pool(num_slots) -> kv_slots.SlotPool
    page_block_bytes -> int

(``ServeEngine`` is the production backend; tests inject lightweight fakes.)
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core.recall_pipeline import RecallFlightTracker
from repro.serving.metrics import EngineMetrics, RequestMetrics

# request lifecycle states
QUEUED, PREFILL, DECODE, DONE = "queued", "prefill", "decode", "done"

_STAT_KEYS = ("corrected", "kv_heads", "sync_pages", "async_pages",
              "reused_pages", "sim_sum", "sim_cnt")


@dataclass
class _Tracked:
    req: object                       # engine.Request (duck-typed)
    order: int                        # position in the submitted batch
    metrics: RequestMetrics
    state: str = QUEUED
    slot: int = -1
    tokens: List[int] = field(default_factory=list)
    prefill_s: float = 0.0
    decode_s: float = 0.0
    agg: Dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in _STAT_KEYS})

    def finished(self) -> bool:
        if len(self.tokens) >= self.req.max_new_tokens:
            return True
        eos = getattr(self.req, "eos_token", None)
        return bool(self.tokens) and eos is not None and self.tokens[-1] == eos


def _request_stats(agg: Dict[str, float]) -> dict:
    stats = dict(agg)
    if agg["kv_heads"] > 0:
        stats["correction_rate"] = agg["corrected"] / agg["kv_heads"]
        stats["mean_similarity"] = (agg["sim_sum"] / agg["sim_cnt"]
                                    if agg["sim_cnt"] else 0.0)
    return stats


class ContinuousScheduler:
    """Drives one run of requests to completion over a fixed slot pool."""

    def __init__(self, backend, pool):
        self.backend = backend
        self.pool = pool

    def run(self, requests, seed: int = 0):
        """Returns (tracked records in submission order, EngineMetrics)."""
        backend, pool = self.backend, self.pool
        t0 = time.perf_counter()
        now = lambda: time.perf_counter() - t0  # noqa: E731

        queue: deque = deque()
        for i, r in enumerate(requests):
            rm = RequestMetrics(uid=r.uid, prompt_tokens=len(r.tokens),
                                max_new_tokens=r.max_new_tokens,
                                enqueue_t=now())
            queue.append(_Tracked(req=r, order=i, metrics=rm))

        em = EngineMetrics(num_slots=pool.num_slots, scheduler="continuous",
                           page_block_bytes=backend.page_block_bytes,
                           tp=getattr(backend, "tp", 1))
        # per-slot in-flight staged recall: the double buffer a slot carries
        # out of step t is consumed by step t+1 unless the slot turns over
        flight = getattr(backend, "recall_tracker", None) \
            or RecallFlightTracker()
        active: Dict[int, _Tracked] = {}
        cur = np.zeros((pool.num_slots,), np.int32)
        key = jax.random.PRNGKey(seed)
        done: List[_Tracked] = []
        step_idx = 0

        def finish(tr: _Tracked, slot: Optional[int]):
            tr.state = DONE
            tr.metrics.finish_t = now()
            tr.metrics.finish_step = step_idx
            tr.metrics.new_tokens = len(tr.tokens)
            tr.metrics.prefill_s = tr.prefill_s
            tr.metrics.decode_s = tr.decode_s
            done.append(tr)
            if slot is not None:
                flight.invalidate(slot)   # staged buffer abandoned in flight
                pool.free(slot)

        while queue or active:
            # -- admission: refill freed slots at the step boundary --------
            while queue and pool.free_count:
                tr = queue.popleft()
                if tr.req.max_new_tokens <= 0:
                    finish(tr, None)
                    continue
                tr.state = PREFILL
                tr.metrics.prefill_start_t = now()
                slot = pool.alloc(tr.req.uid)
                tp = time.perf_counter()
                logits1, state1, hit, padded = backend.prefill_one(tr.req)
                pool.insert(state1, slot)
                pkey = jax.random.fold_in(
                    jax.random.fold_in(key, 0x5EED), tr.req.uid)
                tok = int(np.asarray(backend.sample(logits1, pkey))[0])
                tr.prefill_s = time.perf_counter() - tp
                tr.metrics.first_token_t = now()
                tr.metrics.prefix_hit_tokens = hit
                tr.metrics.padded_prompt_tokens = padded
                tr.tokens.append(tok)
                tr.state = DECODE
                tr.slot = slot
                if tr.finished():           # max_new_tokens == 1 or instant EOS
                    finish(tr, slot)
                else:
                    active[slot] = tr
                    cur[slot] = tok
            if not active:
                continue

            # -- one decode step over the full slot batch ------------------
            pool.flush_resets()          # lazily reset freed-but-idle slots
            ts = time.perf_counter()
            logits, new_state, stats = backend.step(pool.state, cur[:, None])
            key = jax.random.fold_in(key, step_idx)
            toks = np.asarray(backend.sample(logits, key))
            stats_np = {k: (np.asarray(stats[k]) if k in stats
                            else np.zeros(pool.num_slots)) for k in _STAT_KEYS}
            dt = time.perf_counter() - ts
            pool.state = new_state
            em.record_step(len(active))
            em.sync_pages += float(
                sum(stats_np["sync_pages"][s] for s in active))
            em.async_pages += float(
                sum(stats_np["async_pages"][s] for s in active))
            em.reused_pages += float(
                sum(stats_np["reused_pages"][s] for s in active))
            for s in active:
                flight.note_step(s, float(stats_np["async_pages"][s]),
                                 float(stats_np["sync_pages"][s]),
                                 float(stats_np["reused_pages"][s]))

            for slot, tr in list(active.items()):
                tr.decode_s += dt
                for k in _STAT_KEYS:
                    tr.agg[k] += float(stats_np[k][slot])
                tok = int(toks[slot])
                tr.tokens.append(tok)
                cur[slot] = tok
                if tr.finished():
                    del active[slot]
                    finish(tr, slot)
            step_idx += 1

        em.wall_s = now()
        em.dropped_pages = flight.dropped_pages
        done.sort(key=lambda tr: tr.order)
        em.requests = [tr.metrics for tr in done]
        return done, em
