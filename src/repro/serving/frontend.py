"""Async streaming front-end over ``ServeEngine`` (docs/serving.md).

Two layers, both dependency-free (stdlib only):

* :class:`EngineService` — the thread-safe mailbox between request
  producers and the scheduler. The engine's continuous scheduler runs
  ``ServeEngine.serve_service(service)`` on a dedicated worker thread;
  each host round it drains ``poll()`` (new admissions) and
  ``drain_cancels()`` (client disconnects) and pushes per-token /
  terminal events back through the subscriber callback registered at
  ``submit()`` time. Tokens keep the engine's per-request PRNG streams
  (``fold_in(fold_in(key, uid), i)``), so a request's output is
  bit-identical whether it arrives through the service or a direct
  ``engine.generate`` batch — the property the open-loop harness gates
  (``frontend_bit_identical``).
* :class:`HttpFrontend` — a minimal asyncio HTTP/1.1 server (no aiohttp;
  CI only ships jax + numpy) exposing

  - ``POST /generate`` — admit a request; ``"stream": true`` returns a
    chunked NDJSON event stream (``start`` -> ``token``* -> ``done``)
    with per-token server timestamps, otherwise one JSON document at
    completion. Client disconnect mid-stream cancels the request: the
    scheduler frees the slot (and in-flight staged recall) at the next
    host boundary and records a CANCELLED terminal state.
  - ``GET /metrics`` — Prometheus text exposition of the live run
    registry (``EngineMetrics.registry``).
  - ``GET /stats`` — JSON: the schema-versioned sliding-window
    time-series snapshot (``repro.obs.timeseries``) plus engine info.
  - ``GET /healthz`` — liveness (always 200 while the loop runs).

Blocking client helpers (:func:`http_generate`, :func:`http_get_json`)
ride ``http.client`` so tests and ``benchmarks/openloop_load.py`` can
drive the server from plain threads.
"""
from __future__ import annotations

import asyncio
import json
import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np

# event kinds delivered to ``submit(on_event=...)`` subscribers
EV_TOKEN = "token"
EV_FINISH = "finish"
EV_ERROR = "error"


class EngineService:
    """Thread-safe request mailbox driving ``ServeEngine.serve_service``.

    Producer side (any thread): ``submit`` / ``cancel`` / ``close`` /
    ``stop``. Scheduler side (worker thread): ``poll`` / ``drain_cancels``
    / ``wait`` / ``emit_token`` / ``emit_finish`` — the ``service``
    protocol of ``ContinuousScheduler.run``. Events reach subscribers on
    the *scheduler* thread; callbacks must be cheap and thread-safe
    (the HTTP layer bridges them into asyncio via
    ``loop.call_soon_threadsafe``).
    """

    def __init__(self, engine, seed: int = 0):
        self.engine = engine
        self.seed = seed
        self._cv = threading.Condition()
        self._inbox: List[object] = []
        self._cancels: List[int] = []
        self._subs: Dict[int, Callable] = {}
        self._closed = False
        self._next_uid = 0
        self._used_uids: set = set()
        self.em = None                  # live EngineMetrics once attached
        self.t0: Optional[float] = None
        self._thread: Optional[threading.Thread] = None
        self._result = None
        self._error: Optional[BaseException] = None
        self.started_at = time.time()

    # -- lifecycle ------------------------------------------------------
    def start(self) -> "EngineService":
        assert self._thread is None, "service already started"
        self._thread = threading.Thread(
            target=self._run, name="engine-service", daemon=True)
        self._thread.start()
        return self

    def _run(self):
        try:
            self._result = self.engine.serve_service(self, seed=self.seed)
        except BaseException as e:       # deliver failure to waiting clients
            self._error = e
            with self._cv:
                subs = dict(self._subs)
                self._subs.clear()
            for uid, cb in subs.items():
                try:
                    cb(EV_ERROR, {"uid": uid, "error": repr(e)})
                except Exception:
                    pass

    def close(self) -> None:
        """No further submissions; the scheduler drains what is queued."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    def stop(self):
        """Close, drain, join the worker; returns all completions (in
        admission order, cancelled partials included)."""
        self.close()
        if self._thread is not None:
            self._thread.join()
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    # -- producer side --------------------------------------------------
    def submit(self, tokens, max_new_tokens: int,
               on_event: Callable[[str, dict], None], *,
               uid: Optional[int] = None, priority: int = 0,
               eos_token: Optional[int] = None,
               slo_ttft_ms: Optional[float] = None,
               slo_itl_ms: Optional[float] = None) -> int:
        """Admit one request; returns its uid. ``on_event(kind, payload)``
        fires on the scheduler thread for every token and at the terminal
        state. Explicit ``uid`` supports bit-identity comparisons against
        direct ``engine.generate`` runs (the PRNG stream is keyed on it)."""
        from repro.serving.engine import Request
        tokens = np.asarray(tokens, np.int32)
        max_new_tokens = int(max_new_tokens)
        if max_new_tokens < 0:
            raise ValueError("max_new_tokens must be >= 0")
        max_len = getattr(self.engine, "max_len", None)
        if max_len is not None:
            pad = getattr(self.engine, "_pad_prompt", None)
            plen = len(pad(tokens)) if pad is not None else len(tokens)
            if plen + max_new_tokens > max_len:
                raise ValueError(
                    f"padded prompt {plen} + {max_new_tokens} new tokens "
                    f"exceeds engine max_len {max_len}")
        with self._cv:
            if self._closed:
                raise RuntimeError("service closed to new submissions")
            if uid is None:
                while self._next_uid in self._used_uids:
                    self._next_uid += 1
                uid = self._next_uid
                self._next_uid += 1
            elif uid in self._used_uids:
                raise ValueError(f"duplicate uid {uid}")
            self._used_uids.add(uid)
            self._subs[uid] = on_event
            self._inbox.append(Request(
                uid=uid, tokens=tokens, max_new_tokens=max_new_tokens,
                eos_token=eos_token, priority=priority,
                slo_ttft_ms=slo_ttft_ms, slo_itl_ms=slo_itl_ms))
            self._cv.notify_all()
        return uid

    def cancel(self, uid: int) -> None:
        """Request cancellation (idempotent; unknown uids are ignored by
        the scheduler's cancel pass)."""
        with self._cv:
            self._cancels.append(int(uid))
            self._cv.notify_all()

    # -- scheduler side (ContinuousScheduler service protocol) ----------
    def attach(self, em, t0: float) -> None:
        self.em = em
        self.t0 = t0

    def poll(self) -> List[object]:
        with self._cv:
            out, self._inbox = self._inbox, []
        return out

    def drain_cancels(self) -> List[int]:
        with self._cv:
            out, self._cancels = self._cancels, []
        return out

    def wait(self, timeout: float) -> None:
        with self._cv:
            if not (self._inbox or self._cancels or self._closed):
                self._cv.wait(timeout)

    @property
    def closed(self) -> bool:
        """True once no new work can ever arrive: closed AND drained."""
        with self._cv:
            return self._closed and not self._inbox and not self._cancels

    @property
    def pending(self) -> bool:
        """Work waiting in the mailbox (lets a decode window stop at the
        next slot turnover instead of running the full sync interval)."""
        with self._cv:
            return bool(self._inbox or self._cancels)

    def emit_token(self, uid: int, index: int, token: int,
                   t_rel: float, interpolated: bool = False) -> None:
        """``interpolated`` marks a timestamp the scheduler subdivided out
        of one host-visible dispatch (sync-free windows, and the multiple
        tokens a speculative verify step commits at once) rather than
        measured per token — latency consumers can weight accordingly."""
        cb = self._subs.get(uid)
        if cb is None:
            return
        try:
            cb(EV_TOKEN, {"uid": uid, "index": index, "token": token,
                          "t": t_rel, "interpolated": bool(interpolated)})
        except Exception:               # subscriber bugs never kill decode
            pass

    def emit_finish(self, uid: int, tr) -> None:
        cb = self._subs.pop(uid, None)
        if cb is None:
            return
        rm = tr.metrics
        rec = {
            "uid": uid,
            "state": tr.state,
            "cancelled": bool(rm.cancelled),
            "tokens": [int(t) for t in tr.tokens],
            "new_tokens": len(tr.tokens),
            "ttft_s": rm.ttft_s,
            "queue_wait_s": rm.queue_wait_s,
            "finish_t": rm.finish_t,
        }
        try:
            cb(EV_FINISH, rec)
        except Exception:
            pass


# ----------------------------------------------------------------------
# asyncio HTTP front-end (stdlib only)
# ----------------------------------------------------------------------
_MAX_BODY = 8 << 20


def _resp(status: str, body: bytes, ctype: str = "application/json") -> bytes:
    return (f"HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\n"
            f"Content-Length: {len(body)}\r\nConnection: close\r\n"
            f"\r\n").encode() + body


def _json_resp(status: str, obj) -> bytes:
    return _resp(status, (json.dumps(obj) + "\n").encode())


class HttpFrontend:
    """Minimal asyncio HTTP/1.1 server over an :class:`EngineService`."""

    def __init__(self, service: EngineService, host: str = "127.0.0.1",
                 port: int = 0):
        self.service = service
        self.host, self.port = host, port
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> None:
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    async def serve_forever(self) -> None:
        assert self._server is not None
        async with self._server:
            await self._server.serve_forever()

    # -- request plumbing ----------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            line = await reader.readline()
            parts = line.decode("latin-1").split()
            if len(parts) < 2:
                return
            method, path = parts[0].upper(), parts[1]
            headers = {}
            while True:
                h = await reader.readline()
                if h in (b"\r\n", b"\n", b""):
                    break
                k, _, v = h.decode("latin-1").partition(":")
                headers[k.strip().lower()] = v.strip()
            body = b""
            n = int(headers.get("content-length", 0) or 0)
            if 0 < n <= _MAX_BODY:
                body = await reader.readexactly(n)
            await self._route(method, path, body, reader, writer)
        except (asyncio.IncompleteReadError, ConnectionError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass

    async def _route(self, method, path, body, reader, writer):
        if method == "GET" and path == "/healthz":
            writer.write(_json_resp("200 OK", {
                "ok": True, "engine_running": self.service.running,
                "uptime_s": time.time() - self.service.started_at}))
            await writer.drain()
        elif method == "GET" and path == "/metrics":
            em = self.service.em
            text = em.registry.to_prometheus() if em is not None else "\n"
            writer.write(_resp("200 OK", text.encode(),
                               "text/plain; version=0.0.4"))
            await writer.drain()
        elif method == "GET" and path == "/stats":
            writer.write(_json_resp("200 OK", self._stats()))
            await writer.drain()
        elif method == "POST" and path == "/generate":
            await self._generate(body, reader, writer)
        else:
            writer.write(_json_resp("404 Not Found",
                                    {"error": f"no route {method} {path}"}))
            await writer.drain()

    def _stats(self) -> dict:
        svc = self.service
        board = getattr(getattr(svc.engine, "obs", None), "timeseries", None)
        em = svc.em
        extra = {}
        if em is not None:
            extra = {
                "completed": em.registry.counter(
                    "requests_completed_total").value,
                "cancelled": em.cancellations,
                "generated_tokens": em.registry.counter(
                    "request_tokens_generated_total").value,
                "slo": em.slo_summary(),
            }
        if board is not None:
            snap = board.snapshot(extra=extra)
        else:
            snap = {"schema_version": 0, "stats": {}, "rates": {},
                    "extra": extra}
        snap["engine_running"] = svc.running
        return snap

    async def _generate(self, body, reader, writer):
        svc = self.service
        try:
            req = json.loads(body.decode() or "{}")
            tokens = req["tokens"]
            if not isinstance(tokens, list) or not tokens:
                raise ValueError("tokens must be a non-empty list")
        except (ValueError, KeyError) as e:
            writer.write(_json_resp("400 Bad Request", {"error": str(e)}))
            await writer.drain()
            return
        stream = bool(req.get("stream", True))
        loop = asyncio.get_running_loop()
        q: asyncio.Queue = asyncio.Queue()

        def on_event(kind, payload):
            loop.call_soon_threadsafe(q.put_nowait, (kind, payload))

        try:
            uid = svc.submit(
                tokens, int(req.get("max_new_tokens", 32)), on_event,
                uid=req.get("uid"), priority=int(req.get("priority", 0)),
                eos_token=req.get("eos_token"),
                slo_ttft_ms=req.get("slo_ttft_ms"),
                slo_itl_ms=req.get("slo_itl_ms"))
        except (ValueError, RuntimeError) as e:
            writer.write(_json_resp("400 Bad Request", {"error": str(e)}))
            await writer.drain()
            return

        if not stream:
            await self._await_completion(uid, q, writer)
            return
        await self._stream(uid, q, reader, writer)

    async def _await_completion(self, uid, q, writer):
        tokens = []
        while True:
            kind, payload = await q.get()
            if kind == EV_TOKEN:
                tokens.append(payload["token"])
            elif kind == EV_FINISH:
                writer.write(_json_resp("200 OK", payload))
                await writer.drain()
                return
            else:
                writer.write(_json_resp("500 Internal Server Error",
                                        payload))
                await writer.drain()
                return

    async def _stream(self, uid, q, reader, writer):
        """Chunked NDJSON event stream; client EOF cancels the request.

        The pending-read watcher is the disconnect detector: an HTTP
        client that goes away closes its socket, our read returns EOF,
        and the uid goes onto the scheduler's cancel queue — the slot
        (and any staged recall in flight) is released at the next host
        boundary."""
        svc = self.service
        writer.write(
            b"HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\n"
            b"Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n")
        await writer.drain()

        def chunk(obj) -> bytes:
            data = (json.dumps(obj) + "\n").encode()
            return f"{len(data):x}\r\n".encode() + data + b"\r\n"

        eof_watch = asyncio.ensure_future(reader.read(1))
        try:
            writer.write(chunk({"event": "start", "uid": uid,
                                "t_server": time.time()}))
            await writer.drain()
            while True:
                get = asyncio.ensure_future(q.get())
                await asyncio.wait({get, eof_watch},
                                   return_when=asyncio.FIRST_COMPLETED)
                if eof_watch.done() and not get.done():
                    get.cancel()
                    svc.cancel(uid)
                    # drain until the scheduler confirms the terminal state
                    while True:
                        kind, payload = await q.get()
                        if kind != EV_TOKEN:
                            break
                    return
                kind, payload = await get
                if kind == EV_TOKEN:
                    writer.write(chunk({"event": "token", **payload,
                                        "t_server": time.time()}))
                    await writer.drain()
                elif kind == EV_FINISH:
                    writer.write(chunk({"event": "done", **payload}))
                    writer.write(b"0\r\n\r\n")
                    await writer.drain()
                    return
                else:
                    writer.write(chunk({"event": "error", **payload}))
                    writer.write(b"0\r\n\r\n")
                    await writer.drain()
                    return
        except (ConnectionError, RuntimeError):
            svc.cancel(uid)
        finally:
            if not eof_watch.done():
                eof_watch.cancel()


def run_http_frontend(service: EngineService, host: str = "127.0.0.1",
                      port: int = 0, ready: Optional[threading.Event] = None,
                      stop: Optional[threading.Event] = None,
                      frontend: Optional[HttpFrontend] = None) -> HttpFrontend:
    """Run the HTTP front-end's event loop on the CALLING thread until
    ``stop`` is set (or forever). Tests and the open-loop harness run this
    on a helper thread; ``launch/serve.py --serve-http`` runs it on main.
    The bound port lands in ``frontend.port`` before ``ready`` is set."""
    fe = frontend if frontend is not None else HttpFrontend(service, host,
                                                            port)

    async def main():
        await fe.start()
        if ready is not None:
            ready.set()
        if stop is None:
            await fe._server.serve_forever()
        else:
            while not stop.is_set():
                await asyncio.sleep(0.01)
        await fe.stop()

    try:
        asyncio.run(main())
    except KeyboardInterrupt:           # pragma: no cover - interactive
        pass
    return fe


def serve_http_background(service: EngineService, host: str = "127.0.0.1",
                          port: int = 0):
    """Spawn the HTTP front-end on a daemon thread; returns
    ``(frontend, stop_event, thread)`` once the port is bound (tests and
    ``benchmarks/openloop_load.py`` use this; set ``stop_event`` and join
    the thread to shut down)."""
    fe = HttpFrontend(service, host, port)
    ready, stop = threading.Event(), threading.Event()
    th = threading.Thread(
        target=run_http_frontend, args=(service, host, port),
        kwargs={"ready": ready, "stop": stop, "frontend": fe},
        name="http-frontend", daemon=True)
    th.start()
    if not ready.wait(30.0):            # pragma: no cover - startup hang
        raise RuntimeError("HTTP front-end failed to bind")
    return fe, stop, th


# ----------------------------------------------------------------------
# blocking client helpers (http.client; used by tests + benchmarks)
# ----------------------------------------------------------------------
def http_get_json(host: str, port: int, path: str, timeout: float = 30.0):
    import http.client
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        return resp.status, json.loads(resp.read().decode())
    finally:
        conn.close()


def http_get_text(host: str, port: int, path: str, timeout: float = 30.0):
    import http.client
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        return resp.status, resp.read().decode()
    finally:
        conn.close()


def http_generate(host: str, port: int, payload: dict,
                  timeout: float = 300.0):
    """POST /generate with ``stream=true``; yields decoded NDJSON events
    as they arrive (http.client de-chunks transparently)."""
    import http.client
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        body = json.dumps({**payload, "stream": True})
        conn.request("POST", "/generate", body=body,
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        if resp.status != 200:
            raise RuntimeError(
                f"/generate -> {resp.status}: {resp.read().decode()}")
        while True:
            line = resp.readline()
            if not line:
                return
            line = line.strip()
            if line:
                yield json.loads(line.decode())
    finally:
        conn.close()
