"""Serving engine: batched prefill + decode with any retrieval method.

Continuous-batching-lite: a fixed number of batch slots; finished requests free
their slot and queued requests take it at the next prefill boundary (per-slot
state reset is a functional update). Per-step wall-clock and retrieval
statistics feed the latency benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, FreeKVConfig
from repro.models.model import prefill, serve_step
from repro.serving.sampling import SamplerConfig, sample


@dataclass
class Request:
    uid: int
    tokens: np.ndarray                 # prompt (T,)
    max_new_tokens: int = 32
    frontend: Optional[np.ndarray] = None


@dataclass
class Completion:
    uid: int
    tokens: List[int]
    prefill_s: float
    decode_s: float
    steps: int
    stats: dict


class ServeEngine:
    def __init__(self, cfg: ArchConfig, fkv: FreeKVConfig, params,
                 max_len: int, batch_size: int,
                 sampler: SamplerConfig = SamplerConfig(),
                 state_dtype=jnp.float32, mesh=None):
        self.cfg, self.fkv, self.params = cfg, fkv, params
        self.max_len, self.batch_size = max_len, batch_size
        self.sampler = sampler
        self._prefill = jax.jit(
            lambda p, b: prefill(cfg, fkv, p, b, max_len=max_len,
                                 state_dtype=state_dtype, mesh=mesh))
        self._step = jax.jit(
            lambda p, s, t: serve_step(cfg, fkv, p, s, t, mesh=mesh,
                                       collect_stats=True))

    # -- batched generation --------------------------------------------
    def generate(self, requests: List[Request], seed: int = 0) -> List[Completion]:
        out: List[Completion] = []
        for i in range(0, len(requests), self.batch_size):
            out.extend(self._generate_batch(requests[i: i + self.batch_size],
                                            seed + i))
        return out

    def _generate_batch(self, reqs: List[Request], seed: int) -> List[Completion]:
        cfg = self.cfg
        B = len(reqs)
        T = max(len(r.tokens) for r in reqs)
        toks = np.zeros((B, T), np.int32)
        for i, r in enumerate(reqs):            # left-pad to align last token
            toks[i, T - len(r.tokens):] = r.tokens
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.frontend is not None:
            fe = np.stack([
                r.frontend if r.frontend is not None
                else np.zeros((cfg.n_frontend_tokens, cfg.d_model), np.float32)
                for r in reqs])
            batch["frontend"] = jnp.asarray(fe)

        t0 = time.perf_counter()
        logits, state = self._prefill(self.params, batch)
        logits.block_until_ready()
        prefill_s = time.perf_counter() - t0

        key = jax.random.PRNGKey(seed)
        max_new = max(r.max_new_tokens for r in reqs)
        gen = [[] for _ in reqs]
        agg = {"corrected": 0.0, "kv_heads": 0.0, "sync_pages": 0.0,
               "async_pages": 0.0, "sim_sum": 0.0, "sim_cnt": 0.0}
        t0 = time.perf_counter()
        cur = sample(logits, self.sampler, key)
        steps = 0
        for step in range(max_new):
            for i, r in enumerate(reqs):
                if step < r.max_new_tokens:
                    gen[i].append(int(cur[i]))
            logits, state, stats = self._step(self.params, state, cur[:, None])
            steps += 1
            for k in agg:
                agg[k] += float(np.sum(np.asarray(stats[k])))
            key = jax.random.fold_in(key, step)
            cur = sample(logits, self.sampler, key)
        jax.block_until_ready(logits)
        decode_s = time.perf_counter() - t0

        stats = dict(agg)
        if agg["kv_heads"] > 0:
            stats["correction_rate"] = agg["corrected"] / agg["kv_heads"]
            stats["mean_similarity"] = (agg["sim_sum"] / agg["sim_cnt"]
                                        if agg["sim_cnt"] else 0.0)
        return [Completion(uid=r.uid, tokens=gen[i], prefill_s=prefill_s,
                           decode_s=decode_s, steps=steps, stats=stats)
                for i, r in enumerate(reqs)]
