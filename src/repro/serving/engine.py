"""Serving engine: batched prefill + decode with any retrieval method.

Decode dispatch is host-sync-free by default: sampling is fused into the
jitted step (on-device, per-slot PRNG key streams), the decode state and
loop carry are DONATED (the paged KV slot pool updates in place — no
per-step copy), and up to ``FreeKVConfig.sync_interval`` fused steps run
per host round trip (``models.model.decode_window``). Greedy outputs are
bit-identical to the synchronous per-step reference
(``fkv.sample_on_device=False``). See docs/serving.md.

Two schedulers share the jitted model entry points:

* ``scheduler="continuous"`` (default) — the ``serving.scheduler`` /
  ``serving.kv_slots`` subsystem: a fixed pool of physical batch slots, slot
  refill at every step boundary, and an optional radix-trie prefix cache
  (``prefix_cache_tokens > 0``) that skips the transformer forward for a
  previously prefilled shared prompt prefix via ``model.prefill_extend``.
* ``scheduler="static"`` — the original chunked lockstep path, kept as a
  fallback and as the baseline for ``benchmarks/serving_throughput.py``.

Recall transfers ride the overlapped double-buffered pipeline
(``core/recall_pipeline``, on by default via ``FreeKVConfig.recall_overlap``):
each slot carries a staged speculative buffer across continuous-batching
steps, only correction top-ups block the decode step, and the engine-owned
``RecallFlightTracker`` accounts hidden vs exposed transfer per slot —
including buffers abandoned in flight at slot turnover. See
``EngineMetrics.summary()["recall_overlap"]`` and ``docs/architecture.md``.

Prompt lengths can be bucketed (``prefill_bucket``) to bound the number of
compiled prefill shapes under heterogeneous traffic: cold prompts are
left-padded to the bucket (pads become attended context, exactly as the
chunked path treats ragged batches) and the *padded* token sequence keys the
prefix cache — two identically padded prompts dedupe exactly. The default
``prefill_bucket=1`` pads nothing (outputs are unchanged from the chunked
path for equal-length traffic) at the cost of one compile per distinct prompt
length. Cache hits shrink the reused span so the suffix is an exact bucket
multiple — the extension path never pads — but note each distinct
(prefix_len, suffix_len) pair is its own compiled shape.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, FreeKVConfig
from repro.core.recall_pipeline import RecallFlightTracker
from repro.models.model import (DECODE_STAT_KEYS, decode_window,
                                decode_window_spec, prefill, prefill_extend,
                                serve_step, supports_kv_extend,
                                supports_spec_decode)
from repro.obs import Observability
from repro.serving.kv_slots import SlotPool
from repro.serving.metrics import EngineMetrics, RequestMetrics
from repro.serving.prefix_cache import RadixPrefixCache
from repro.serving.sampling import (SamplerConfig, sample, sample_step,
                                    step_keys)
from repro.serving.scheduler import ContinuousScheduler, _request_stats


@dataclass
class Request:
    uid: int
    tokens: np.ndarray                 # prompt (T,)
    max_new_tokens: int = 32
    frontend: Optional[np.ndarray] = None
    eos_token: Optional[int] = None
    # scheduling priority (higher = more urgent). Admission stays FIFO, but
    # with ``fkv.preempt`` a queued request whose priority STRICTLY exceeds
    # the lowest-priority running request's swaps that victim's paged KV out
    # to host and takes its slot; the victim resumes bit-identically later.
    priority: int = 0
    # per-request SLO tags (ms); None falls back to the engine-level default.
    # Tagged completions feed EngineMetrics.summary()["slo"] (attainment +
    # goodput); tags never influence scheduling decisions.
    slo_ttft_ms: Optional[float] = None
    slo_itl_ms: Optional[float] = None
    # optional reference stream for the speculative drafter (prompt-lookup
    # style: a retrieved document, an earlier draft of the answer, ...).
    # Its bigrams overlay the prompt-seeded table at admission. Hints steer
    # ONLY the proposer — verification guarantees outputs are bit-identical
    # with any hint, a wrong hint just lowers the accept rate.
    draft_hint: Optional[np.ndarray] = None


@dataclass
class Completion:
    uid: int
    tokens: List[int]
    prefill_s: float
    decode_s: float
    steps: int
    stats: dict
    metrics: Optional[RequestMetrics] = None


class PrefillJob:
    """Incremental chunked prefill of one admitted request.

    The prompt is consumed in chunks: the opening chunk runs the ordinary
    prefill forward (capturing its post-RoPE K/V), every later chunk runs
    ``model.prefill_extend`` over the K/V accumulated so far — exactly the
    prefix-cache extension math, so each chunk's attention equals the same
    span of a whole-shot prefill bit-for-bit. Intermediate chunks skip the
    paged-state rebuild (``build_state=False``: their states would be
    discarded at the next chunk); only the FINAL chunk builds the decode
    state, from the full concatenated K/V — the identical construction the
    whole-shot path uses — so the state spliced into the slot pool and the
    first-token logits are bit-identical to un-chunked prefill.

    A prefix-cache hit seeds the accumulated K/V with the cached span
    (shrunk so the remaining suffix is an exact bucket multiple, as in
    ``prefill_one``); on completion the full prompt's K/V is inserted back
    into the trie. The scheduler owns the pacing: it calls ``advance`` with
    its per-window token budget, interleaving chunks with decode windows so
    co-batched decoders stall at most one chunk's compute.

    Note each distinct (prefix_len, suffix_len) pair is its own compiled
    extension shape — steady chunk budgets keep the shape set small.
    """

    def __init__(self, engine: "ServeEngine", req: Request):
        self.engine, self.req = engine, req
        padded = engine._pad_prompt(np.asarray(req.tokens, np.int32))
        assert len(padded) + req.max_new_tokens <= engine.max_len, (
            f"request {req.uid}: padded prompt {len(padded)} + "
            f"{req.max_new_tokens} new tokens exceeds max_len {engine.max_len}")
        self.seq = tuple(int(t) for t in padded)
        self.pos = 0                    # prompt tokens prefilled so far
        self.hit = 0                    # of which served by the prefix cache
        self.chunks = 0
        self._flat: Optional[List[np.ndarray]] = None  # accumulated K/V
        self.result = None  # (logits (1,V), B=1 state, hit, padded) when done
        cache = engine.prefix_cache
        if cache is not None:
            matched, payload = cache.match(self.seq)
            b = engine.prefill_bucket
            suffix = max(b, -(-(len(self.seq) - matched) // b) * b)
            tp = len(self.seq) - suffix
            if tp >= max(b, engine.fkv.page_size):   # at least one page reused
                self._flat = [np.asarray(a[:tp]) for a in payload]
                self.pos = self.hit = tp

    @property
    def remaining(self) -> int:
        return len(self.seq) - self.pos

    @property
    def done(self) -> bool:
        return self.result is not None

    def advance(self, budget: int) -> int:
        """Run ONE chunk of at most ``budget`` prompt tokens; returns the
        tokens consumed. The final chunk sets ``result`` to the same tuple
        ``prefill_one`` returns."""
        assert not self.done and budget > 0
        eng = self.engine
        n = min(int(budget), self.remaining)
        last = n == self.remaining
        if self.pos == 0:
            chunk = np.asarray(self.seq[:n], np.int32)
            fn = eng._prefill_kv if last else eng._prefill_kv_nostate
            logits, state, kv = fn(
                eng.params, {"tokens": jnp.asarray(chunk[None])})
            self._flat = eng._kv_tree_to_flat(kv)
        else:
            ptree = eng._flat_to_prefix_tree(self._flat)
            suf = np.asarray(self.seq[self.pos: self.pos + n], np.int32)
            fn = eng._extend if last else eng._extend_nostate
            logits, state, suf_kv = fn(eng.params,
                                       {"tokens": jnp.asarray(suf[None])},
                                       ptree)
            self._flat = [np.concatenate([p, s], axis=0) for p, s in
                          zip(self._flat, eng._kv_tree_to_flat(suf_kv))]
        self.pos += n
        self.chunks += 1
        if last:
            if eng.prefix_cache is not None:
                eng.prefix_cache.insert(self.seq, self._flat)
            self._flat = None
            self.result = (logits, eng._attach_draft_tab(
                state, self.seq, getattr(self.req, "draft_hint", None)),
                self.hit, len(self.seq))
        return n


class ServeEngine:
    def __init__(self, cfg: ArchConfig, fkv: FreeKVConfig, params,
                 max_len: int, batch_size: int,
                 sampler: SamplerConfig = SamplerConfig(),
                 state_dtype=jnp.float32, mesh=None,
                 scheduler: str = "continuous",
                 prefill_bucket: int = 1,
                 prefix_cache_tokens: int = 0,
                 pad_token: int = 0,
                 tp: int = 1,
                 obs: Optional[Observability] = None,
                 slo_ttft_ms: Optional[float] = None,
                 slo_itl_ms: Optional[float] = None):
        assert scheduler in ("continuous", "static"), scheduler
        if tp > 1:
            # tensor-parallel serving: KV-head-group sharding over a 1-D
            # ('model',) mesh. Every retrieval-side state leaf (pool + quant
            # scales, summaries, rings, selection buffers) is sharded per
            # KV-head group and the per-layer retrieval step runs inside a
            # shard_map; backbone compute stays replicated, so greedy
            # outputs are bit-identical to tp=1 (docs/serving.md).
            assert mesh is None, "pass either mesh= or tp=, not both"
            assert not fkv.sharded_retrieval, \
                "tp serving and the page-sharded fused step are exclusive"
            assert cfg.n_kv_heads % tp == 0 and cfg.n_heads % tp == 0, (
                f"{cfg.name}: tp={tp} must divide both n_heads="
                f"{cfg.n_heads} and n_kv_heads={cfg.n_kv_heads}")
            from repro.launch.mesh import make_tp_mesh
            mesh = make_tp_mesh(tp)
            fkv = dataclasses.replace(fkv, tp_serving=True)
        # speculative decoding (models.serve_step_spec) rides the continuous
        # scheduler's host-sync-free window; configs it cannot serve exactly
        # (static scheduler, synchronous sampling, non-attention stacks, the
        # page-sharded fused step) silently fall back to draft_len=0 — the
        # fallback is exact by construction, it just commits 1 token/step.
        if fkv.draft_len > 0 and not (
                scheduler == "continuous" and fkv.sample_on_device
                and supports_spec_decode(cfg, fkv)):
            fkv = dataclasses.replace(fkv, draft_len=0)
        self.spec_decode = fkv.draft_len > 0
        self.draft_len = fkv.draft_len
        self.tp = tp
        self.mesh = mesh
        self.cfg, self.fkv, self.params = cfg, fkv, params
        self.max_len, self.batch_size = max_len, batch_size
        self.sampler = sampler
        self.state_dtype = state_dtype
        self.scheduler = scheduler
        self.prefill_bucket = max(1, prefill_bucket)
        self.pad_token = pad_token
        self._prefill = jax.jit(
            lambda p, b: prefill(cfg, fkv, p, b, max_len=max_len,
                                 state_dtype=state_dtype, mesh=mesh))
        self._prefill_kv = jax.jit(
            lambda p, b: prefill(cfg, fkv, p, b, max_len=max_len,
                                 state_dtype=state_dtype, mesh=mesh,
                                 return_kv=True))
        # chunked-prefill opening chunk (more chunks follow): capture the
        # chunk's K/V but skip the paged-state build it would discard
        self._prefill_kv_nostate = jax.jit(
            lambda p, b: prefill(cfg, fkv, p, b, max_len=max_len,
                                 state_dtype=state_dtype, mesh=mesh,
                                 return_kv=True, build_state=False))
        self._extend = jax.jit(
            lambda p, b, pkv: prefill_extend(cfg, fkv, p, b, pkv,
                                             max_len=max_len,
                                             state_dtype=state_dtype,
                                             mesh=mesh))
        # chunked-prefill intermediate chunks: same extension math but no
        # paged-state rebuild (the state would be discarded at the next chunk)
        self._extend_nostate = jax.jit(
            lambda p, b, pkv: prefill_extend(cfg, fkv, p, b, pkv,
                                             max_len=max_len,
                                             state_dtype=state_dtype,
                                             mesh=mesh, build_state=False))
        # the decode state (arg 1) is DONATED: XLA updates the paged KV slot
        # pool, host pool, quant scales, rings and selection buffers in
        # place instead of copying the whole pytree every step. Callers
        # (schedulers) reassign their state reference from the output and
        # never read the consumed one.
        self._step = jax.jit(
            lambda p, s, t: serve_step(cfg, fkv, p, s, t, mesh=mesh,
                                       collect_stats=True),
            donate_argnums=(1,))
        # host-sync-free decode: up to sync_interval fused (step + on-device
        # sample) iterations per dispatch, state AND loop carry donated —
        # zero host round trips and zero state copies inside the window.
        self.sync_interval = max(1, fkv.sync_interval)
        self.sample_on_device = bool(fkv.sample_on_device)
        # speculative mode swaps in the drafted-window variant: same carry,
        # same donation, (k, 1 + draft_len, B) token/valid/stat blocks.
        _win = decode_window_spec if self.spec_decode else decode_window
        self._window = jax.jit(
            lambda p, s, lp: _win(cfg, fkv, p, s, lp,
                                  sampler=sampler,
                                  k_max=self.sync_interval,
                                  mesh=mesh),
            donate_argnums=(1, 2))
        self._can_extend = supports_kv_extend(cfg)
        self.prefix_cache = (RadixPrefixCache(prefix_cache_tokens)
                             if prefix_cache_tokens > 0 and self._can_extend
                             else None)
        self._pool: Optional[SlotPool] = None
        self.last_metrics: Optional[EngineMetrics] = None
        # observability plane (repro.obs): per-step latency/speculation
        # histograms + Perfetto trace spans, recorded by the scheduler at
        # sync boundaries only. Default off — the registry-backed counters
        # in EngineMetrics always run; this gates the extra distributions.
        self.obs = obs if obs is not None else Observability.off()
        # engine-level SLO defaults (ms): requests without their own tags
        # inherit these; None leaves the request untagged (see
        # EngineMetrics.slo_check / summary()["slo"]).
        self.slo_ttft_ms = slo_ttft_ms
        self.slo_itl_ms = slo_itl_ms
        # per-slot in-flight staged recall accounting (core/recall_pipeline);
        # the continuous scheduler feeds it each step and invalidates on
        # slot turnover. Reset per generate() run. Under TP it is fed global
        # (psum'ed) counts and carries the per-shard view.
        self.recall_tracker = RecallFlightTracker(shards=self.tp)

    # ------------------------------------------------------------------
    # scheduler backend protocol
    # ------------------------------------------------------------------
    @property
    def page_block_bytes(self) -> int:
        """Bytes of one (kv-head, page) K+V block — the recall transfer unit.
        Under the quantized host tier this is the *packed* unit (int payload
        + fp32 scales); identical to the dense block when kv_quant='none'."""
        from repro.quant import page_block_bytes
        itemsize = jnp.dtype(self.state_dtype).itemsize
        return page_block_bytes(self.fkv, self.cfg.d_head, itemsize)

    def _apply_quant_metrics(self, em: EngineMetrics):
        """Fill the kv_quant section: dense-equivalent block bytes plus the
        slot pool's physical vs dense host-tier footprint."""
        from repro.quant import page_block_bytes_dense, pool_bytes_detail
        itemsize = jnp.dtype(self.state_dtype).itemsize
        em.kv_quant = self.fkv.kv_quant
        em.dense_block_bytes = page_block_bytes_dense(
            self.fkv, self.cfg.d_head, itemsize)
        em.dequant_elems_per_block = 2 * self.fkv.page_size * self.cfg.d_head
        if self._pool is not None:
            detail = pool_bytes_detail(self._pool.state, self.cfg.d_head,
                                       dense_itemsize=itemsize)
            em.pool_bytes_physical = float(detail["physical"])
            em.pool_bytes_dense = float(detail["dense"])

    @property
    def prefill_chunk_tokens(self) -> int:
        """Per-window chunked-prefill token budget; 0 = whole-shot prefill
        at admission. Forced to 0 for stacks the extension path cannot serve
        (recurrent mixers, encoder-decoder, frontends) — the scheduler then
        keeps the inline whole-shot behavior for every request."""
        return self.fkv.prefill_chunk_tokens if self._can_extend else 0

    @property
    def preempt(self) -> bool:
        """Whether the scheduler may swap lower-priority running requests
        out to host to admit strictly higher-priority queued ones."""
        return self.fkv.preempt

    def start_prefill_job(self, req: Request) -> PrefillJob:
        """Open an incremental prefill for ``req`` (chunked-prefill path)."""
        return PrefillJob(self, req)

    def make_slot_pool(self, num_slots: int) -> SlotPool:
        return SlotPool(self.cfg, self.fkv, num_slots, self.max_len,
                        self.state_dtype,
                        mesh=self.mesh if self.tp > 1 else None)

    def step(self, state, tokens):
        # tokens stay device-resident across decode steps; only a cold
        # (host/numpy) vector is ever uploaded
        if not isinstance(tokens, jax.Array):
            tokens = jnp.asarray(tokens)
        return self._step(self.params, state, tokens)

    def decode_window(self, state, loop):
        """Dispatch up to ``sync_interval`` fused decode steps without any
        host synchronization; ``state`` and ``loop`` are donated."""
        if self.mesh is not None:
            # freshly uploaded lanes land single-device; replicate them over
            # the TP mesh once so donation aliases them thereafter
            from repro.sharding.rules import replicated_put
            loop = replicated_put(self.mesh, loop)
        return self._window(self.params, state, loop)

    def sample(self, logits, key):
        return sample(logits, self.sampler, key)

    def sample_lanes(self, logits, keys, counts):
        """Per-slot sampling on the per-request key streams — the same
        sampler the fused device step runs, executed outside it (the
        synchronous reference path and prefill first tokens)."""
        return sample_step(logits, self.sampler, step_keys(keys, counts))

    def sample_slot(self, logits, req_key, count: int):
        """Sample one request's token ``count`` from B=1 logits."""
        keys = jnp.asarray(req_key)[None]
        return self.sample_lanes(logits, keys,
                                 jnp.full((1,), count, jnp.int32))

    def _attach_draft_tab(self, state, seq, hint=None):
        """Seed the B=1 state's bigram drafter table from the (padded)
        prompt before it is spliced into a slot. Host-side and cheap — one
        (1, vocab) scatter per admission; the in-jit drafter then folds the
        generated stream in as tokens commit. ``hint`` (a request's
        ``draft_hint``) overlays its bigrams on top of the prompt's."""
        if not self.spec_decode or state is None:
            return state
        from repro.core import drafter
        tab = drafter.seed_from_prompt(self.cfg.vocab_size,
                                       np.asarray(seq, np.int64))
        if hint is not None and len(hint) >= 2:
            h = drafter.seed_from_prompt(self.cfg.vocab_size,
                                         np.asarray(hint, np.int64))
            tab = np.where(h >= 0, h, tab)
        state = dict(state)
        state["draft_tab"] = jnp.asarray(tab)
        return state

    def _pad_prompt(self, tokens: np.ndarray) -> np.ndarray:
        b = self.prefill_bucket
        padded_len = max(b, -(-len(tokens) // b) * b)
        out = np.full((padded_len,), self.pad_token, np.int32)
        out[padded_len - len(tokens):] = tokens
        return out

    def prefill_one(self, req: Request):
        """Prefill one request (B=1), via the prefix cache when possible.

        Returns (last-token logits (1, V), B=1 decode state,
        prefix_hit_tokens, padded_prompt_tokens)."""
        padded = self._pad_prompt(np.asarray(req.tokens, np.int32))
        assert len(padded) + req.max_new_tokens <= self.max_len, (
            f"request {req.uid}: padded prompt {len(padded)} + "
            f"{req.max_new_tokens} new tokens exceeds max_len {self.max_len}")
        seq = tuple(int(t) for t in padded)
        b = self.prefill_bucket
        if self.prefix_cache is not None:
            matched, payload = self.prefix_cache.match(seq)
            # shrink the reused span so the suffix is an exact bucket multiple
            suffix = max(b, -(-(len(seq) - matched) // b) * b)
            tp = len(seq) - suffix
            if tp >= max(b, self.fkv.page_size):   # at least one page reused
                prefix_flat = [a[:tp] for a in payload]
                ptree = self._flat_to_prefix_tree(prefix_flat)
                suf = jnp.asarray(np.asarray(seq[tp:], np.int32)[None])
                logits, state, suf_kv = self._extend(
                    self.params, {"tokens": suf}, ptree)
                full = [np.concatenate([p, s], axis=0) for p, s in
                        zip(prefix_flat, self._kv_tree_to_flat(suf_kv))]
                self.prefix_cache.insert(seq, full)
                return logits, self._attach_draft_tab(
                    state, seq, getattr(req, "draft_hint", None)), tp, \
                    len(seq)

        batch = {"tokens": jnp.asarray(padded[None])}
        if self.cfg.frontend is not None:
            fe = (req.frontend if req.frontend is not None
                  else np.zeros((self.cfg.n_frontend_tokens, self.cfg.d_model),
                                np.float32))
            batch["frontend"] = jnp.asarray(fe[None])
        if self.prefix_cache is not None:
            logits, state, kv = self._prefill_kv(self.params, batch)
            self.prefix_cache.insert(seq, self._kv_tree_to_flat(kv))
        else:
            logits, state = self._prefill(self.params, batch)
        return logits, self._attach_draft_tab(
            state, seq, getattr(req, "draft_hint", None)), 0, len(seq)

    # -- prefix-cache payload <-> model pytree conversions --------------
    # Flat payload layout: [k, v] per layer, prelude first, then pattern
    # positions period-major; every array (T, n_kv, d_head) with token axis 0
    # (the axis the radix trie slices).
    def _kv_tree_to_flat(self, kvtree) -> List[np.ndarray]:
        flat: List[np.ndarray] = []
        for kvp in kvtree["prelude"]:
            flat += [np.asarray(kvp[0][0]), np.asarray(kvp[1][0])]
        for k, v in kvtree["pattern"]:
            k, v = np.asarray(k), np.asarray(v)     # (n_periods, 1, T, kv, d)
            for j in range(k.shape[0]):
                flat += [k[j, 0], v[j, 0]]
        return flat

    def _flat_to_prefix_tree(self, flat: List[np.ndarray]):
        cfg = self.cfg
        i = 0
        pre = []
        for _ in cfg.prelude:
            pre.append((jnp.asarray(flat[i][None]),
                        jnp.asarray(flat[i + 1][None])))
            i += 2
        pat = []
        for _ in cfg.pattern:
            ks = np.stack(flat[i: i + 2 * cfg.n_periods: 2])
            vs = np.stack(flat[i + 1: i + 2 * cfg.n_periods: 2])
            i += 2 * cfg.n_periods
            pat.append((jnp.asarray(ks[:, None]), jnp.asarray(vs[:, None])))
        return {"prelude": tuple(pre), "pattern": tuple(pat)}

    # ------------------------------------------------------------------
    # generation entry point
    # ------------------------------------------------------------------
    def generate(self, requests: List[Request], seed: int = 0) -> List[Completion]:
        if self.scheduler == "continuous":
            return self._generate_continuous(requests, seed)
        t0 = time.perf_counter()
        out: List[Completion] = []
        for i in range(0, len(requests), self.batch_size):
            out.extend(self._generate_batch(requests[i: i + self.batch_size],
                                            seed + i))
        em = EngineMetrics(num_slots=self.batch_size, scheduler="static",
                           tp=self.tp, sample_on_device=False)
        from repro.core.offload import host_offload_active
        em.transfer_is_dma = host_offload_active(self.fkv)
        em.page_block_bytes = self.page_block_bytes
        self._apply_quant_metrics(em)
        em.wall_s = time.perf_counter() - t0
        em.requests = [RequestMetrics(uid=c.uid, prompt_tokens=len(r.tokens),
                                      max_new_tokens=r.max_new_tokens,
                                      new_tokens=len(c.tokens),
                                      prefill_s=c.prefill_s,
                                      decode_s=c.decode_s, finish_t=em.wall_s)
                       for r, c in zip(requests, out)]
        for rm in em.requests:
            em.record_request(rm)
        self.last_metrics = em
        return out

    def _generate_continuous(self, requests, seed, service=None):
        assert self.scheduler == "continuous", \
            "live serving needs scheduler='continuous'"
        if self._pool is None:
            self._pool = self.make_slot_pool(self.batch_size)
        else:
            self._pool.reset_all()
        self.recall_tracker = RecallFlightTracker(shards=self.tp)
        sched = ContinuousScheduler(self, self._pool)
        tracked, em = sched.run(requests, seed, service=service)
        from repro.core.offload import pool_on_host
        em.transfer_is_dma = pool_on_host(self._pool.state)
        self._apply_quant_metrics(em)
        if self.prefix_cache is not None:
            em.prefix_cache = self.prefix_cache.stats()
        self.last_metrics = em
        return [Completion(uid=tr.req.uid, tokens=tr.tokens,
                           prefill_s=tr.prefill_s, decode_s=tr.decode_s,
                           steps=max(len(tr.tokens) - 1, 0),
                           stats=_request_stats(tr.agg), metrics=tr.metrics)
                for tr in tracked]

    def serve_service(self, service, seed: int = 0) -> List[Completion]:
        """Live-serving entry point: drive the continuous scheduler off a
        ``serving/frontend.EngineService`` inbox (dynamic admission,
        streaming per-token events, client-disconnect cancellation) until
        the service closes and drains. Blocking — the front-end runs it on
        a dedicated worker thread. Returns all completions (including
        cancelled requests' partial records) in admission order."""
        return self._generate_continuous([], seed, service=service)

    # -- static chunked fallback ---------------------------------------
    def _generate_batch(self, reqs: List[Request], seed: int) -> List[Completion]:
        cfg = self.cfg
        B = len(reqs)
        T = max(len(r.tokens) for r in reqs)
        toks = np.zeros((B, T), np.int32)
        for i, r in enumerate(reqs):            # left-pad to align last token
            toks[i, T - len(r.tokens):] = r.tokens
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.frontend is not None:
            fe = np.stack([
                r.frontend if r.frontend is not None
                else np.zeros((cfg.n_frontend_tokens, cfg.d_model), np.float32)
                for r in reqs])
            batch["frontend"] = jnp.asarray(fe)

        t0 = time.perf_counter()
        logits, state = self._prefill(self.params, batch)
        logits.block_until_ready()
        prefill_s = time.perf_counter() - t0

        key = jax.random.PRNGKey(seed)
        max_new = max(r.max_new_tokens for r in reqs)
        gen = [[] for _ in reqs]
        # per-request stats: finished rows are masked out of the aggregation
        # (they still ride the lockstep batch — that cost is what the
        # continuous scheduler removes — but they no longer pollute stats)
        aggs = [{k: 0.0 for k in DECODE_STAT_KEYS} for _ in reqs]
        decode_ss = [0.0 for _ in reqs]
        cur = sample(logits, self.sampler, key)
        done = [r.max_new_tokens <= 0 for r in reqs]
        for step in range(max_new):
            for i, r in enumerate(reqs):
                if done[i]:
                    continue
                tok = int(cur[i])
                gen[i].append(tok)
                if len(gen[i]) >= r.max_new_tokens or \
                        (r.eos_token is not None and tok == r.eos_token):
                    done[i] = True
            if all(done):
                break                # no row needs another step: stop
            ts = time.perf_counter()
            logits, state, stats = self._step(self.params, state, cur[:, None])
            stats_np = {k: np.asarray(v) for k, v in stats.items()
                        if k in aggs[0]}
            dt = time.perf_counter() - ts
            for i in range(B):
                # row i needs this step iff it still appends a token next
                # iteration; a finished row's decode cost and retrieval
                # traffic are excluded from its completion record
                if not done[i]:
                    decode_ss[i] += dt
                    for k in aggs[i]:
                        aggs[i][k] += float(stats_np[k][i])
            key = jax.random.fold_in(key, step)
            cur = sample(logits, self.sampler, key)
        jax.block_until_ready(logits)

        return [Completion(uid=r.uid, tokens=gen[i], prefill_s=prefill_s,
                           decode_s=decode_ss[i],
                           steps=max(len(gen[i]) - 1, 0),
                           stats=_request_stats(aggs[i]))
                for i, r in enumerate(reqs)]
