"""Radix-trie prefix cache: token-ID-keyed reuse of prefilled KV.

A request whose prompt shares a prefix with a previously prefilled prompt can
skip the transformer forward for the matched span — the engine re-runs only the
suffix via ``model.prefill_extend`` and rebuilds the paged decode state from
the cached per-layer K/V (see docs/serving.md).

The trie is engine-agnostic: payloads are lists of arrays whose axis 0 is the
token axis (here: one (T, n_kv, d_head) K and V array per attention layer).
Each trie node owns a token *segment* plus the payload slice covering it, so
shared prefixes are stored once (path compression) and a lookup is O(L).
Matching may stop inside a segment (partial-page / partial-segment match); the
node is not split on match — only inserts split nodes.

Eviction is LRU over leaves with a token-count capacity, mirroring
prompt-cache-engine's LRU/TTL design (SNIPPETS.md) at page granularity-free
token resolution.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

Payload = List[np.ndarray]          # per-layer arrays, token axis 0


def _slice_payload(payload: Payload, start: int, stop: int) -> Payload:
    return [np.ascontiguousarray(a[start:stop]) for a in payload]


def _concat_payloads(parts: Sequence[Payload]) -> Payload:
    if not parts:
        return []
    return [np.concatenate([p[i] for p in parts], axis=0)
            for i in range(len(parts[0]))]


def _payload_nbytes(payload: Payload) -> int:
    return sum(int(a.nbytes) for a in payload)


class _Node:
    __slots__ = ("tokens", "payload", "children", "parent", "last_used")

    def __init__(self, tokens: Tuple[int, ...], payload: Optional[Payload],
                 parent: Optional["_Node"]):
        self.tokens = tokens
        self.payload = payload                    # None only for the root
        self.children: Dict[int, _Node] = {}      # first token -> child
        self.parent = parent
        self.last_used = 0

    def is_leaf(self) -> bool:
        return not self.children


class RadixPrefixCache:
    """LRU-evicted radix trie over token IDs with KV payloads.

    capacity_tokens bounds the total number of cached tokens (sum of segment
    lengths); 0 disables the cache entirely (every match misses, inserts are
    dropped) so callers can keep one code path.
    """

    def __init__(self, capacity_tokens: int):
        self.capacity_tokens = int(capacity_tokens)
        self.root = _Node((), None, None)
        self._clock = 0
        self.total_tokens = 0
        # telemetry, consumed by serving.metrics
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.lookup_tokens = 0
        self.insert_count = 0
        self.evictions = 0

    # -- internals -----------------------------------------------------
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _touch(self, node: _Node):
        t = self._tick()
        while node is not None:
            node.last_used = t
            node = node.parent

    @staticmethod
    def _common_len(a: Sequence[int], b: Sequence[int]) -> int:
        n = min(len(a), len(b))
        i = 0
        while i < n and a[i] == b[i]:
            i += 1
        return i

    def _split(self, node: _Node, at: int) -> _Node:
        """Split ``node``'s segment at offset ``at``; returns the upper half."""
        upper = _Node(node.tokens[:at], _slice_payload(node.payload, 0, at),
                      node.parent)
        upper.last_used = node.last_used
        upper.children[node.tokens[at]] = node
        node.parent.children[node.tokens[0]] = upper
        node.tokens = node.tokens[at:]
        node.payload = _slice_payload(node.payload, at,
                                      at + len(node.tokens))
        node.parent = upper
        return upper

    # -- public API ----------------------------------------------------
    def match(self, tokens: Sequence[int]) -> Tuple[int, Optional[Payload]]:
        """Longest cached prefix of ``tokens``.

        Returns (n_matched, payload covering the matched span) — payload is
        None on a zero-length match. The matched path (and, for a partial
        segment match, the containing node) is LRU-touched.
        """
        tokens = tuple(tokens)
        self.lookup_tokens += len(tokens)
        node, off, parts = self.root, 0, []
        while off < len(tokens):
            child = node.children.get(tokens[off])
            if child is None:
                break
            n = self._common_len(child.tokens, tokens[off:])
            if n == 0:
                break
            parts.append(_slice_payload(child.payload, 0, n)
                         if n < len(child.tokens) else child.payload)
            off += n
            node = child
            if n < len(child.tokens):
                break
        self._touch(node)
        if off == 0:
            self.misses += 1
            return 0, None
        self.hits += 1
        self.hit_tokens += off
        return off, _concat_payloads(parts)

    def insert(self, tokens: Sequence[int], payload: Payload) -> int:
        """Insert ``tokens`` with its full-span payload; returns the number of
        newly stored tokens (already-cached prefix spans are deduplicated)."""
        if self.capacity_tokens <= 0 or not len(tokens):
            return 0
        tokens = tuple(tokens)
        node, off = self.root, 0
        while off < len(tokens):
            child = node.children.get(tokens[off])
            if child is None:
                break
            n = self._common_len(child.tokens, tokens[off:])
            if n < len(child.tokens):
                if n == 0:
                    break
                child = self._split(child, n)
            node, off = child, off + n
        added = len(tokens) - off
        if added:
            leaf = _Node(tokens[off:],
                         _slice_payload(payload, off, len(tokens)), node)
            node.children[tokens[off]] = leaf
            node = leaf
            self.total_tokens += added
        self._touch(node)
        self.insert_count += 1
        self._evict_to_capacity()
        return added

    def _evict_to_capacity(self):
        # One trie walk per *generation* of leaves (not per victim): evict
        # leaves in LRU order until under capacity; parents that became
        # leaves are picked up by the next walk (rarely more than one).
        while self.total_tokens > self.capacity_tokens:
            leaves = self._leaves()
            if not leaves:
                return
            leaves.sort(key=lambda n: n.last_used)
            for victim in leaves:
                if self.total_tokens <= self.capacity_tokens:
                    break
                del victim.parent.children[victim.tokens[0]]
                self.total_tokens -= len(victim.tokens)
                self.evictions += 1

    def _leaves(self) -> List[_Node]:
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            if n is not self.root and n.is_leaf():
                out.append(n)
            stack.extend(n.children.values())
        return out

    # -- accounting ----------------------------------------------------
    def nbytes(self) -> int:
        total, stack = 0, [self.root]
        while stack:
            n = stack.pop()
            if n.payload is not None:
                total += _payload_nbytes(n.payload)
            stack.extend(n.children.values())
        return total

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    @property
    def hit_token_rate(self) -> float:
        return self.hit_tokens / self.lookup_tokens if self.lookup_tokens else 0.0

    def clear(self):
        """Drop all cached entries AND reset counters — stats after a clear
        describe only post-clear traffic (benchmarks rely on this)."""
        self.root = _Node((), None, None)
        self.total_tokens = 0
        self.hits = self.misses = 0
        self.hit_tokens = self.lookup_tokens = 0
        self.insert_count = self.evictions = 0

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "hit_tokens": self.hit_tokens,
                "lookup_tokens": self.lookup_tokens,
                "hit_rate": self.hit_rate,
                "hit_token_rate": self.hit_token_rate,
                "cached_tokens": self.total_tokens,
                "evictions": self.evictions,
                "nbytes": self.nbytes()}
