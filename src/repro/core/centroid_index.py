"""Centroid-then-token page selection (the ninth retriever, method="centroid").

FreeKV's exact selection scans every host-pool page summary each decode step
— O(n_pages) per step, the dominant cost once contexts approach ~1M tokens.
This module maintains a CTkvr-style two-level index over the page summaries:

  * per-(layer, kv-head) **centroids** partition the pages into
    ``fkv.centroid_count`` clusters (k-means on page-summary midpoints);
  * each cluster carries a **hierarchical min-max bounding box** — the
    elementwise min/max over its member pages' (lo, hi) summaries — so the
    Quest score of a query against a cluster box is a TRUE upper bound on
    the score of any member page;
  * selection scores the query against the ``C`` cluster boxes first
    (``kernels/centroid_scores.py``), lets pages inherit their cluster's
    pooled upper bound, keeps the top ``COVER_PAGES_FACTOR * n_sel``
    candidate pages, and runs the existing exact page scoring only on that
    gathered candidate set — O(C + candidates) instead of O(n_pages).

Index maintenance is designed so the incremental state is reproducible by a
full rebuild at ANY time (``tests/test_centroid_index.py`` property (b)):

  * the centroid means are a frozen **snapshot**: they change only at the
    periodic re-center (every ``fkv.centroid_refresh_interval`` completed
    pages) and at the prefill build;
  * every page is assigned by the same pure function of (its summary, the
    snapshot) — incrementally at page completion (``update_on_append``),
    and for ALL pages at each re-center — so at every step each valid
    page's assignment equals ``argmin`` against the current snapshot;
  * cluster counts (int sums) and bounding boxes (min/max merges) are
    order-independent and exactly associative, hence ``rebuild`` — which
    recomputes assignments and stats from (summaries, snapshot) alone, the
    swap-in path — matches the incrementally maintained leaves bit-for-bit
    after any append/offload/swap_out/swap_in sequence.

Physicality follows the repo convention: the jnp ops here compute full-width
with masking; the per-step *cost* of the index (pages assigned, candidates
scored) is accounted from counts (``benchmarks/longctx_selection.py``), and
the Pallas stage-1 kernel does the physical C-sized scan.

State leaves (ride the decode state through jit, donation, slot splice,
preemption swap and the TP shard_map; specs in ``sharding/rules``):

  cent        (B, C, kv, 2, d)   cluster bounding boxes (lo, hi)
  cent_mean   (B, C, kv, d) f32  centroid means (the assignment snapshot)
  cent_assign (B, n_pages, kv)   page -> cluster id, -1 = not offloaded
  cent_count  (B, C, kv) int32   member pages per cluster
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, FreeKVConfig
from repro.core import selection

NEG_INF = -1e30
# candidate pages kept after stage 1, as a multiple of n_sel: enough slack
# that the union of winning clusters' pages covers the exact top-k on
# clustered key distributions (coverage is asserted, not assumed, by the
# bit-identity tests; corrected heads fall back to the exact scan anyway)
COVER_PAGES_FACTOR = 4
_BIG = jnp.float32(jnp.finfo(jnp.float32).max)


def candidate_count(n_pages: int, n_sel: int) -> int:
    return min(n_pages, COVER_PAGES_FACTOR * n_sel)


# ---------------------------------------------------------------------------
# leaves
# ---------------------------------------------------------------------------
def init_index(batch, n_pages, n_cent, kv, d, dtype):
    """Empty index leaves (merged into the retriever's decode state)."""
    return {
        "cent": jnp.zeros((batch, n_cent, kv, 2, d), dtype),
        "cent_mean": jnp.zeros((batch, n_cent, kv, d), jnp.float32),
        "cent_assign": jnp.full((batch, n_pages, kv), -1, jnp.int32),
        "cent_count": jnp.zeros((batch, n_cent, kv), jnp.int32),
    }


def page_mid(summ):
    """(B, N, kv, 2, d) summaries -> (B, N, kv, d) f32 box midpoints."""
    lo = summ[..., 0, :].astype(jnp.float32)
    hi = summ[..., 1, :].astype(jnp.float32)
    return 0.5 * (lo + hi)


def _dist2(mid, mean):
    """Squared distances. mid (B, N, kv, d) f32; mean (B, C, kv, d) f32
    -> (B, N, kv, C) f32.

    Elementwise (sub, square, reduce-last-axis) rather than a matmul
    expansion: the per-element reduction order over d is then identical for
    the single-page incremental call and the full-width rebuild, which is
    what makes incremental assignment bit-reproducible."""
    m = mean.transpose(0, 2, 1, 3)                     # (B, kv, C, d)
    diff = mid[:, :, :, None, :] - m[:, None, :, :, :]  # (B, N, kv, C, d)
    return (diff * diff).sum(-1)


def assign_pages(summ, cent_mean, valid):
    """Assign every valid page to its nearest centroid.

    valid (B, N) bool (page fully offloaded). Returns (B, N, kv) int32
    with -1 for invalid pages. Ties break to the lowest cluster id
    (jnp.argmin), identically in every caller."""
    a = jnp.argmin(_dist2(page_mid(summ), cent_mean), axis=-1)
    return jnp.where(valid[:, :, None], a, -1).astype(jnp.int32)


def rebuild_stats(summ, assign, n_cent, dtype):
    """Cluster counts + bounding boxes from scratch, via scatter-min/max
    (order-independent, exactly associative -> bit-equal to any
    incremental min/max-merge maintenance of the same assignment set)."""
    B, N, kv = assign.shape
    d = summ.shape[-1]
    lo = summ[..., 0, :].astype(jnp.float32)           # (B, N, kv, d)
    hi = summ[..., 1, :].astype(jnp.float32)
    ok = assign >= 0
    safe = jnp.where(ok, assign, 0)
    bI = jnp.arange(B)[:, None, None]
    kI = jnp.arange(kv)[None, None, :]
    c_lo = jnp.full((B, n_cent, kv, d), _BIG).at[bI, safe, kI].min(
        jnp.where(ok[..., None], lo, _BIG))
    c_hi = jnp.full((B, n_cent, kv, d), -_BIG).at[bI, safe, kI].max(
        jnp.where(ok[..., None], hi, -_BIG))
    count = jnp.zeros((B, n_cent, kv), jnp.int32).at[bI, safe, kI].add(
        ok.astype(jnp.int32))
    empty = (count == 0)[..., None]
    cent = jnp.stack([jnp.where(empty, 0.0, c_lo),
                      jnp.where(empty, 0.0, c_hi)], axis=3)
    return cent.astype(dtype), count


def recompute_means(summ, assign, n_cent, prev_mean):
    """Segment means of member-page midpoints; empty clusters keep their
    previous mean (so they can repopulate as the distribution drifts)."""
    B, N, kv = assign.shape
    d = summ.shape[-1]
    mid = page_mid(summ)
    ok = assign >= 0
    safe = jnp.where(ok, assign, 0)
    bI = jnp.arange(B)[:, None, None]
    kI = jnp.arange(kv)[None, None, :]
    s = jnp.zeros((B, n_cent, kv, d), jnp.float32).at[bI, safe, kI].add(
        jnp.where(ok[..., None], mid, 0.0))
    n = jnp.zeros((B, n_cent, kv), jnp.int32).at[bI, safe, kI].add(
        ok.astype(jnp.int32))
    mean = s / jnp.maximum(n, 1)[..., None]
    return jnp.where((n > 0)[..., None], mean, prev_mean)


# ---------------------------------------------------------------------------
# build / rebuild
# ---------------------------------------------------------------------------
def build(summ, length, n_cent, page_size, dtype, iters=2):
    """Prefill-time index construction: strided seeds + ``iters`` k-means
    refinements + a final assign-all, so the invariant 'every assignment is
    argmin against the current snapshot' holds from the first decode step."""
    B, N = summ.shape[:2]
    n_done = length // page_size                       # (B,)
    valid = jnp.arange(N)[None, :] < n_done[:, None]
    mid = page_mid(summ)
    c = jnp.arange(n_cent)
    seed = jnp.clip((c[None, :] * jnp.maximum(n_done, 1)[:, None]) // n_cent,
                    0, N - 1)                          # (B, C)
    mean = mid[jnp.arange(B)[:, None], seed]           # (B, C, kv, d)
    for _ in range(iters):
        a = assign_pages(summ, mean, valid)
        mean = recompute_means(summ, a, n_cent, mean)
    a = assign_pages(summ, mean, valid)
    cent, count = rebuild_stats(summ, a, n_cent, dtype)
    return {"cent": cent, "cent_mean": mean, "cent_assign": a,
            "cent_count": count}


def rebuild(state, page_size):
    """Exact rebuild from (summaries, mean snapshot, length) alone — the
    swap-in path, and the oracle the property tests compare the
    incrementally maintained leaves against (bit-equality)."""
    summ = state["summ"]
    n_cent = state["cent_mean"].shape[1]
    n_done = state["length"] // page_size
    valid = jnp.arange(summ.shape[1])[None, :] < n_done[:, None]
    a = assign_pages(summ, state["cent_mean"], valid)
    cent, count = rebuild_stats(summ, a, n_cent, state["cent"].dtype)
    return {"cent": cent, "cent_mean": state["cent_mean"],
            "cent_assign": a, "cent_count": count}


# ---------------------------------------------------------------------------
# incremental maintenance (decode append / offload)
# ---------------------------------------------------------------------------
def update_on_append(state, fkv: FreeKVConfig):
    """Index maintenance after ``paging.append_token``: assign the page that
    just completed (if any) against the frozen mean snapshot, min/max-merge
    its box into its cluster, then — every ``centroid_refresh_interval``
    completed pages — one cheap k-means step (re-center + reassign-all +
    exact stat rebuild). All updates are per-row masked on page completion."""
    p = fkv.page_size
    length = state["length"]                           # post-append
    page_done = (length % p) == 0                      # (B,)
    page_idx = length // p - 1
    safe_pi = jnp.where(page_done, page_idx, 0)
    B = length.shape[0]
    n_cent = state["cent_mean"].shape[1]
    kv = state["cent_mean"].shape[2]

    # -- assign the completed page (same distance fn as the full rebuild)
    row = state["summ"][jnp.arange(B), safe_pi]        # (B, kv, 2, d)
    a = jnp.argmin(_dist2(page_mid(row[:, None]), state["cent_mean"]),
                   axis=-1)[:, 0].astype(jnp.int32)    # (B, kv)
    bI = jnp.arange(B)[:, None]
    kI = jnp.arange(kv)[None, :]
    old_a = state["cent_assign"][bI, safe_pi[:, None], kI]
    assign = state["cent_assign"].at[bI, safe_pi[:, None], kI].set(
        jnp.where(page_done[:, None], a, old_a))

    # -- count += 1, bounds min/max-merge for the page's cluster
    old_n = state["cent_count"][bI, a, kI]
    count = state["cent_count"].at[bI, a, kI].set(
        old_n + page_done[:, None].astype(jnp.int32))
    box = row.astype(jnp.float32)                      # (B, kv, 2, d)
    old_box = state["cent"][bI, a, kI].astype(jnp.float32)
    merged = jnp.stack([jnp.minimum(old_box[:, :, 0], box[:, :, 0]),
                        jnp.maximum(old_box[:, :, 1], box[:, :, 1])], axis=2)
    new_box = jnp.where((old_n > 0)[..., None, None], merged, box)
    new_box = jnp.where(page_done[:, None, None, None], new_box, old_box)
    cent = state["cent"].at[bI, a, kI].set(new_box.astype(state["cent"].dtype))
    st = dict(state, cent=cent, cent_assign=assign, cent_count=count)

    # -- periodic re-center (one masked k-means iteration per row)
    n_done = length // p
    recen = page_done & (n_done % max(fkv.centroid_refresh_interval, 1) == 0)
    mean2 = recompute_means(st["summ"], st["cent_assign"], n_cent,
                            st["cent_mean"])
    valid = jnp.arange(st["summ"].shape[1])[None, :] < n_done[:, None]
    a2 = assign_pages(st["summ"], mean2, valid)
    cent2, count2 = rebuild_stats(st["summ"], a2, n_cent, cent.dtype)
    r1 = recen[:, None]
    return dict(
        st,
        cent_mean=jnp.where(recen[:, None, None, None], mean2,
                            st["cent_mean"]),
        cent_assign=jnp.where(r1[..., None], a2, st["cent_assign"]),
        cent=jnp.where(recen[:, None, None, None, None], cent2, st["cent"]),
        cent_count=jnp.where(r1[..., None], count2, st["cent_count"]))


# ---------------------------------------------------------------------------
# two-stage selection
# ---------------------------------------------------------------------------
def cluster_scores(cfg: ArchConfig, fkv: FreeKVConfig, q, state,
                   use_kernels=False):
    """Stage 1: query vs cluster bounding boxes -> (B, kv, C) f32 pooled
    upper bounds (group max — an upper bound for every head in the group);
    empty clusters score NEG_INF."""
    B, H, d = q.shape
    kv = cfg.n_kv_heads
    G = H // kv
    scale = cfg.attn_scale if cfg.attn_scale is not None else 1.0 / (d ** 0.5)
    if use_kernels:
        from repro.kernels import ops
        s = ops.centroid_scores(q.reshape(B, kv, G, d), state["cent"],
                                state["cent_count"], scale=scale,
                                interpret=ops.resolve_interpret(fkv))
    else:
        sh = selection.page_scores_minmax(q, state["cent"], scale)  # (B,H,C)
        s = sh.reshape(B, kv, G, -1)
        s = jnp.where((state["cent_count"].transpose(0, 2, 1) > 0)
                      [:, :, None, :], s, NEG_INF)
    return s.max(axis=2)                               # (B, kv, C)


def candidate_pages(cl_scores, cent_assign, valid, m):
    """Pages inherit their cluster's pooled upper bound; keep the top-``m``
    selectable pages per (batch, kv-head). Returns (B, kv, m) int32 page
    ids, -1-padded, ordered by inherited score (cluster-major)."""
    a = cent_assign.transpose(0, 2, 1)                 # (B, kv, N)
    safe = jnp.where(a >= 0, a, 0)
    inh = jnp.take_along_axis(cl_scores, safe, axis=-1)
    ok = (a >= 0) & valid[:, None, :]
    inh = jnp.where(ok, inh, NEG_INF)
    top_s, top_i = jax.lax.top_k(inh, m)
    return jnp.where(top_s > NEG_INF / 2, top_i, -1).astype(jnp.int32)


def centroid_select(cfg: ArchConfig, fkv: FreeKVConfig, q, state, n_sel,
                    use_kernels=False):
    """Full centroid-then-token selection.

    Returns (idx (B, kv, n_sel) int32 page ids -1-padded, cand_idx
    (B, kv, m)). Stage 2 scores ONLY the gathered candidate summaries with
    the existing page scoring (kernel or jnp) — per-page scores are
    independent of the rest of the set, so under the non-softmax pooling
    modes the result is bit-equal to the exact top-k whenever the
    candidates cover it (docs/methods.md)."""
    B, H, d = q.shape
    kv = cfg.n_kv_heads
    N = state["summ"].shape[1]
    scale = cfg.attn_scale if cfg.attn_scale is not None else 1.0 / (d ** 0.5)
    cs = cluster_scores(cfg, fkv, q, state, use_kernels=use_kernels)
    valid = selection.selectable_mask(cfg, fkv, N, state["length"])
    m = candidate_count(N, n_sel)
    cand_idx = candidate_pages(cs, state["cent_assign"], valid, m)

    # gather candidate summaries per kv head: (B, m, kv, 2, d) where each
    # head's page axis holds its own candidates
    safe = jnp.clip(cand_idx, 0, N - 1)
    bI = jnp.arange(B)[:, None, None]
    kI = jnp.arange(kv)[None, :, None]
    summ_c = state["summ"][bI, safe, kI].transpose(0, 2, 1, 3, 4)
    if use_kernels:
        from repro.kernels import ops
        scores = ops.page_scores(
            q.reshape(B, kv, H // kv, d), summ_c, scale=scale,
            interpret=ops.resolve_interpret(fkv)).reshape(B, H, -1)
    else:
        scores = selection.page_scores_minmax(q, summ_c, scale)   # (B,H,m)
    ok = cand_idx >= 0                                 # (B, kv, m)
    pooled = selection.group_consistent_scores(cfg, scores, ok,
                                               fkv.group_pool)
    k = min(n_sel, m)
    top_s, top_i = jax.lax.top_k(pooled, k)
    idx = jnp.take_along_axis(cand_idx, top_i.astype(jnp.int32), axis=2)
    idx = jnp.where(top_s > NEG_INF / 2, idx, -1)
    if k < n_sel:
        pad = jnp.full(idx.shape[:-1] + (n_sel - k,), -1, jnp.int32)
        idx = jnp.concatenate([idx, pad], axis=-1)
    return idx, cand_idx
