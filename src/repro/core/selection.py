"""Page selection (§3.2): Quest-style min-max scoring over page summaries +
group-consistent pooling. The paper's choice is **MeanS** — mean pooling across
the GQA group over softmax(page attention weights) (App. B.2); the alternatives
are implemented for the ablation benchmark.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, FreeKVConfig

NEG_INF = -1e30


def page_scores_minmax(q, summ, scale):
    """Quest upper-bound score per (q-head, page).

    q:    (B, H, d)
    summ: (B, n_pages, kv, 2, d)  (min, max) pooled keys
    Returns (B, H, n_pages) fp32.
    """
    B, H, d = q.shape
    kv = summ.shape[2]
    G = H // kv
    qg = q.reshape(B, kv, G, d).astype(jnp.float32)
    lo = summ[..., 0, :].astype(jnp.float32)     # (B,n,kv,d)
    hi = summ[..., 1, :].astype(jnp.float32)
    # sum_d max(q_d*lo_d, q_d*hi_d) == relu(q) @ hi + min(q, 0) @ lo
    # (exact since lo <= hi coordinate-wise) -> two MXU matmuls, no (n,d)
    # elementwise intermediate
    s = (jnp.einsum("bkgd,bnkd->bkgn", jnp.maximum(qg, 0), hi)
         + jnp.einsum("bkgd,bnkd->bkgn", jnp.minimum(qg, 0), lo)) * scale
    return s.reshape(B, H, -1)


def selectable_mask(cfg: ArchConfig, fkv: FreeKVConfig, n_pages, length):
    """Pages eligible for selection: fully offloaded, not sink, not inside the
    local window (those tokens are device-resident already)."""
    p = fkv.page_size
    pages = jnp.arange(n_pages)
    first = fkv.n_sink // p                      # sink pages resident
    n_done = length // p                         # fully offloaded pages (B,)
    last = jnp.maximum(first, (length - fkv.n_window) // p)  # window boundary
    return (pages[None, :] >= first) & (pages[None, :] < jnp.minimum(
        n_done, last)[:, None])                  # (B, n_pages)


def group_consistent_scores(cfg: ArchConfig, scores, valid, mode="mean_softmax"):
    """(B, H, n_pages) per-q-head scores -> (B, kv, n_pages) group-consistent.

    modes: mean_softmax (MeanS, paper) | max_softmax | mean_qk | max_qk
    (the q-pooling variants MaxQ/MeanQ pool q before scoring — see
    ``select_pages``'s q_pool argument).

    ``valid`` is (B, n_pages) shared across kv heads, or (B, kv, n) when the
    page axis is per-head (the centroid retriever's gathered candidates).
    """
    B, H, n = scores.shape
    kv = cfg.n_kv_heads
    G = H // kv
    ok = valid if valid.ndim == 3 else valid[:, None, :]   # (B, kv, n)
    s = scores.reshape(B, kv, G, n)
    s = jnp.where(ok[:, :, None, :], s, NEG_INF)
    if mode.endswith("softmax"):
        s = jax.nn.softmax(s, axis=-1)
    if mode.startswith("mean"):
        pooled = s.mean(axis=2)
    else:
        pooled = s.max(axis=2)
    return jnp.where(ok, pooled, NEG_INF)


def select_pages(cfg: ArchConfig, fkv: FreeKVConfig, q, summ, length, n_sel,
                 q_pool=None):
    """Full selection: scores -> group-consistent pooling -> top-k page ids.

    Returns (idx (B, kv, n_sel) int32 with -1 for invalid, scores_pooled).
    """
    B, H, d = q.shape
    scale = cfg.attn_scale if cfg.attn_scale is not None else 1.0 / (d ** 0.5)
    if q_pool in ("max", "mean"):                # MaxQ / MeanQ ablations
        kv = cfg.n_kv_heads
        qg = q.reshape(B, kv, H // kv, d)
        qp = qg.max(axis=2) if q_pool == "max" else qg.mean(axis=2)
        q = jnp.repeat(qp, H // kv, axis=1)
    if fkv.use_kernels:
        from repro.kernels import ops
        kv = cfg.n_kv_heads
        scores = ops.page_scores(
            q.reshape(B, kv, H // kv, d), summ, scale=scale,
            interpret=ops.resolve_interpret(fkv),
        ).reshape(B, H, -1)
    else:
        scores = page_scores_minmax(q, summ, scale)              # (B,H,n)
    valid = selectable_mask(cfg, fkv, summ.shape[1], length)     # (B,n)
    pooled = group_consistent_scores(cfg, scores, valid, fkv.group_pool)
    k = min(n_sel, pooled.shape[-1])
    top_s, top_i = jax.lax.top_k(pooled, k)                      # (B,kv,k)
    idx = jnp.where(top_s > NEG_INF / 2, top_i, -1).astype(jnp.int32)
    if 0.0 < fkv.select_top_p < 1.0 and fkv.group_pool.endswith("softmax"):
        # dynamic budget (paper §6 / Twilight-style): pooled scores are a
        # probability distribution over pages under the *S pooling modes;
        # keep the smallest prefix reaching top_p mass (always >= 1 page)
        mass = jnp.cumsum(jnp.maximum(top_s, 0.0), axis=-1)
        keep = (mass - jnp.maximum(top_s, 0.0)) < fkv.select_top_p
        keep = keep.at[..., 0].set(True)
        idx = jnp.where(keep, idx, -1)
    if k < n_sel:
        pad = jnp.full(idx.shape[:-1] + (n_sel - k,), -1, jnp.int32)
        idx = jnp.concatenate([idx, pad], axis=-1)
    return idx, pooled


def oracle_pages(cfg: ArchConfig, fkv: FreeKVConfig, q, k_full, length, n_sel):
    """Oracle top-k pages from *exact* attention weights (tests/benchmarks).

    q: (B,H,d); k_full: (B,T,kv,d) post-rope keys. Returns (B,kv,n_sel)."""
    B, H, d = q.shape
    T = k_full.shape[1]
    p = fkv.page_size
    kv = cfg.n_kv_heads
    scale = cfg.attn_scale if cfg.attn_scale is not None else 1.0 / (d ** 0.5)
    qg = q.reshape(B, kv, H // kv, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k_full.astype(jnp.float32)) * scale
    tok_valid = jnp.arange(T)[None, :] < length[:, None]
    s = jnp.where(tok_valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    n_pages = T // p
    wp = w[..., : n_pages * p].reshape(B, kv, H // kv, n_pages, p).sum(-1)
    pooled = wp.mean(axis=2)                                     # (B,kv,n_pages)
    valid = selectable_mask(cfg, fkv, n_pages, length)
    pooled = jnp.where(valid[:, None, :], pooled, NEG_INF)
    _, top_i = jax.lax.top_k(pooled, n_sel)
    return top_i.astype(jnp.int32)
