"""Overlapped double-buffered streamed recall (§4 system side).

The synchronous decode path recalls *every* freshly selected page on the
critical path and then lets the correction mask (§3.3) pick fresh vs stale
content per KV head — the speculative-retrieval algorithm with none of its
systems payoff. This module supplies the payoff: a **recall executor** that
splits each decode step's transfer into

  * a **correction top-up** — the only on-critical-path transfer: pages for
    *corrected* heads that are not already resident in the previous step's
    buffer. Pool pages are written exactly once (at page completion /
    prefill), so reusing a resident page is bit-exact, and
  * a **staged recall** — the speculatively selected pages for step t+1
    stream into the alternate buffer while step t's attention computes over
    the merged (previous ∪ top-up) buffer. Nothing downstream of attention
    depends on the staged arrays, so XLA / the TPU DMA engine (or plain JAX
    async dispatch on the CPU sim) overlaps them with compute; on TPU with
    ``fkv.offload == "host"`` the source is the ``pinned_host`` pool and the
    stream is a genuine host→device DMA (see ``core/offload.py``).

The two buffers of the paper's double buffering are the decode state's
``sel_k/sel_v`` (the buffer attention reads) and the staged arrays that
become the *next* state's ``sel_k/sel_v`` — per continuous-batching slot,
carried across engine steps by the slot pool. Chunk-level double buffering
*within* one transfer lives in the Pallas kernel
(``kernels/recall_gather.py``: 2-deep VMEM ring, per-chunk DMA overlap).

Guarantee: for any correction mask, ``merged == where(corr, fresh, stale)``
and ``staged == fresh`` hold bit-exactly, so greedy decode outputs are
bit-identical with the pipeline on or off (``tests/test_recall_pipeline.py``).

Physicality: through the Pallas kernel (``use_kernels=True``) masked lanes
issue no DMA, so the top-up/staged/reused split is a real traffic split.
The jnp reference gather is full-width regardless of masking (a gather has
no notion of skipping); under ``offload='sim'`` its transfer cost is
accounted analytically from the block counts (benchmarks/_common.py), which
is why the counts here — not array shapes — are the source of truth.

Host-side, ``RecallFlightTracker`` accounts per-slot in-flight staged pages
across continuous-batching steps: a slot freed at a step boundary abandons
its staged buffer (the next occupant prefills its own), which the serving
metrics report as dropped in-flight transfer.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax.numpy as jnp

from repro.core import recall
from repro.obs.trace import (SPAN_RECALL_REUSE, SPAN_RECALL_STAGED,
                             SPAN_RECALL_TOPUP, annotate)


def match_resident(new_idx, prev_idx):
    """Which newly selected pages already sit in the previous buffer.

    new_idx/prev_idx (B, kv, n_sel) int32 page ids, -1 = invalid.
    Returns (hit (B, kv, n_sel) bool, src (B, kv, n_sel) int32): for every
    hit, ``src`` is the position inside the previous buffer holding that
    page (top-k ids are distinct, so the match is unique)."""
    eq = (new_idx[..., :, None] == prev_idx[..., None, :]) \
        & (new_idx >= 0)[..., :, None] & (prev_idx >= 0)[..., None, :]
    hit = eq.any(axis=-1)
    src = jnp.argmax(eq, axis=-1).astype(jnp.int32)
    return hit, src


def _take_pages(buf, src):
    """Gather buffer pages (B, kv, n_sel, p, d) at per-slot positions src."""
    return jnp.take_along_axis(buf, src[..., None, None], axis=2)


@dataclass
class PipelinedRecall:
    """One decode step's transfer plan + results (all device arrays)."""
    use_k: jnp.ndarray        # merged buffer attention reads (B,kv,n_sel,p,d)
    use_v: jnp.ndarray
    use_idx: jnp.ndarray      # page ids backing use_k/use_v (B,kv,n_sel)
    staged_k: jnp.ndarray     # next step's buffer == fresh recall, bit-exact
    staged_v: jnp.ndarray
    topup_blocks: jnp.ndarray  # (B,) critical-path (kv-head, page) fetches
    staged_blocks: jnp.ndarray  # (B,) overlapped fetches
    reused_blocks: jnp.ndarray  # (B,) buffer hits (no transfer at all)


class RecallExecutor:
    """Double-buffered recall over one (pool, idx) -> (k, v) gather backend.

    ``recall_fn(pool, idx)`` is the full K+V gather (jnp reference, chunked
    Pallas kernel, or shard-local recall); ``values_fn`` optionally the
    V-only variant (ShadowKV). ``pool`` is opaque to the executor — the
    retrievers pass the fp pool array, or a (packed pool, scales) pair under
    the quantized host tier (``src/repro/quant``), and the gather backend
    unpacks it. The executor is pure (safe under jit): the overlap is
    expressed through dataflow — attention depends only on ``use_k/use_v``,
    never on the staged arrays."""

    def __init__(self, recall_fn=None, values_fn=None):
        self.recall_fn = recall_fn or recall.recall_pages
        self.values_fn = values_fn or recall.recall_values_only

    # -- blocking path (sync mode / non-speculative baselines) ----------
    def recall(self, pool, idx):
        """Full blocking recall — the synchronous baseline's only mode."""
        return self.recall_fn(pool, idx)

    # -- pipelined path -------------------------------------------------
    def step(self, pool, new_idx, prev_idx, prev_k, prev_v,
             need) -> PipelinedRecall:
        """Plan + execute one overlapped decode step.

        need (B, kv) bool — heads whose fresh pages must be visible to THIS
        step's attention (the correction mask; all-True for always-fresh
        baselines). Pages for ``~need`` heads only feed the staged buffer.
        """
        dt = prev_k.dtype
        hit, src = match_resident(new_idx, prev_idx)
        with annotate(SPAN_RECALL_REUSE):
            reused_k = _take_pages(prev_k, src)
            reused_v = _take_pages(prev_v, src)
        valid = new_idx >= 0
        need3 = need[:, :, None]

        # critical path: corrected heads' non-resident pages only
        topup_idx = jnp.where(need3 & ~hit & valid, new_idx, -1)
        with annotate(SPAN_RECALL_TOPUP):
            tk, tv = self.recall_fn(pool, topup_idx)
        tk, tv = tk.astype(dt), tv.astype(dt)
        # overlapped: everything else that is fresh and non-resident
        stage_idx = jnp.where(~need3 & ~hit & valid, new_idx, -1)
        with annotate(SPAN_RECALL_STAGED):
            sk, sv = self.recall_fn(pool, stage_idx)
        sk, sv = sk.astype(dt), sv.astype(dt)

        hit5 = hit[..., None, None]
        fresh_k = jnp.where(hit5, reused_k, jnp.where(need3[..., None, None],
                                                      tk, sk))
        fresh_v = jnp.where(hit5, reused_v, jnp.where(need3[..., None, None],
                                                      tv, sv))
        use_k = jnp.where(need3[..., None, None], fresh_k, prev_k)
        use_v = jnp.where(need3[..., None, None], fresh_v, prev_v)
        use_idx = jnp.where(need3, new_idx, prev_idx)
        return PipelinedRecall(
            use_k=use_k, use_v=use_v, use_idx=use_idx,
            staged_k=fresh_k, staged_v=fresh_v,
            topup_blocks=jnp.sum(topup_idx >= 0, axis=(1, 2)),
            staged_blocks=jnp.sum(stage_idx >= 0, axis=(1, 2)),
            reused_blocks=jnp.sum(hit, axis=(1, 2)))

    def step_values(self, pool, new_idx, prev_idx, prev_v) -> PipelinedRecall:
        """ShadowKV variant: V-only delta fetch against the previous buffer.

        Selection is fresh every step (no correction mask), so everything
        non-resident is a critical-path fetch — but buffer hits still skip
        the transfer entirely, and the composed buffer doubles as the next
        step's resident set."""
        dt = prev_v.dtype
        hit, src = match_resident(new_idx, prev_idx)
        reused_v = _take_pages(prev_v, src)
        fetch_idx = jnp.where(~hit & (new_idx >= 0), new_idx, -1)
        fv = self.values_fn(pool, fetch_idx).astype(dt)
        fresh_v = jnp.where(hit[..., None, None], reused_v, fv)
        zero = jnp.zeros_like(fresh_v)
        return PipelinedRecall(
            use_k=zero, use_v=fresh_v, use_idx=new_idx,
            staged_k=zero, staged_v=fresh_v,
            topup_blocks=jnp.sum(fetch_idx >= 0, axis=(1, 2)),
            staged_blocks=jnp.zeros(new_idx.shape[0], jnp.int32),
            reused_blocks=jnp.sum(hit, axis=(1, 2)))


class RecallFlightTracker:
    """Host-side per-slot accounting of in-flight staged recall.

    The staged buffer a slot carries out of step t is consumed by step t+1
    — unless the slot turns over at the boundary (request finished, slot
    freed/refilled), in which case the in-flight pages were streamed for
    nothing. The continuous-batching scheduler feeds this tracker each step
    and invalidates on slot free; the dropped total surfaces in
    ``EngineMetrics.summary()["recall_overlap"]``.

    Under tensor-parallel serving (``shards > 1``) the fed counts are the
    GLOBAL integer page counts (psum'ed across the KV-head-group shards by
    the TP retriever wrapper); every page block belongs to exactly one KV
    head, hence one shard, so each shard's own host link carries exactly
    ``1/shards`` of every class — ``summary()["per_shard"]`` reports that
    view."""

    def __init__(self, shards: int = 1):
        self.shards = max(shards, 1)
        self._in_flight: Dict[int, float] = {}
        self.dropped_pages = 0.0
        self.staged_pages = 0.0
        self.topup_pages = 0.0
        self.reused_pages = 0.0

    def note_step(self, slot: int, staged: float, topup: float = 0.0,
                  reused: float = 0.0):
        """Record one engine step's per-slot transfer split; the staged
        pages replace whatever the slot had in flight (now consumed)."""
        self._in_flight[slot] = staged
        self.staged_pages += staged
        self.topup_pages += topup
        self.reused_pages += reused

    def invalidate(self, slot: int):
        """Slot turnover: the staged buffer is abandoned mid-flight."""
        self.dropped_pages += self._in_flight.pop(slot, 0.0)

    def drop(self, pages: float):
        """Pages streamed for work that was discarded without ever touching
        the slot's carried buffer — a speculative-decoding verify row whose
        draft was rejected staged (and topped up) for a continuation that
        never commits; the rollback recall re-stages from the last committed
        row. Accounted straight into the dropped total."""
        self.dropped_pages += max(pages, 0.0)

    def suspend(self, slot: int) -> float:
        """Preemption swap-out: the slot's staged buffer lives in the
        ``sel_k/sel_v`` leaves and round-trips through host memory with the
        rest of the state, so the in-flight pages travel WITH the request
        instead of being dropped. Returns the suspended count for
        ``restore`` at swap-in."""
        return self._in_flight.pop(slot, 0.0)

    def restore(self, slot: int, staged: float):
        """Preemption swap-in: reattach a ``suspend``ed in-flight count to
        the (possibly different) slot the request resumed into."""
        if staged:
            self._in_flight[slot] = staged

    def in_flight(self, slot: int) -> Optional[float]:
        return self._in_flight.get(slot)

    def summary(self) -> dict:
        moved = self.staged_pages + self.topup_pages
        return {
            "staged_pages": self.staged_pages,
            "topup_pages": self.topup_pages,
            "reused_pages": self.reused_pages,
            "dropped_pages": self.dropped_pages,
            "hidden_fraction": self.staged_pages / moved if moved else 0.0,
            "per_shard": {
                "shards": self.shards,
                "staged_pages": self.staged_pages / self.shards,
                "topup_pages": self.topup_pages / self.shards,
                "dropped_pages": self.dropped_pages / self.shards,
            },
        }
