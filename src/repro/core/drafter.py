"""Device-resident self-drafting proposer for speculative decoding.

FreeKV hides retrieval latency by speculating on *which pages* the next step
needs; this module speculates on *which tokens* the model will emit, so one
batched verify pass (``models.serve_step_verify``) can commit several tokens
per target-model step. The drafter is training-free and model-free: a
per-slot bigram successor table over the request's own token stream (prompt
+ committed continuation), the n-gram/self-drafting family of proposers.

The table is ONE decode-state lane:

  ``draft_tab`` (B, vocab) int32 — ``draft_tab[b, t]`` is the most recent
  successor of token ``t`` observed in slot ``b``'s stream, or -1.

It lives as a top-level key of the serving decode state (sibling of
``pos``), so slot splice/extract, preemption swap, donation, and the TP
``decode_state_spec`` fallthrough (batch-only → replicated) all apply to it
with zero special cases. Seeding from the prompt happens host-side at
admission (``seed_from_prompt``); proposal and the on-commit update run
inside the jitted decode window (pure gathers/scatters, no host sync).

Exactness does not depend on draft quality in any way: proposals are
verified by the target model with accept-longest-prefix, so a wrong (or
-1 → fallback 0) proposal merely costs its slice of the drafted block.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def init_draft_tab(batch: int, vocab: int):
    """Empty successor table: no bigram observed yet."""
    return jnp.full((batch, vocab), -1, jnp.int32)


def seed_from_prompt(vocab: int, tokens) -> np.ndarray:
    """Bigram table (1, vocab) for one request's prompt, host-side.

    Later occurrences win (``tab[t]`` = most recent successor of ``t``),
    matching the in-jit ``update`` ordering over the generated stream."""
    tab = np.full((1, vocab), -1, np.int32)
    toks = np.asarray(tokens, np.int64)
    if toks.size >= 2:
        src = np.clip(toks[:-1], 0, vocab - 1)
        tab[0, src] = np.clip(toks[1:], 0, vocab - 1)
    return tab


def propose(tab, cur, draft_len: int):
    """Chain ``draft_len`` successor lookups from ``cur`` (B,) int32.

    Returns (B, draft_len) int32 proposals, clamped to valid token ids — a
    miss (no successor) proposes token 0, which the verify pass simply
    rejects. The chain is draft-time-only state; nothing here is carried."""
    B = cur.shape[0]
    bidx = jnp.arange(B)
    out = []
    t = cur
    for _ in range(draft_len):
        nxt = tab[bidx, jnp.clip(t, 0, tab.shape[1] - 1)]
        t = jnp.where(nxt >= 0, nxt, 0).astype(jnp.int32)
        out.append(t)
    return jnp.stack(out, axis=1) if out else jnp.zeros((B, 0), jnp.int32)


def update(tab, toks, emit):
    """Fold one verify block's committed bigrams into the table.

    ``toks`` (B, S) — the token stream rows fed+emitted this block, where
    ``toks[:, j] -> toks[:, j+1]`` is a bigram iff ``emit[:, j+1]`` (row j+1
    was actually emitted). Masked rows scatter into their existing value
    (read-modify-write no-op) so the update stays shape-static and the
    sequential-scatter order matches the one-token-per-step path exactly."""
    B, S = toks.shape
    bidx = jnp.arange(B)
    for j in range(S - 1):
        src = jnp.clip(toks[:, j], 0, tab.shape[1] - 1)
        new = jnp.clip(toks[:, j + 1], 0, tab.shape[1] - 1)
        old = tab[bidx, src]
        tab = tab.at[bidx, src].set(jnp.where(emit[:, j + 1], new, old))
    return tab
