"""Host offload of the KV pool via JAX memory kinds (the paper's CPU-DRAM
offload, TPU-native).

``fkv.offload == "host"`` places the per-layer pool (and page summaries) in
``pinned_host`` memory; XLA inserts host<->device DMA for the page
scatter (offload path, amortized per completed page) and the recall gather
(the paper's streamed recall). ``"sim"`` keeps the pool in device memory and
accounts transfer costs analytically (benchmarks/_common.py) — the default on
platforms where compute on host-resident buffers is unsupported.

With the overlapped recall pipeline (``core/recall_pipeline``), the host
pool is the *source* of both transfer classes: the correction top-up (the
only host→device DMA the decode step waits on) and the staged speculative
stream that fills the alternate double buffer. Because ``pinned_host``
donation keeps the pool pages page-locked, the staged gather lowers to a
true async DMA on TPU; nothing downstream of attention consumes its result,
so XLA schedules it behind decode compute. ``pool_on_host`` tells the
executor/telemetry whether transfers are real DMAs or simulated
(cost-model) ones.

Usage:
    state = place_decode_state(state, fkv)            # after init/prefill
    shardings = decode_state_shardings(..., fkv=fkv)  # dryrun: memory kinds
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.base import FreeKVConfig

# pool payload + its quant scales live on host; summaries stay in HBM (read
# every step). ``pool_scale`` only exists under fkv.kv_quant != "none".
HOST_KEYS = ("pool", "pool_scale")


def host_memory_kind():
    """The best host-side memory kind this backend exposes, or None.

    TPU (and current CPU jaxlibs) expose ``pinned_host``; the jax-0.4.x CPU
    backend only has ``unpinned_host``. Preferring pinned keeps the staged
    recall a true async DMA where that matters, while the fallback lets the
    offload path (and its tests) execute everywhere instead of skipping."""
    try:
        kinds = [m.kind for m in jax.devices()[0].addressable_memories()]
    except Exception:  # noqa: BLE001
        return None
    for kind in ("pinned_host", "unpinned_host"):
        if kind in kinds:
            return kind
    return None


def _host_kind_available() -> bool:
    return host_memory_kind() is not None


def host_sharding_for(leaf, mesh=None, spec=None):
    """A sharding equivalent to the leaf's current one but in host memory
    (pinned when the backend supports it)."""
    kind = host_memory_kind()
    if mesh is not None and spec is not None:
        return jax.sharding.NamedSharding(mesh, spec, memory_kind=kind)
    dev = jax.devices()[0]
    return jax.sharding.SingleDeviceSharding(dev, memory_kind=kind)


def place_decode_state(state, fkv: FreeKVConfig, mesh=None, specs=None,
                       cfg=None):
    """Move the pool leaves of a (possibly nested, layer-stacked) decode state
    to pinned_host memory. No-op for offload != 'host' or unsupported hosts.

    Sharding-aware: with a ``mesh``, each pool leaf keeps its partitioning
    while moving memory kinds — pass ``specs`` (a single PartitionSpec for
    every pool leaf) or ``cfg`` (per-leaf specs derived from
    ``sharding/rules.decode_state_spec``, e.g. KV-head-group sharding under
    tensor-parallel serving, where each shard's pool slice is host-resident
    on its own device)."""
    if fkv.offload != "host" or not _host_kind_available():
        return state

    def _spec_for(path, leaf):
        if specs is not None:
            return specs
        if mesh is not None and cfg is not None:
            from repro.sharding import rules
            return rules.decode_state_spec(cfg, mesh, rules._path_str(path),
                                           leaf, fkv)
        return None

    def move(path, leaf):
        key = str(getattr(path[-1], "key", path[-1]))
        if key in HOST_KEYS and hasattr(leaf, "shape"):
            sh = _spec_for(path, leaf)
            return jax.device_put(leaf, host_sharding_for(leaf, mesh, sh))
        return leaf

    return jax.tree_util.tree_map_with_path(move, state)


def host_offload_active(fkv: FreeKVConfig) -> bool:
    """Config-level check: would pools be placed in pinned_host memory?
    (Use ``pool_on_host`` for ground truth on an actual state pytree.)"""
    return fkv.offload == "host" and _host_kind_available()


def pool_on_host(state) -> bool:
    """True when the state's pool leaves live in ``pinned_host`` memory —
    i.e. recall transfers are genuine host→device DMAs rather than the
    ``offload='sim'`` cost-model simulation."""
    found = False

    def check(path, leaf):
        nonlocal found
        key = str(getattr(path[-1], "key", path[-1]))
        if key in HOST_KEYS:
            kind = getattr(getattr(leaf, "sharding", None), "memory_kind",
                           None)
            found = found or kind in ("pinned_host", "unpinned_host")
        return leaf

    jax.tree_util.tree_map_with_path(check, state)
    return found


def swap_state_to_host(state):
    """Pull an extracted (B=1) decode state fully to host numpy — the
    serving preemption swap-out tier.

    Unlike ``place_decode_state`` (which keeps pool leaves device-addressable
    in pinned host memory for DMA recall), a swapped-out victim's state
    leaves the device entirely: every leaf — packed int8/int4 pool payload,
    fp32 quant scales, sink/window rings, selection buffers, summaries,
    ``pos`` — is materialized as a host numpy array at its stored dtype, so
    the round trip back through ``SlotPool.swap_in`` is exact (bit-identical
    for fp leaves, the identical packed representation for quantized pools).
    """
    return jax.tree.map(np.asarray, jax.device_get(state))


def pool_bytes(state) -> int:
    """Total bytes resident in the (host) pool across layers (telemetry).

    Quant-aware by construction: packed int8/int4 pool leaves report their
    physical ``nbytes`` and the fp32 ``pool_scale`` leaves are included, so
    this is the true host-tier footprint. For the dense-equivalent
    comparison (capacity multiplier), see
    ``repro.quant.accounting.pool_bytes_detail``."""
    total = 0

    def acc(path, leaf):
        nonlocal total
        key = str(getattr(path[-1], "key", path[-1]))
        if key in HOST_KEYS and hasattr(leaf, "nbytes"):
            total += leaf.nbytes
        return leaf

    jax.tree_util.tree_map_with_path(acc, state)
    return total
