"""Host offload of the KV pool via JAX memory kinds (the paper's CPU-DRAM
offload, TPU-native).

``fkv.offload == "host"`` places the per-layer pool (and page summaries) in
``pinned_host`` memory; XLA inserts host<->device DMA for the page
scatter (offload path, amortized per completed page) and the recall gather
(the paper's streamed recall). ``"sim"`` keeps the pool in device memory and
accounts transfer costs analytically (benchmarks/_common.py) — the default on
platforms where compute on host-resident buffers is unsupported.

With the overlapped recall pipeline (``core/recall_pipeline``), the host
pool is the *source* of both transfer classes: the correction top-up (the
only host→device DMA the decode step waits on) and the staged speculative
stream that fills the alternate double buffer. Because ``pinned_host``
donation keeps the pool pages page-locked, the staged gather lowers to a
true async DMA on TPU; nothing downstream of attention consumes its result,
so XLA schedules it behind decode compute. ``pool_on_host`` tells the
executor/telemetry whether transfers are real DMAs or simulated
(cost-model) ones.

Usage:
    state = place_decode_state(state, fkv)            # after init/prefill
    shardings = decode_state_shardings(..., fkv=fkv)  # dryrun: memory kinds
"""
from __future__ import annotations

import jax

from repro.configs.base import FreeKVConfig

HOST_KEYS = ("pool",)          # summaries stay in HBM (read every step)


def _host_kind_available() -> bool:
    try:
        kinds = [m.kind for m in jax.devices()[0].addressable_memories()]
        return "pinned_host" in kinds
    except Exception:  # noqa: BLE001
        return False


def host_sharding_for(leaf, mesh=None, spec=None):
    """A sharding equivalent to the leaf's current one but in pinned_host."""
    if mesh is not None and spec is not None:
        return jax.sharding.NamedSharding(mesh, spec,
                                          memory_kind="pinned_host")
    dev = jax.devices()[0]
    return jax.sharding.SingleDeviceSharding(dev, memory_kind="pinned_host")


def place_decode_state(state, fkv: FreeKVConfig, mesh=None, specs=None):
    """Move the pool leaves of a (possibly nested, layer-stacked) decode state
    to pinned_host memory. No-op for offload != 'host' or unsupported hosts."""
    if fkv.offload != "host" or not _host_kind_available():
        return state

    def move(path, leaf):
        key = str(getattr(path[-1], "key", path[-1]))
        if key in HOST_KEYS and hasattr(leaf, "shape"):
            sh = None
            if specs is not None:
                sh = specs
            return jax.device_put(leaf, host_sharding_for(leaf, mesh, sh))
        return leaf

    return jax.tree_util.tree_map_with_path(move, state)


def host_offload_active(fkv: FreeKVConfig) -> bool:
    """Config-level check: would pools be placed in pinned_host memory?
    (Use ``pool_on_host`` for ground truth on an actual state pytree.)"""
    return fkv.offload == "host" and _host_kind_available()


def pool_on_host(state) -> bool:
    """True when the state's pool leaves live in ``pinned_host`` memory —
    i.e. recall transfers are genuine host→device DMAs rather than the
    ``offload='sim'`` cost-model simulation."""
    found = False

    def check(path, leaf):
        nonlocal found
        key = str(getattr(path[-1], "key", path[-1]))
        if key in HOST_KEYS:
            kind = getattr(getattr(leaf, "sharding", None), "memory_kind",
                           None)
            found = found or kind == "pinned_host"
        return leaf

    jax.tree_util.tree_map_with_path(check, state)
    return found


def pool_bytes(state) -> int:
    """Total bytes resident in the (host) pool across layers (telemetry)."""
    total = 0

    def acc(path, leaf):
        nonlocal total
        key = str(getattr(path[-1], "key", path[-1]))
        if key in HOST_KEYS and hasattr(leaf, "nbytes"):
            total += leaf.nbytes
        return leaf

    jax.tree_util.tree_map_with_path(acc, state)
    return total
