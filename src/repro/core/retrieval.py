"""Retrievers: FreeKV (the paper) + the baselines it compares against, behind a
uniform functional API so the model stack and serving engine are method-agnostic:

    r = make_retriever(cfg, fkv)
    state = r.init_state(batch, max_len, dtype)
    state = r.prefill(state, k, v, q_last)         # bulk-insert a prompt
    o, state, info = r.decode(state, q, k_new, v_new[, q_proxy])

Shapes: k/v (B,T,kv,dh) post-RoPE; q (B,H,dh) single decode token.
``info`` carries per-step statistics for the latency cost model (bytes recalled
on/off the critical path, correction counts, similarities).

Methods:
  freekv     speculative retrieval + fine-grained correction (the paper)
  arkvale    fresh selection + blocking recall each step (tau=inf ~ always correct)
  infinigen  selection from a proxy query (prev layer), token-wise recall
  quest      per-q-head (non-group-consistent) selection, no offload
  shadowkv   low-rank keys on device, V-only recall
  raas       dynamic dropping with recency timestamps (no pool)
  streaming  sink + window only (StreamingLLM / Razor-style static)
  full       exact dense cache (oracle)
  centroid   centroid-then-token two-level selection (CTkvr-style) inside
             the FreeKV speculative + correction machinery
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, FreeKVConfig
from repro.core import centroid_index, paging, recall, selection
from repro.core.correction import corrected_heads
from repro.core.recall_pipeline import RecallExecutor, match_resident
from repro.models.layers import softcap
from repro.obs.trace import (SPAN_ATTN_COMPUTE, SPAN_RECALL_CORRECTION,
                             SPAN_RECALL_SELECT, annotate)
from repro.quant import quantizers as qz

NEG_INF = -1e30


def _scale(cfg):
    return cfg.attn_scale if cfg.attn_scale is not None else 1.0 / (cfg.d_head ** 0.5)


def _attend(cfg, q, k_cat, v_cat, pos_cat, cur_pos, window=None,
            fkv=None, use_kernels=False):
    """q (B,H,d); k/v_cat (B,kv,L,d); pos_cat (B,kv,L) -> (B,H,d).

    With ``use_kernels`` (single-device path) this dispatches to the Pallas
    paged-attention kernel (interpret-mode on CPU, Mosaic on TPU)."""
    B, H, d = q.shape
    if (use_kernels and window is None and fkv is not None
            and k_cat.shape[2] % fkv.page_size == 0):
        from repro.kernels import ops
        p = fkv.page_size
        kv_ = k_cat.shape[1]
        G_ = H // kv_
        L = k_cat.shape[2]
        o = ops.paged_attention(
            q.reshape(B, kv_, G_, d),
            k_cat.reshape(B, kv_, L // p, p, d),
            v_cat.reshape(B, kv_, L // p, p, d),
            pos_cat.reshape(B, kv_, L // p, p), cur_pos,
            scale=_scale(cfg), softcap=cfg.attn_logit_softcap,
            interpret=ops.resolve_interpret(fkv))
        return o.reshape(B, H, d)
    kv = k_cat.shape[1]
    G = H // kv
    qg = q.reshape(B, kv, G, d)
    s = jnp.einsum("bkgd,bkld->bkgl", qg, k_cat).astype(jnp.float32) * _scale(cfg)
    s = softcap(s, cfg.attn_logit_softcap)
    ok = (pos_cat >= 0) & (pos_cat <= cur_pos[:, None, None])
    if window is not None:
        ok &= pos_cat > (cur_pos[:, None, None] - window)
    s = jnp.where(ok[:, :, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgl,bkld->bkgd", w.astype(v_cat.dtype), v_cat)
    return o.reshape(B, H, d)


def _window_floor(fkv, length):
    """First position attended via the window ring. Tokens in
    [n_sink, window_floor) are attended via selected pages; the partition
    sink / selected / window is exact (no double counting, no gaps):
    selectable pages are exactly [n_sink//p, window_floor//p)."""
    p = fkv.page_size
    return jnp.maximum(fkv.n_sink // p, (length - fkv.n_window) // p) * p


def _cat_regions(fkv, state, sel_k, sel_v, sel_idx, p):
    """Concatenate sink + window + selected pages per KV head, with the
    three-region position partition applied via pos = -1 masking."""
    B, n_sink, kv, d = state["sink_k"].shape
    n_win = state["win_k"].shape[1]
    length = state["length"]
    wfloor = _window_floor(fkv, length)[:, None, None]           # (B,1,1)
    ks = state["sink_k"].transpose(0, 2, 1, 3)                   # (B,kv,S,d)
    vs = state["sink_v"].transpose(0, 2, 1, 3)
    pos_s = jnp.broadcast_to(jnp.arange(n_sink)[None, None, :], (B, kv, n_sink))
    pos_s = jnp.where(pos_s < length[:, None, None], pos_s, -1)
    kw = state["win_k"].transpose(0, 2, 1, 3)
    vw = state["win_v"].transpose(0, 2, 1, 3)
    pos_w = jnp.broadcast_to(state["win_pos"][:, None, :], (B, kv, n_win))
    pos_w = jnp.where((pos_w >= n_sink) & (pos_w >= wfloor), pos_w, -1)
    n_sel = sel_idx.shape[2]
    kp = sel_k.reshape(B, kv, n_sel * p, d)
    vp = sel_v.reshape(B, kv, n_sel * p, d)
    pos_p = (sel_idx[..., None] * p + jnp.arange(p)[None, None, None, :])
    pos_p = jnp.where(sel_idx[..., None] >= 0, pos_p, -1).reshape(B, kv, n_sel * p)
    pos_p = jnp.where((pos_p >= n_sink) & (pos_p < wfloor), pos_p, -1)
    k_cat = jnp.concatenate([ks, kw, kp], axis=2)
    v_cat = jnp.concatenate([vs, vw, vp], axis=2)
    pos = jnp.concatenate([pos_s, pos_w, pos_p], axis=2).astype(jnp.int32)
    return k_cat, v_cat, pos


def ring_snapshot(state, n_rows: int):
    """Save the ``n_rows`` window-ring slots a drafted block will write.

    A verify pass (``models.serve_step_verify``) appends every drafted row
    into the ring before knowing which rows commit; rows ``>= m`` must then
    be undone so the ring is bitwise what ``m`` sequential appends leave.
    Appends at positions ``length + j`` land in slots ``(length + j) %
    n_win`` — distinct while ``n_rows <= n_win`` — so saving those slots'
    (k, v, pos) beforehand is a complete undo log. Works for any state with
    the ``win_k/win_v/win_pos`` ring contract (FreeKV and streaming)."""
    n_win = state["win_k"].shape[1]
    slots = (state["length"][:, None] + jnp.arange(n_rows)[None]) % n_win
    k = jnp.take_along_axis(state["win_k"], slots[:, :, None, None], axis=1)
    v = jnp.take_along_axis(state["win_v"], slots[:, :, None, None], axis=1)
    pos = jnp.take_along_axis(state["win_pos"], slots, axis=1)
    return slots, k, v, pos


def ring_restore(state, snap, keep):
    """Undo the ring writes of rejected drafted rows.

    ``snap`` is ``ring_snapshot`` taken before the block; ``keep`` (B,) is
    the per-slot committed row count m. Slots written by rows < m keep the
    new content (identical to sequential appends); slots written by rows
    >= m revert to the snapshot. Pool/summary writes by rejected rows are
    deliberately NOT undone: a stale page is never selectable before the
    genuine append rewrites it (selection admits pages < length//p only,
    and the crossing append rewrites first)."""
    slots, k, v, pos = snap
    B, S = slots.shape
    rej = jnp.arange(S)[None, :] >= keep[:, None]                  # (B, S)
    bidx = jnp.arange(B)[:, None]
    cur_k = jnp.take_along_axis(state["win_k"], slots[:, :, None, None], 1)
    cur_v = jnp.take_along_axis(state["win_v"], slots[:, :, None, None], 1)
    cur_p = jnp.take_along_axis(state["win_pos"], slots, axis=1)
    r4 = rej[:, :, None, None]
    return dict(
        state,
        win_k=state["win_k"].at[bidx, slots].set(jnp.where(r4, k, cur_k)),
        win_v=state["win_v"].at[bidx, slots].set(jnp.where(r4, v, cur_v)),
        win_pos=state["win_pos"].at[bidx, slots].set(
            jnp.where(rej, pos, cur_p)))


class FreeKVRetriever:
    """FreeKV (and, by flags, ArkVale / InfiniGen-style baselines)."""

    def __init__(self, cfg: ArchConfig, fkv: FreeKVConfig,
                 speculative: bool = True, proxy_query: bool = False,
                 token_wise_recall: bool = False, mesh=None):
        self.cfg, self.fkv = cfg, fkv
        self.speculative = speculative          # False => ArkVale-style blocking
        self.proxy_query = proxy_query          # True  => InfiniGen-style
        self.token_wise_recall = token_wise_recall
        self.offloaded = True
        self.mesh = mesh                        # enables shard-local recall
        self.use_kernels = fkv.use_kernels and mesh is None
        self.executor = RecallExecutor(recall_fn=self._recall,
                                       values_fn=self._recall_values)

    def _overlap(self):
        """Pipelined (double-buffered) recall applies to the speculative
        single-device path; the sharded path keeps its own fused step."""
        return (self.fkv.recall_overlap and self.speculative
                and self.mesh is None)

    def _pool_view(self, state):
        """The opaque pool reference the recall executor threads through:
        the fp pool array, or a (packed pool, fp32 scales) pair under the
        quantized host tier — every gather backend unpacks the same way."""
        if "pool_scale" in state:
            return (state["pool"], state["pool_scale"])
        return state["pool"]

    def _recall_values(self, pool, idx):
        if isinstance(pool, tuple):                   # quantized host tier
            pool_q, scales = pool
            if self.use_kernels:
                from repro.kernels import ops
                return ops.recall_values_quant(
                    pool_q, scales, idx, bits=self.fkv.quant_bits,
                    chunk=self.fkv.recall_chunk_pages or None,
                    interpret=ops.resolve_interpret(self.fkv))
            return qz.dequant_recall_values(pool_q, scales, idx,
                                            self.fkv.quant_bits)
        if self.use_kernels:
            from repro.kernels import ops
            return ops.recall_values(
                    pool, idx, chunk=self.fkv.recall_chunk_pages or None,
                    interpret=ops.resolve_interpret(self.fkv))
        return recall.recall_values_only(pool, idx)

    def _recall(self, pool, idx):
        if isinstance(pool, tuple):                   # quantized host tier
            # fused dequant-on-recall: packed page + scales move, bf16/fp
            # never does. The page-sharded shard_map gather is fp-only; under
            # a mesh the jnp dequant gather runs (correct under pjit, the
            # partitioner handles it) — see docs/methods.md.
            pool_q, scales = pool
            if self.use_kernels and self.mesh is None:
                from repro.kernels import ops
                return ops.recall_gather_quant(
                    pool_q, scales, idx, bits=self.fkv.quant_bits,
                    chunk=self.fkv.recall_chunk_pages or None,
                    interpret=ops.resolve_interpret(self.fkv))
            return qz.dequant_recall_pages(pool_q, scales, idx,
                                           self.fkv.quant_bits)
        mesh = self.mesh
        if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
            if self.use_kernels:
                from repro.kernels import ops
                return ops.recall_gather(
                    pool, idx, chunk=self.fkv.recall_chunk_pages or None,
                    interpret=ops.resolve_interpret(self.fkv))
            return recall.recall_pages(pool, idx)
        import math as _math
        ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        nb = _math.prod(mesh.shape[a] for a in ba) if ba else 1
        batch_ok = pool.shape[0] % max(nb, 1) == 0 and pool.shape[0] >= nb
        kv_div = self.cfg.n_kv_heads % mesh.shape["model"] == 0
        return recall.recall_pages_sharded(pool, idx, mesh, batch_ok, kv_div)

    # -- state ---------------------------------------------------------
    def init_state(self, batch, max_len, dtype=jnp.bfloat16):
        return paging.init_kv_state(self.cfg, self.fkv, batch, max_len, dtype)

    def _n_sel(self, state):
        return state["sel_idx"].shape[2]

    # -- prefill -------------------------------------------------------
    def prefill(self, state, k, v, q_last):
        """k/v (B,T,kv,d); q_last (B,H,d): the prompt's final query, used for
        the initial speculative selection + recall."""
        B, T = k.shape[:2]
        length = jnp.full((B,), T, jnp.int32)
        state = paging.prefill_fill_pool(state, k, v, length)
        idx, _ = selection.select_pages(
            self.cfg, self.fkv, q_last, state["summ"], state["length"],
            self._n_sel(state))
        sk, sv = self._recall(self._pool_view(state), idx)
        return dict(state, sel_k=sk.astype(state["sel_k"].dtype),
                    sel_v=sv.astype(state["sel_v"].dtype), sel_idx=idx,
                    qprev=q_last.astype(state["qprev"].dtype))

    def _use_sharded(self, state):
        mesh = self.mesh
        if not (self.fkv.sharded_retrieval and mesh is not None
                and "model" in getattr(mesh, "axis_names", ())):
            return False
        if self.fkv.kv_quant != "none":
            # the fused shard-local step reads the fp pool directly; the
            # quantized tier falls back to the plain (pjit-partitioned) path
            return False
        mp = mesh.shape["model"]
        n_sel = state["sel_idx"].shape[2]
        n_pages = state["pool"].shape[1]
        return n_sel % mp == 0 and n_pages % mp == 0

    # -- decode --------------------------------------------------------
    def decode(self, state, q, k_new, v_new, q_proxy=None):
        cfg, fkv = self.cfg, self.fkv
        p = fkv.page_size
        cur_pos = state["length"]                    # position of the new token

        if self._use_sharded(state):             # beyond-paper (§Perf)
            from repro.core.sharded_retrieval import sharded_decode_step
            if self.speculative:
                corr, sim = corrected_heads(cfg, fkv, q, state["qprev"])
                corr = corr | jnp.all(state["qprev"].astype(jnp.float32) == 0)
            else:
                corr = jnp.ones((q.shape[0], cfg.n_kv_heads), bool)
                sim = jnp.zeros((q.shape[0], cfg.n_kv_heads), jnp.float32)
            prev_idx = state["sel_idx"]
            # NOTE: append happens INSIDE the shard body (the page write is
            # masked to its owning shard) — state here is pre-append
            o, updates, new_k, new_v, new_idx = sharded_decode_step(
                cfg, fkv, self.mesh, state, q, k_new, v_new, corr)
            state = dict(state, **updates,
                         sel_k=new_k.astype(state["sel_k"].dtype),
                         sel_v=new_v.astype(state["sel_v"].dtype),
                         sel_idx=new_idx,
                         qprev=q.astype(state["qprev"].dtype))
            n_sel = new_idx.shape[2]
            # speculation quality: the fused step fetches fresh regardless,
            # but selection overlap vs the previous step is still the
            # telemetry of interest (docs/observability.md)
            sel_pages = jnp.sum(new_idx >= 0, axis=(1, 2))
            spec_hit = jnp.sum(match_resident(new_idx, prev_idx)[0],
                               axis=(1, 2))
            info = {"corrected": corr, "similarity": sim,
                    "sync_pages": jnp.sum(corr, axis=1) * n_sel,
                    "async_pages": jnp.sum(~corr, axis=1) * n_sel,
                    "sel_pages": sel_pages,
                    "spec_hit_pages": spec_hit,
                    "churn_pages": sel_pages - spec_hit,
                    "granularity": "page"}
            return o, state, info

        state = paging.append_token(state, k_new, v_new)
        state = self._post_append(state)
        B = q.shape[0]

        if self.speculative:
            with annotate(SPAN_RECALL_CORRECTION):
                corr, sim = corrected_heads(cfg, fkv, q, state["qprev"])
            first_step = state["qprev"].astype(jnp.float32)
            is_cold = jnp.all(first_step == 0)       # no prefill qprev -> correct
            corr = corr | is_cold
        else:                                        # ArkVale/InfiniGen: always fresh
            corr = jnp.ones((B, cfg.n_kv_heads), bool)
            sim = jnp.zeros((B, cfg.n_kv_heads), jnp.float32)

        # --- selection (off critical path for FreeKV: overlaps compute) ----
        q_sel = q
        if self.proxy_query and q_proxy is not None:
            q_sel = q_proxy
        with annotate(SPAN_RECALL_SELECT):
            new_idx, sel_info = self._select_indices(state, q_sel, corr)
        n_sel = new_idx.shape[2]
        reused = jnp.zeros((B,), jnp.int32)
        # speculation quality (repro.obs): how much of the new selection the
        # previous step's speculative buffer already holds
        sel_pages = jnp.sum(new_idx >= 0, axis=(1, 2))
        spec_hit = jnp.sum(match_resident(new_idx, state["sel_idx"])[0],
                           axis=(1, 2))

        if self._overlap():
            # --- pipelined (§4): correction top-up on the critical path,
            # staged double-buffer refill off it (core/recall_pipeline) ----
            pr = self.executor.step(self._pool_view(state), new_idx,
                                    state["sel_idx"],
                                    state["sel_k"], state["sel_v"], corr)
            use_k, use_v, use_idx = pr.use_k, pr.use_v, pr.use_idx
            new_k, new_v = pr.staged_k, pr.staged_v
            sync_pages, async_pages = pr.topup_blocks, pr.staged_blocks
            reused = pr.reused_blocks
        else:
            # --- synchronous reference: full blocking recall every step ----
            new_k, new_v = self.executor.recall(self._pool_view(state),
                                                new_idx)
            new_k = new_k.astype(state["sel_k"].dtype)
            new_v = new_v.astype(state["sel_v"].dtype)
            if self.speculative:                     # correction merge (§3.3)
                m = corr[:, :, None, None, None]
                use_k = jnp.where(m, new_k, state["sel_k"])
                use_v = jnp.where(m, new_v, state["sel_v"])
                use_idx = jnp.where(corr[:, :, None], new_idx, state["sel_idx"])
            else:
                use_k, use_v, use_idx = new_k, new_v, new_idx
            sync_pages = jnp.sum(corr, axis=1) * n_sel
            async_pages = jnp.sum(~corr, axis=1) * n_sel

        k_cat, v_cat, pos = _cat_regions(fkv, state, use_k, use_v, use_idx, p)
        with annotate(SPAN_ATTN_COMPUTE):
            o = _attend(cfg, q, k_cat, v_cat, pos, cur_pos, fkv=fkv,
                        use_kernels=self.use_kernels)

        state = dict(state, sel_k=new_k, sel_v=new_v, sel_idx=new_idx,
                     qprev=q.astype(state["qprev"].dtype))
        info = {
            "corrected": corr, "similarity": sim,
            # (kv-head, page) blocks on the critical path (blocking recall)
            "sync_pages": sync_pages,
            # blocks recalled off the critical path (speculative, overlapped)
            "async_pages": async_pages,
            # blocks served from the resident double buffer (no transfer)
            "reused_pages": reused,
            # speculation quality: selected page slots / buffer hits /
            # pages entering the top-k this step
            "sel_pages": sel_pages,
            "spec_hit_pages": spec_hit,
            "churn_pages": sel_pages - spec_hit,
            "granularity": "token" if self.token_wise_recall else "page",
        }
        info.update(sel_info)
        return o, state, info

    # -- subclass hooks ------------------------------------------------
    def _post_append(self, state):
        """Retriever-owned index maintenance after the token append (the
        centroid retriever keeps its two-level index in sync here)."""
        return state

    def _select_indices(self, state, q_sel, corr):
        """Selection hook -> (new_idx (B, kv, n_sel), extra info). ``corr``
        lets subclasses route corrected heads to an exact scan."""
        new_idx, _ = selection.select_pages(
            self.cfg, self.fkv, q_sel, state["summ"], state["length"],
            self._n_sel(state))
        return new_idx, {}

    # -- speculative-decoding rollback (models.serve_step_verify) -------
    def draft_probe(self, state):
        """Per-row rewind probe the verify scan stacks: the post-step lanes
        (beyond ``length`` and the ring, which have their own undo paths)
        needed to restore an arbitrary committed row's state."""
        return (state["qprev"], state["sel_idx"])

    def draft_rewind(self, state, keep_len, probe):
        """Roll a drafted block back to ``keep_len`` committed tokens.

        ``probe`` is this layer's ``draft_probe`` gathered at the last
        committed row. The selection buffers are rebuilt with ONE staged
        recall of that row's ``sel_idx`` — bitwise what the sequential path
        stored, because pool pages are write-once and both the overlap and
        blocking paths store exactly ``recall(pool, sel_idx)`` content
        (core/recall_pipeline: ``staged == fresh`` holds bit-exactly). That
        recall is simultaneously the draft-ahead prefetch: the next drafted
        block's first verify row reuses it as its resident buffer. Stale
        pool/summary pages written by rejected rows stay (never selectable
        before the genuine append rewrites them); the ring is restored
        separately via ``ring_restore``."""
        qprev, sel_idx = probe
        nk, nv = self.executor.recall(self._pool_view(state), sel_idx)
        return dict(state, length=keep_len, qprev=qprev, sel_idx=sel_idx,
                    sel_k=nk.astype(state["sel_k"].dtype),
                    sel_v=nv.astype(state["sel_v"].dtype))


class CentroidRetriever(FreeKVRetriever):
    """Centroid-then-token selection (CTkvr-style two-level index over the
    page summaries, ``core/centroid_index``): per-step selection scans the
    C cluster bounding boxes plus a bounded candidate set instead of every
    page summary — the ~1M-token regime where the exact scan dominates.

    Runs inside the same speculative-recall + correction machinery:
    speculative selection is two-stage (approximate), while **corrected
    heads always re-select with the exact full scan**, so mis-clustered
    heads are corrected rather than lost. With correction on the greedy
    output is bit-identical to ``freekv`` whenever the candidate set covers
    the exact top-k (structural for the non-softmax pooling modes; see
    docs/methods.md for the softmax-pooling caveat)."""

    def __init__(self, cfg, fkv, mesh=None):
        assert not fkv.sharded_retrieval, \
            "method='centroid' composes with tp_serving, not sharded_retrieval"
        super().__init__(cfg, fkv, speculative=True, mesh=mesh)

    def init_state(self, batch, max_len, dtype=jnp.bfloat16):
        st = super().init_state(batch, max_len, dtype)
        st.update(centroid_index.init_index(
            st["length"].shape[0], st["pool"].shape[1],
            self.fkv.centroid_count, self.cfg.n_kv_heads, self.cfg.d_head,
            st["summ"].dtype))
        return st

    def prefill(self, state, k, v, q_last):
        st = super().prefill(state, k, v, q_last)
        st.update(centroid_index.build(
            st["summ"], st["length"], self.fkv.centroid_count,
            self.fkv.page_size, st["cent"].dtype))
        return st

    def _post_append(self, state):
        return centroid_index.update_on_append(state, self.fkv)

    def _select_indices(self, state, q_sel, corr):
        # Corrected heads re-select via the exact full scan (its cost is
        # charged to corrected heads only — the jnp path computes full-width
        # with masking per repo convention, counts are the source of truth);
        # uncorrected heads take the two-stage centroid selection.
        exact_idx, _ = selection.select_pages(
            self.cfg, self.fkv, q_sel, state["summ"], state["length"],
            self._n_sel(state))
        cent_idx, cand_idx = centroid_index.centroid_select(
            self.cfg, self.fkv, q_sel, state, self._n_sel(state),
            use_kernels=self.use_kernels)
        new_idx = jnp.where(corr[:, :, None], exact_idx, cent_idx)
        return new_idx, {"cand_pages": jnp.sum(cand_idx >= 0, axis=(1, 2))}


class QuestRetriever(FreeKVRetriever):
    """Quest: no offload, per-q-head (non-group-consistent) selection -> G x
    memory traffic; selection+recall are on the critical path."""

    def __init__(self, cfg, fkv):
        super().__init__(cfg, fkv, speculative=False)
        self.offloaded = False

    def decode(self, state, q, k_new, v_new, q_proxy=None):
        cfg, fkv = self.cfg, self.fkv
        p = fkv.page_size
        B, H, d = q.shape
        kv, G = cfg.n_kv_heads, cfg.group_size
        cur_pos = state["length"]
        state = paging.append_token(state, k_new, v_new)
        n_sel = self._n_sel(state)
        scores = selection.page_scores_minmax(q, state["summ"], _scale(cfg))
        valid = selection.selectable_mask(cfg, fkv, state["summ"].shape[1],
                                          state["length"])
        scores = jnp.where(valid[:, None, :], scores, NEG_INF)
        _, idx_h = jax.lax.top_k(scores, n_sel)                   # (B,H,n_sel)
        idx_h = idx_h.astype(jnp.int32)
        # per-q-head gather: emulate by gathering per KV *group member* (G x)
        idx_g = idx_h.reshape(B, kv, G, n_sel)
        outs = []
        for g in range(G):
            sk, sv = self._recall(self._pool_view(state), idx_g[:, :, g])
            k_cat, v_cat, pos = _cat_regions(fkv, state, sk.astype(q.dtype),
                                             sv.astype(q.dtype),
                                             idx_g[:, :, g], p)
            qh = q.reshape(B, kv, G, d)[:, :, g].reshape(B, kv, d)
            outs.append(_attend(cfg, qh, k_cat, v_cat, pos, cur_pos))
        o = jnp.stack(outs, axis=2).reshape(B, kv, G, d).reshape(B, H, d)
        state = dict(state, qprev=q.astype(state["qprev"].dtype))
        info = {"corrected": jnp.ones((B, kv), bool),
                "sync_pages": jnp.full((B,), H * n_sel),
                "async_pages": jnp.zeros((B,), jnp.int32),
                "similarity": jnp.zeros((B, kv)), "granularity": "page"}
        return o, state, info


class StreamingRetriever:
    """Sink + sliding window only (StreamingLLM; Razor-like static dropping).
    Also used for ATTN_LOCAL layers (gemma2) with window = cfg.sliding_window."""

    def __init__(self, cfg, fkv, window=None, n_sink=None):
        self.cfg, self.fkv = cfg, fkv
        self.window = window or fkv.n_window
        self.n_sink = fkv.n_sink if n_sink is None else n_sink
        self.offloaded = False

    def init_state(self, batch, max_len, dtype=jnp.bfloat16):
        cfg = self.cfg
        kv, d = cfg.n_kv_heads, cfg.d_head
        n_win = self.window
        return {
            "sink_k": jnp.zeros((batch, self.n_sink, kv, d), dtype),
            "sink_v": jnp.zeros((batch, self.n_sink, kv, d), dtype),
            "win_k": jnp.zeros((batch, n_win, kv, d), dtype),
            "win_v": jnp.zeros((batch, n_win, kv, d), dtype),
            "win_pos": jnp.full((batch, n_win), -1, jnp.int32),
            "length": jnp.zeros((batch,), jnp.int32),
        }

    def prefill(self, state, k, v, q_last):
        B, T = k.shape[:2]
        n_win = state["win_k"].shape[1]
        dt = state["win_k"].dtype
        tail = jnp.arange(max(T - n_win, 0), T)
        slots = tail % n_win
        st = dict(state)
        st["sink_k"] = k[:, : self.n_sink].astype(dt)
        st["sink_v"] = v[:, : self.n_sink].astype(dt)
        st["win_k"] = state["win_k"].at[:, slots].set(k[:, tail].astype(dt))
        st["win_v"] = state["win_v"].at[:, slots].set(v[:, tail].astype(dt))
        st["win_pos"] = state["win_pos"].at[:, slots].set(
            jnp.broadcast_to(tail, (B, tail.shape[0])).astype(jnp.int32))
        st["length"] = jnp.full((B,), T, jnp.int32)
        return st

    def decode(self, state, q, k_new, v_new, q_proxy=None):
        cfg = self.cfg
        B, H, d = q.shape
        kv = cfg.n_kv_heads
        n_win = state["win_k"].shape[1]
        cur_pos = state["length"]
        slot = cur_pos % n_win
        bidx = jnp.arange(B)
        st = dict(state)
        st["win_k"] = state["win_k"].at[bidx, slot].set(k_new.astype(state["win_k"].dtype))
        st["win_v"] = state["win_v"].at[bidx, slot].set(v_new.astype(state["win_v"].dtype))
        st["win_pos"] = state["win_pos"].at[bidx, slot].set(cur_pos)
        st["length"] = cur_pos + 1
        n_sink = st["sink_k"].shape[1]
        ks = st["sink_k"].transpose(0, 2, 1, 3)
        vs = st["sink_v"].transpose(0, 2, 1, 3)
        pos_s = jnp.broadcast_to(jnp.arange(n_sink)[None, None, :], (B, kv, n_sink))
        pos_s = jnp.where(pos_s < st["length"][:, None, None], pos_s, -1)
        kw = st["win_k"].transpose(0, 2, 1, 3)
        vw = st["win_v"].transpose(0, 2, 1, 3)
        pos_w = jnp.broadcast_to(st["win_pos"][:, None, :], (B, kv, n_win))
        pos_w = jnp.where(pos_w >= n_sink, pos_w, -1)
        k_cat = jnp.concatenate([ks, kw], axis=2)
        v_cat = jnp.concatenate([vs, vw], axis=2)
        pos = jnp.concatenate([pos_s, pos_w], axis=2)
        o = _attend(cfg, q, k_cat, v_cat, pos, cur_pos)
        info = {"corrected": jnp.zeros((B, kv), bool),
                "sync_pages": jnp.zeros((B,), jnp.int32),
                "async_pages": jnp.zeros((B,), jnp.int32),
                "similarity": jnp.zeros((B, kv)), "granularity": "page"}
        return o, st, info

    # -- speculative-decoding rollback (models.serve_step_verify) -------
    def draft_probe(self, state):
        """Sink + ring only: nothing beyond length/ring needs restoring."""
        return ()

    def draft_rewind(self, state, keep_len, probe):
        del probe
        return dict(state, length=keep_len)


class FullRetriever:
    """Exact dense KV cache — the accuracy oracle / no-compression baseline."""

    def __init__(self, cfg, fkv):
        self.cfg, self.fkv = cfg, fkv
        self.offloaded = False

    def init_state(self, batch, max_len, dtype=jnp.bfloat16):
        cfg = self.cfg
        return {
            "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.d_head), dtype),
            "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.d_head), dtype),
            "length": jnp.zeros((batch,), jnp.int32),
        }

    def prefill(self, state, k, v, q_last):
        B, T = k.shape[:2]
        dt = state["k"].dtype
        return dict(
            state,
            k=jax.lax.dynamic_update_slice(state["k"], k.astype(dt), (0, 0, 0, 0)),
            v=jax.lax.dynamic_update_slice(state["v"], v.astype(dt), (0, 0, 0, 0)),
            length=jnp.full((B,), T, jnp.int32))

    def decode(self, state, q, k_new, v_new, q_proxy=None):
        cfg = self.cfg
        B, H, d = q.shape
        kv = cfg.n_kv_heads
        cur_pos = state["length"]
        bidx = jnp.arange(B)
        st = dict(state)
        st["k"] = state["k"].at[bidx, cur_pos].set(k_new.astype(state["k"].dtype))
        st["v"] = state["v"].at[bidx, cur_pos].set(v_new.astype(state["v"].dtype))
        st["length"] = cur_pos + 1
        L = st["k"].shape[1]
        k_cat = st["k"].transpose(0, 2, 1, 3)
        v_cat = st["v"].transpose(0, 2, 1, 3)
        pos = jnp.broadcast_to(jnp.arange(L)[None, None, :], (B, kv, L))
        pos = jnp.where(pos < st["length"][:, None, None], pos, -1)
        o = _attend(cfg, q, k_cat, v_cat, pos, cur_pos)
        info = {"corrected": jnp.zeros((B, kv), bool),
                "sync_pages": jnp.zeros((B,), jnp.int32),
                "async_pages": jnp.zeros((B,), jnp.int32),
                "similarity": jnp.zeros((B, kv)), "granularity": "page"}
        return o, st, info


class RaaSRetriever:
    """RaaS-like dynamic dropping: pages without recent significant attention
    are evicted permanently (timestamp-based, budget-bounded, no pool)."""

    def __init__(self, cfg, fkv):
        self.cfg, self.fkv = cfg, fkv
        self.offloaded = False

    def init_state(self, batch, max_len, dtype=jnp.bfloat16):
        cfg, fkv = self.cfg, self.fkv
        kv, d, p = cfg.n_kv_heads, cfg.d_head, fkv.page_size
        n_keep = max(1, (fkv.budget - fkv.n_sink - fkv.n_window) // p)
        base = StreamingRetriever(cfg, fkv).init_state(batch, max_len, dtype)
        base.update({
            "keep_k": jnp.zeros((batch, kv, n_keep, p, d), dtype),
            "keep_v": jnp.zeros((batch, kv, n_keep, p, d), dtype),
            "keep_idx": jnp.full((batch, kv, n_keep), -1, jnp.int32),
            "last_used": jnp.full((batch, kv, n_keep), -(10 ** 9), jnp.int32),
        })
        return base

    def prefill(self, state, k, v, q_last):
        cfg, fkv = self.cfg, self.fkv
        p = fkv.page_size
        B, T = k.shape[:2]
        st = StreamingRetriever(cfg, fkv).prefill(state, k, v, q_last)
        # seed kept pages with the top pages under the last query (like snapKV)
        n_keep = state["keep_idx"].shape[2]
        n_pages = T // p
        kp = k[:, : n_pages * p].reshape(B, n_pages, p, cfg.n_kv_heads, cfg.d_head)
        summ = jnp.stack([kp.min(2), kp.max(2)], axis=3)
        length = jnp.full((B,), T, jnp.int32)
        scores = selection.page_scores_minmax(q_last, summ, _scale(cfg))
        valid = selection.selectable_mask(cfg, fkv, n_pages, length)
        pooled = selection.group_consistent_scores(cfg, scores, valid,
                                                   fkv.group_pool)
        _, idx = jax.lax.top_k(pooled, n_keep)
        idx = idx.astype(jnp.int32)
        vp = v[:, : n_pages * p].reshape(B, n_pages, p, cfg.n_kv_heads, cfg.d_head)
        pool = paging.nhd_pages_to_hnd(kp, vp)
        kk, vv = recall.recall_pages(pool, idx)
        return dict(st, keep_k=kk.astype(state["keep_k"].dtype),
                    keep_v=vv.astype(state["keep_v"].dtype), keep_idx=idx,
                    last_used=jnp.full_like(state["last_used"], T))

    def decode(self, state, q, k_new, v_new, q_proxy=None):
        cfg, fkv = self.cfg, self.fkv
        p = fkv.page_size
        B, H, d = q.shape
        kv = cfg.n_kv_heads
        cur_pos = state["length"]
        stream = StreamingRetriever(cfg, fkv)
        # attention over sink + window + kept pages
        st = dict(state)
        n_win = st["win_k"].shape[1]
        slot = cur_pos % n_win
        bidx = jnp.arange(B)
        st["win_k"] = st["win_k"].at[bidx, slot].set(k_new.astype(st["win_k"].dtype))
        st["win_v"] = st["win_v"].at[bidx, slot].set(v_new.astype(st["win_v"].dtype))
        st["win_pos"] = st["win_pos"].at[bidx, slot].set(cur_pos)
        st["length"] = cur_pos + 1
        k_cat, v_cat, pos = _cat_regions(
            fkv, {**st}, st["keep_k"], st["keep_v"], st["keep_idx"], p)
        # need attention weights to update timestamps: recompute scores per page
        o = _attend(cfg, q, k_cat, v_cat, pos, cur_pos)
        # page-level attention mass for kept pages (group-mean, like selection)
        n_keep = st["keep_idx"].shape[2]
        G = cfg.group_size
        qg = q.reshape(B, kv, G, d)
        s = jnp.einsum("bkgd,bkld->bkgl", qg, k_cat).astype(jnp.float32) * _scale(cfg)
        s = jnp.where((pos >= 0)[:, :, None, :], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        off = k_cat.shape[2] - n_keep * p
        wp = w[..., off:].reshape(B, kv, G, n_keep, p).sum(-1).mean(2)
        significant = wp > (1.0 / jnp.maximum(st["length"], 1))[:, None, None]
        last_used = jnp.where(significant & (st["keep_idx"] >= 0),
                              st["length"][:, None, None], st["last_used"])
        # when a page completes, insert it by evicting the stalest kept page
        page_done = (st["length"] % p) == 0
        page_idx = st["length"] // p - 1
        tok_pos = page_idx[:, None] * p + jnp.arange(p)[None, :]
        tok_slot = tok_pos % n_win
        pk = jnp.take_along_axis(st["win_k"], tok_slot[:, :, None, None], axis=1)
        pv = jnp.take_along_axis(st["win_v"], tok_slot[:, :, None, None], axis=1)
        evict = jnp.argmin(last_used, axis=2)                      # (B,kv)
        kI = jnp.arange(kv)[None, :]
        bI = bidx[:, None]
        sel = page_done[:, None, None, None]
        newp_k = pk.transpose(0, 2, 1, 3)                          # (B,kv,p,d)
        newp_v = pv.transpose(0, 2, 1, 3)
        keep_k = st["keep_k"].at[bI, kI, evict].set(
            jnp.where(sel, newp_k, st["keep_k"][bI, kI, evict]))
        keep_v = st["keep_v"].at[bI, kI, evict].set(
            jnp.where(sel, newp_v, st["keep_v"][bI, kI, evict]))
        keep_idx = st["keep_idx"].at[bI, kI, evict].set(
            jnp.where(page_done[:, None], page_idx[:, None],
                      st["keep_idx"][bI, kI, evict]).astype(jnp.int32))
        last_used = last_used.at[bI, kI, evict].set(
            jnp.where(page_done[:, None], st["length"][:, None],
                      last_used[bI, kI, evict]))
        st.update(keep_k=keep_k, keep_v=keep_v, keep_idx=keep_idx,
                  last_used=last_used)
        info = {"corrected": jnp.zeros((B, kv), bool),
                "sync_pages": jnp.zeros((B,), jnp.int32),
                "async_pages": jnp.zeros((B,), jnp.int32),
                "similarity": jnp.zeros((B, kv)), "granularity": "page"}
        return o, st, info


class ShadowKVRetriever(FreeKVRetriever):
    """ShadowKV-like: rank-r key representation resident on device (keys are
    reconstructed, not transferred); only V pages are recalled from the pool.
    SVD is computed at prefill (the paper notes ShadowKV does not natively
    support long generation; decoded tokens here stay in the window/sink or are
    recalled normally)."""

    def __init__(self, cfg, fkv):
        super().__init__(cfg, fkv, speculative=False)
        self.rank = min(fkv.svd_rank, cfg.d_head)

    def init_state(self, batch, max_len, dtype=jnp.bfloat16):
        st = super().init_state(batch, max_len, dtype)
        cfg = self.cfg
        n_pages = st["pool"].shape[1]
        p = self.fkv.page_size
        st["k_u"] = jnp.zeros((batch, cfg.n_kv_heads, n_pages * p, self.rank),
                              dtype)
        st["k_w"] = jnp.zeros((batch, cfg.n_kv_heads, self.rank, cfg.d_head),
                              dtype)
        return st

    def prefill(self, state, k, v, q_last):
        st = super().prefill(state, k, v, q_last)
        B, T, kv, d = k.shape
        kf = k.transpose(0, 2, 1, 3).astype(jnp.float32)           # (B,kv,T,d)
        u, s, vt = jnp.linalg.svd(kf, full_matrices=False)
        r = self.rank
        ur = u[..., :r] * s[..., None, :r]                         # (B,kv,T,r)
        wr = vt[..., :r, :]                                        # (B,kv,r,d)
        k_u = jax.lax.dynamic_update_slice(
            st["k_u"], ur.astype(st["k_u"].dtype), (0, 0, 0, 0))
        return dict(st, k_u=k_u, k_w=wr.astype(st["k_w"].dtype))

    def decode(self, state, q, k_new, v_new, q_proxy=None):
        cfg, fkv = self.cfg, self.fkv
        p = fkv.page_size
        B, H, d = q.shape
        kv = cfg.n_kv_heads
        cur_pos = state["length"]
        state = paging.append_token(state, k_new, v_new)
        n_sel = self._n_sel(state)
        with annotate(SPAN_RECALL_SELECT):
            idx, _ = selection.select_pages(
                cfg, fkv, q, state["summ"], state["length"], n_sel)
        # speculation quality: selection overlap vs the previous resident set
        sel_pages = jnp.sum(idx >= 0, axis=(1, 2))
        spec_hit = jnp.sum(match_resident(idx, state["sel_idx"])[0],
                           axis=(1, 2))
        # keys: reconstruct selected pages from the low-rank factors
        safe = jnp.clip(idx, 0, state["pool"].shape[1] - 1)
        tok = safe[..., None] * p + jnp.arange(p)[None, None, None, :]
        bI = jnp.arange(B)[:, None, None, None]
        kI = jnp.arange(kv)[None, :, None, None]
        u_sel = state["k_u"][bI, kI, tok]                          # (B,kv,n_sel,p,r)
        k_rec = jnp.einsum("bkspr,bkrd->bkspd", u_sel.astype(jnp.float32),
                           state["k_w"].astype(jnp.float32))
        k_rec = jnp.where((idx >= 0)[..., None, None], k_rec, 0).astype(q.dtype)
        # values: genuine recall (V half only — ShadowKV's saving)
        if fkv.recall_overlap and self.mesh is None:
            # executor delta-fetch: V pages already resident in the previous
            # step's buffer are reused bit-exactly; only misses transfer
            pr = self.executor.step_values(self._pool_view(state), idx,
                                           state["sel_idx"], state["sel_v"])
            v_sel = pr.staged_v.astype(q.dtype)
            sync_pages = pr.topup_blocks // 2                       # V-only
            reused = pr.reused_blocks // 2
            state = dict(state, sel_v=pr.staged_v)
        else:
            v_sel = self._recall_values(self._pool_view(state),
                                        idx).astype(q.dtype)
            sync_pages = jnp.sum(idx >= 0, axis=(1, 2)) // 2        # V-only
            reused = jnp.zeros((B,), jnp.int32)
        k_cat, v_cat, pos = _cat_regions(fkv, state, k_rec, v_sel, idx, p)
        o = _attend(cfg, q, k_cat, v_cat, pos, cur_pos)
        state = dict(state, sel_idx=idx, qprev=q.astype(state["qprev"].dtype))
        info = {"corrected": jnp.ones((B, kv), bool),
                "sync_pages": sync_pages,
                "async_pages": jnp.zeros((B,), jnp.int32),
                "reused_pages": reused,
                "sel_pages": sel_pages,
                "spec_hit_pages": spec_hit,
                "churn_pages": sel_pages - spec_hit,
                "similarity": jnp.zeros((B, kv)), "granularity": "page"}
        return o, state, info


METHODS = ("freekv", "arkvale", "infinigen", "quest", "shadowkv", "raas",
           "streaming", "full", "centroid")


def make_retriever(cfg: ArchConfig, fkv: FreeKVConfig, mesh=None):
    from repro.core.sharded_retrieval import (TPGroupShardedRetriever,
                                              tp_serving_active)
    if tp_serving_active(cfg, fkv, mesh):
        # serving TP: the plain (mesh-free) retriever for the local KV-head
        # group runs inside a per-layer shard_map — overlap pipeline, quant
        # pool views and kernels all shard-local (core/sharded_retrieval)
        return TPGroupShardedRetriever(
            cfg, fkv, mesh, lambda c: make_retriever(c, fkv, mesh=None))
    m = fkv.method
    if m == "freekv":
        return FreeKVRetriever(cfg, fkv, speculative=True, mesh=mesh)
    if m == "centroid":
        return CentroidRetriever(cfg, fkv, mesh=mesh)
    if m == "arkvale":
        return FreeKVRetriever(cfg, fkv, speculative=False, mesh=mesh)
    if m == "infinigen":
        return FreeKVRetriever(cfg, fkv, speculative=False, proxy_query=True,
                               token_wise_recall=True, mesh=mesh)
    if m == "quest":
        return QuestRetriever(cfg, fkv)
    if m == "shadowkv":
        return ShadowKVRetriever(cfg, fkv)
    if m == "raas":
        return RaaSRetriever(cfg, fkv)
    if m == "streaming":
        return StreamingRetriever(cfg, fkv, window=fkv.budget - fkv.n_sink)
    if m == "full":
        return FullRetriever(cfg, fkv)
    raise ValueError(f"unknown method {m!r}")
