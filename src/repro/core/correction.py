"""Fine-grained correction (§3.3): query-based identification via cosine
similarity of adjacent decode-step queries, group-mean pooled per KV head,
triggering head-wise synchronous recall when C_i < tau.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchConfig, FreeKVConfig


def query_similarity(q, qprev, eps=1e-6):
    """Per-q-head cosine similarity. q, qprev: (B, H, d) -> (B, H) fp32."""
    qf = q.astype(jnp.float32)
    pf = qprev.astype(jnp.float32)
    num = jnp.sum(qf * pf, axis=-1)
    den = jnp.linalg.norm(qf, axis=-1) * jnp.linalg.norm(pf, axis=-1)
    return num / jnp.maximum(den, eps)


def corrected_heads(cfg: ArchConfig, fkv: FreeKVConfig, q, qprev, pool="mean"):
    """Which KV heads need synchronous correction this step.

    Returns (corr (B, kv) bool, sim_grouped (B, kv) fp32). ``pool`` is the
    group-consistency pooling over C_i (App. B.3: mean is the paper's choice;
    max triggers more corrections for the same tau)."""
    B, H, _ = q.shape
    kv = cfg.n_kv_heads
    sim = query_similarity(q, qprev).reshape(B, kv, H // kv)
    g = sim.mean(axis=-1) if pool == "mean" else sim.min(axis=-1)
    # (max pooling over *dissimilarity* == min pooling over similarity)
    return g < fkv.tau, g
