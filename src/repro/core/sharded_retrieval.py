"""Sharded retrieval: two multi-device execution schemes for the FreeKV
decode step.

1. **KV-head-group tensor parallelism** (``TPGroupShardedRetriever``, used by
   ``ServeEngine(tp>1)``): every retrieval-side state leaf is sharded over
   the GQA KV-head dim on a 1-D ``('model',)`` mesh and the entire per-layer
   retrieval step — append/offload, selection, recall (incl. the overlapped
   double-buffer pipeline and the quantized pool view), correction,
   attention — runs shard-local inside one ``shard_map``. Selection stays
   the exact per-head top-k, the only cross-shard transfer is the tiny
   per-head-group attention-output all-gather, and greedy outputs are
   **bit-identical** to the unsharded path (``tests/test_sharded_serving``).

2. **Page-sharded fused decode step** (``sharded_decode_step``, beyond-paper
   §Perf optimization, ``fkv.sharded_retrieval``): described below —
   approximate shard-local selection + LSE-merged partial attention for
   meshes where the KV-head count cannot absorb the model axis
   (long_500k-style sequence parallelism). The two schemes are mutually
   exclusive per config.

The paper's FreeKV runs selection globally, recalls selected pages to one
device, and appends/offloads pages with batch-indexed scatters. Distributed
over a page-sharded pool, the faithful port pays per-layer collectives for
(a) the cross-shard recall gather (masked psum of selected pages),
(b) the pool append scatter (the partitioner emits pool-block all-reduces for
    batch-fancy-indexed updates), and
(c) replicated budget attention on every model shard.

This module keeps the ENTIRE retrieval pipeline shard-local inside one
shard_map over the 'model' axis:

  * window-ring append is computed redundantly (it is model-replicated state);
  * the completed page is written ONLY by its owning page shard (masked);
  * each shard scores only ITS pages and selects top-(n_sel / n_shards)
    locally (an approximation of global top-k: forced spread across shards);
  * recall is a purely local gather;
  * decode attention runs as partials (num, den, max) over the local pages —
    sink/window attended on shard 0 only — merged with one small LSE combine
    (a psum of (B, H, d) + (B, H) instead of page-sized collectives);
  * speculative reuse + per-KV-head correction semantics are preserved
    shard-locally (stale slices live on their owning shard).

Measured on granite-3-8b x decode_32k (16x16 mesh): collective bytes/step
drop from 20.3 GB -> 0.45 GB per device (§Perf log in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ArchConfig, FreeKVConfig
from repro.core import selection
from repro.models.layers import softcap as _softcap

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# KV-head-group tensor parallelism (serving TP)
# ---------------------------------------------------------------------------
def tp_group_size(mesh) -> int:
    """Size of the 'model' axis, or 1 when the mesh has none."""
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return 1
    return mesh.shape["model"]


def tp_serving_active(cfg: ArchConfig, fkv: FreeKVConfig, mesh) -> bool:
    """Should retrieval run as KV-head-group TP on this (cfg, fkv, mesh)?

    Requires the head counts to divide the model axis (every shard owns an
    integral group of KV heads and their G query heads); mutually exclusive
    with the page-sharded fused step."""
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return False
    mp = tp_group_size(mesh)
    return (fkv.tp_serving and not fkv.sharded_retrieval
            and cfg.n_kv_heads % mp == 0 and cfg.n_heads % mp == 0)


def tp_state_specs(cfg: ArchConfig, mesh, state):
    """PartitionSpec tree for one layer's retrieval state under serving TP —
    the single source of truth is ``sharding/rules.decode_state_spec`` (its
    KV-head branch), so the shard_map in_specs can never diverge from the
    slot pool's storage shardings."""
    from repro.sharding import rules

    def f(path, leaf):
        return rules.decode_state_spec(cfg, mesh, rules._path_str(path), leaf)

    return jax.tree_util.tree_map_with_path(f, state)


class TPGroupShardedRetriever:
    """Wrap any pool-backed retriever in a per-layer shard_map over 'model'.

    ``make_inner`` builds the wrapped retriever for a given ArchConfig; it is
    called twice — once with a *local view* config (head counts divided by
    the model-axis size) whose instance runs inside the shard body, and once
    with the global config for state construction. Because every retrieval
    op is per-KV-head (selection top-k, recall gather, correction masks, the
    overlap executor's resident matching, quant dequant, attention softmax),
    the local instance computes exactly the corresponding slice of the
    global computation: outputs are bit-identical to the unwrapped
    retriever, shard count notwithstanding.

    Cross-shard traffic per decode step: one all-gather of the (B, H, d)
    attention output (forced replicated so the following out-projection runs
    as a full replicated matmul — a partial-contraction psum would break
    bit-identity) plus integer psums of the transfer counters. Host->device
    recall traffic is per-head-group: each shard only ever touches its own
    slice of the (possibly host-resident, possibly quantized) pool.

    Works unchanged inside the host-sync-free decode window
    (``models.model.decode_window``): the per-layer shard_map is pure in
    its sharded state, so the while-loop carry donates/aliases the sharded
    leaves in place, the psum'ed counters land in the window's (k, B) stat
    blocks, and — the backbone (hence logits) being replicated — the fused
    on-device sampler draws identical tokens on every shard.
    """

    def __init__(self, cfg: ArchConfig, fkv: FreeKVConfig, mesh, make_inner):
        mp = tp_group_size(mesh)
        assert cfg.n_kv_heads % mp == 0 and cfg.n_heads % mp == 0, (
            f"{cfg.name}: the model axis ({mp}) must divide both head "
            f"counts ({cfg.n_heads}/{cfg.n_kv_heads}) for KV-head-group TP")
        self.cfg, self.fkv, self.mesh, self.mp = cfg, fkv, mesh, mp
        self.local_cfg = dataclasses.replace(
            cfg, n_heads=cfg.n_heads // mp, n_kv_heads=cfg.n_kv_heads // mp)
        self.inner = make_inner(self.local_cfg)
        self._global = make_inner(cfg)
        self.offloaded = getattr(self._global, "offloaded", False)

    # counters summed over (local) KV heads inside the shard body — psum'ed
    # to their exact global integer values (includes the speculation-quality
    # telemetry so per-step hit/churn counts stay exact under tp>1)
    _COUNTERS = ("sync_pages", "async_pages", "reused_pages", "sel_pages",
                 "spec_hit_pages", "churn_pages", "cand_pages")

    def _hspec(self):
        return P(None, "model", None)          # (B, H|kv, d) head-dim shard

    def init_state(self, batch, max_len, dtype=jnp.bfloat16):
        return self._global.init_state(batch, max_len, dtype)

    def prefill(self, state, k, v, q_last):
        sspec = tp_state_specs(self.cfg, self.mesh, state)
        kv_spec = P(None, None, "model", None)            # (B, T, kv, d)

        def body(st, k_l, v_l, q_l):
            return self.inner.prefill(st, k_l, v_l, q_l)

        return shard_map(
            body, mesh=self.mesh,
            in_specs=(sspec, kv_spec, kv_spec, self._hspec()),
            out_specs=sspec, check_vma=False)(state, k, v, q_last)

    def decode(self, state, q, k_new, v_new, q_proxy=None):
        sspec = tp_state_specs(self.cfg, self.mesh, state)
        hq = self._hspec()
        kn_spec = P(None, "model", None)                   # (B, kv, d)
        # q_proxy=None must stay None for the inner retriever (proxy_query
        # methods fall back to q_sel=q on None); a placeholder array rides
        # the shard_map signature but is never consumed in that case
        has_proxy = q_proxy is not None
        if not has_proxy:
            q_proxy = q

        def body(st, q_l, kn_l, vn_l, qp_l):
            o, st2, info = self.inner.decode(
                st, q_l, kn_l, vn_l, q_proxy=qp_l if has_proxy else None)
            B = q_l.shape[0]
            out_info = {"corrected": info["corrected"],
                        "similarity": info["similarity"]}
            for c in self._COUNTERS:
                val = info.get(c, jnp.zeros((B,), jnp.int32))
                out_info[c] = jax.lax.psum(val, "model")
            return o, st2, out_info

        info_spec = {"corrected": P(None, "model"),
                     "similarity": P(None, "model"),
                     **{c: P(None) for c in self._COUNTERS}}
        o, st2, info = shard_map(
            body, mesh=self.mesh,
            in_specs=(sspec, hq, kn_spec, kn_spec, hq),
            out_specs=(hq, sspec, info_spec),
            check_vma=False)(state, q, k_new, v_new, q_proxy)
        # replicate the per-head-group attention outputs — the ONLY
        # cross-shard tensor transfer of the step. The explicit constraint
        # makes the partitioner all-gather o and run the out-projection as a
        # full replicated matmul; left to itself it may choose a
        # partial-contraction + psum, whose float summation order differs
        # from the single-device program.
        o = jax.lax.with_sharding_constraint(
            o, NamedSharding(self.mesh, P()))
        info["granularity"] = ("token" if getattr(self.inner,
                               "token_wise_recall", False) else "page")
        return o, st2, info

    # -- speculative-decoding rollback (models.serve_step_verify) -------
    # Pure data movement (gathers + elementwise dequant, no float
    # reductions), so it runs OUTSIDE the shard_map on the sharded state:
    # the partitioner keeps the kv-head-aligned gathers shard-local and the
    # restored values are bitwise the unsharded ones.
    def draft_probe(self, state):
        return self._global.draft_probe(state)

    def draft_rewind(self, state, keep_len, probe):
        return self._global.draft_rewind(state, keep_len, probe)


def _partial_attend(cfg, q, k_cat, v_cat, pos, cur_pos):
    """Returns LSE-mergeable partials: num (B,kv,G,d), den (B,kv,G), m."""
    B, H, d = q.shape
    kv = k_cat.shape[1]
    G = H // kv
    qg = q.reshape(B, kv, G, d)
    scale = cfg.attn_scale if cfg.attn_scale is not None else 1.0 / (d ** 0.5)
    s = jnp.einsum("bkgd,bkld->bkgl", qg, k_cat).astype(jnp.float32) * scale
    s = _softcap(s, cfg.attn_logit_softcap)
    ok = (pos >= 0) & (pos <= cur_pos[:, None, None])
    s = jnp.where(ok[:, :, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # (B,kv,G)
    e = jnp.exp(s - m[..., None])
    e = jnp.where(ok[:, :, None, :], e, 0.0)
    num = jnp.einsum("bkgl,bkld->bkgd", e, v_cat.astype(jnp.float32))
    den = jnp.sum(e, axis=-1)
    return num, den, m


def sharded_decode_step(cfg: ArchConfig, fkv: FreeKVConfig, mesh, state, q,
                        k_new, v_new, corr):
    """Shard-local append + select + recall + partial attention + LSE merge.

    Returns (o (B,H,d), updates dict) where updates carries the new pool,
    summ, window buffers and sel_* slices (sel_* sharded over n_sel)."""
    mp = mesh.shape["model"]
    p = fkv.page_size
    Bg, H, d = q.shape
    kv = cfg.n_kv_heads
    n_sel = state["sel_idx"].shape[2]
    assert n_sel % mp == 0, (n_sel, mp)
    k_loc = n_sel // mp
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    import math as _math
    nb = _math.prod(mesh.shape[a] for a in ba) if ba else 1
    b = ba if Bg % max(nb, 1) == 0 else None

    def f(pool, summ, sel_k, sel_v, sel_idx, q, corr, k_new, v_new,
          sink_k, sink_v, win_k, win_v, win_pos, length):
        j = jax.lax.axis_index("model")
        B = pool.shape[0]
        n_loc = pool.shape[1]
        lo = j * n_loc
        n_win = win_k.shape[1]
        dt = win_k.dtype
        bidx = jnp.arange(B)

        # ---- window-ring append (model-replicated; identical on all shards)
        cur_pos = length                                # position of new token
        slot = cur_pos % n_win
        win_k = win_k.at[bidx, slot].set(k_new.astype(dt))
        win_v = win_v.at[bidx, slot].set(v_new.astype(dt))
        win_pos = win_pos.at[bidx, slot].set(cur_pos)
        new_len = cur_pos + 1

        # ---- page offload: only the OWNING shard writes (masked, no comms)
        page_done = (new_len % p) == 0
        page_idx = new_len // p - 1
        tok_pos = page_idx[:, None] * p + jnp.arange(p)[None, :]
        tok_slot = tok_pos % n_win
        pk = jnp.take_along_axis(win_k, tok_slot[:, :, None, None], axis=1)
        pv = jnp.take_along_axis(win_v, tok_slot[:, :, None, None], axis=1)
        hnd = jnp.stack([pk.transpose(0, 2, 1, 3), pv.transpose(0, 2, 1, 3)],
                        axis=2)                         # (B,kv,2,p,d)
        psum_ = jnp.stack([pk.min(axis=1), pk.max(axis=1)], axis=2)  # (B,kv,2,d)
        rel = page_idx - lo
        owned = page_done & (rel >= 0) & (rel < n_loc)
        tgt = jnp.clip(rel, 0, n_loc - 1)
        old_p = pool[bidx, tgt]
        old_s = summ[bidx, tgt]
        selm = owned[:, None, None, None, None]
        pool = pool.at[bidx, tgt].set(
            jnp.where(selm, hnd.astype(pool.dtype), old_p))
        summ = summ.at[bidx, tgt].set(
            jnp.where(selm[..., 0], psum_.astype(summ.dtype), old_s))

        # ---- shard-local selection (global page ids = lo + local index)
        scale = cfg.attn_scale if cfg.attn_scale is not None \
            else 1.0 / (d ** 0.5)
        scores = selection.page_scores_minmax(q, summ, scale)  # (B,H,n_loc)
        pages = lo + jnp.arange(n_loc)
        first = fkv.n_sink // p
        n_done = new_len // p
        last = jnp.maximum(first, (new_len - fkv.n_window) // p)
        valid = (pages[None, :] >= first) & (
            pages[None, :] < jnp.minimum(n_done, last)[:, None])
        pooled = selection.group_consistent_scores(cfg, scores, valid,
                                                   fkv.group_pool)
        kk = min(k_loc, n_loc)
        top_s, top_i = jax.lax.top_k(pooled, kk)
        idx_g = jnp.where(top_s > NEG_INF / 2, top_i + lo, -1).astype(jnp.int32)
        if fkv.sharded_overselect > 1:
            # §Perf opt2 mitigation — global re-rank of the per-shard
            # candidates: all-gather (scores, ids) [tiny: B*kv*kk*8 bytes],
            # keep a local candidate iff its global rank < n_sel_target.
            # Exact global top-k whenever each shard's share of the true
            # top-k is <= kk.
            n_target = (kk * mp) // fkv.sharded_overselect
            all_s = jax.lax.all_gather(top_s, "model")     # (mp,B,kv,kk)
            all_s = all_s.transpose(1, 2, 0, 3).reshape(
                top_s.shape[0], kv, mp * kk)
            # rank = number of strictly-greater scores among all candidates
            rank = jnp.sum(all_s[:, :, None, :] > top_s[..., None], axis=-1)
            survive = (rank < n_target) & (idx_g >= 0)
            idx_g = jnp.where(survive, idx_g, -1)

        # ---- local recall (no collective)
        safe = jnp.clip(idx_g - lo, 0, n_loc - 1)
        bI = bidx[:, None, None]
        kI = jnp.arange(kv)[None, :, None]
        blk = pool[bI, safe, kI]
        blk = jnp.where((idx_g >= 0)[..., None, None, None], blk, 0)
        new_k_pages, new_v_pages = blk[..., 0, :, :], blk[..., 1, :, :]

        # ---- speculative reuse per shard slice
        m = corr[:, :, None, None, None]
        use_k = jnp.where(m, new_k_pages, sel_k.astype(new_k_pages.dtype))
        use_v = jnp.where(m, new_v_pages, sel_v.astype(new_v_pages.dtype))
        use_idx = jnp.where(corr[:, :, None], idx_g, sel_idx)

        # ---- partial attention: local pages (+ sink/window on shard 0)
        wfloor = last * p
        kp = use_k.reshape(B, kv, kk * p, d)
        vp = use_v.reshape(B, kv, kk * p, d)
        pos_p = (use_idx[..., None] * p + jnp.arange(p)[None, None, None])
        pos_p = jnp.where(use_idx[..., None] >= 0, pos_p, -1)
        pos_p = pos_p.reshape(B, kv, kk * p)
        pos_p = jnp.where((pos_p >= fkv.n_sink)
                          & (pos_p < wfloor[:, None, None]), pos_p, -1)
        n_sink = sink_k.shape[1]
        ks = sink_k.transpose(0, 2, 1, 3)
        vs = sink_v.transpose(0, 2, 1, 3)
        pos_s = jnp.broadcast_to(jnp.arange(n_sink)[None, None],
                                 (B, kv, n_sink))
        pos_s = jnp.where((pos_s < new_len[:, None, None]) & (j == 0),
                          pos_s, -1)
        kw = win_k.transpose(0, 2, 1, 3)
        vw = win_v.transpose(0, 2, 1, 3)
        pos_w = jnp.broadcast_to(win_pos[:, None], (B, kv, n_win))
        pos_w = jnp.where((pos_w >= n_sink)
                          & (pos_w >= wfloor[:, None, None]) & (j == 0),
                          pos_w, -1)
        k_cat = jnp.concatenate(
            [ks.astype(kp.dtype), kw.astype(kp.dtype), kp], axis=2)
        v_cat = jnp.concatenate(
            [vs.astype(vp.dtype), vw.astype(vp.dtype), vp], axis=2)
        pos = jnp.concatenate([pos_s, pos_w, pos_p], axis=2).astype(jnp.int32)
        num, den, mx = _partial_attend(cfg, q, k_cat, v_cat, pos, cur_pos)

        # ---- LSE merge across page shards (the only collective)
        mg = jax.lax.pmax(mx, "model")
        w = jnp.exp(mx - mg)
        num = jax.lax.psum(num * w[..., None], "model")
        den = jax.lax.psum(den * w, "model")
        o = (num / jnp.maximum(den, 1e-30)[..., None]).reshape(B, H, d)
        return (o.astype(q.dtype), pool, summ, win_k, win_v, win_pos,
                new_k_pages, new_v_pages, idx_g)

    pool_spec = P(b, "model", None, None, None, None)
    summ_spec = P(b, "model", None, None, None)
    sel_spec = P(b, None, "model", None, None)
    idx_spec = P(b, None, "model")
    rep2 = P(b, None)
    rep3 = P(b, None, None)
    rep4 = P(b, None, None, None)
    out = shard_map(
        f, mesh=mesh,
        in_specs=(pool_spec, summ_spec, sel_spec, sel_spec, idx_spec,
                  rep3, rep2, rep3, rep3, rep4, rep4, rep4, rep4, rep2, P(b)),
        out_specs=(rep3, pool_spec, summ_spec, rep4, rep4, rep2,
                   sel_spec, sel_spec, idx_spec),
        check_vma=False,
    )(state["pool"], state["summ"], state["sel_k"], state["sel_v"],
      state["sel_idx"], q, corr, k_new, v_new, state["sink_k"],
      state["sink_v"], state["win_k"], state["win_v"], state["win_pos"],
      state["length"])
    o, pool, summ, win_k, win_v, win_pos, sel_k, sel_v, sel_idx = out
    updates = dict(pool=pool, summ=summ, win_k=win_k, win_v=win_v,
                   win_pos=win_pos, length=state["length"] + 1)
    return o, updates, sel_k, sel_v, sel_idx
