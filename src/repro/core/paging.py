"""KV paging + the paper's hybrid layouts (§4.2).

Host pool uses the **HND** layout ``(B, n_pages, n_kv, 2, p, d)`` — for one
(KV-head, page) the ``(2, p, d)`` K+V block is contiguous, the paper's maximal
transfer unit (2·p·d elements, 16 KiB at p=32, d=128, bf16).

Device-side caches use the **NHD** layout ``(..., p, n_kv, d)`` (token-major) so
appending freshly projected K/V needs no transpose; the NHD→HND transpose happens
once per page at offload time (amortized, off the critical path).

With the quantized host tier (``fkv.kv_quant`` in {"int8", "int4"} —
``src/repro/quant``), the pool stores packed integers and a ``pool_scale``
leaf carries the fp32 per-page scales; pages are quantized exactly where the
NHD→HND transpose already happens (page completion in ``append_token``, bulk
insert in ``prefill_fill_pool``) so quantization cost is amortized off the
decode critical path too. Page *summaries* are computed from the raw keys
before quantization — selection quality is unaffected. The quant parameters
are inferred from the state itself (presence/shape of ``pool_scale``), so
every downstream consumer keeps its signature, and ``kv_quant="none"`` states
carry no extra leaves and trace the exact same graph as before.

All state is a flat dict of arrays so it scans over layers and shards under
pjit. Every leaf keeps the KV-head dim explicit (never folded into another
axis), which is what lets tensor-parallel serving shard the whole dict per
KV-head group (``sharding/rules.decode_state_spec``) and run every op here —
ring append, page completion, quantize-at-offload, the pool scatter —
shard-local inside the TP ``shard_map`` with bit-identical results
(``core/sharded_retrieval.TPGroupShardedRetriever``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, FreeKVConfig
from repro.quant import quantizers as qz


def state_dims(cfg: ArchConfig, fkv: FreeKVConfig, max_len: int):
    p = fkv.page_size
    n_pages = -(-max_len // p)
    m = fkv.pool_pad_pages
    n_pages = -(-n_pages // m) * m
    n_sink = fkv.n_sink
    n_win = fkv.n_window + p          # ring slack so a completing page is present
    n_sel = max(1, (fkv.budget - fkv.n_sink - fkv.n_window) // p)
    if fkv.sharded_retrieval and fkv.sharded_overselect > 1:
        # §Perf opt2 mitigation: extra (invalid-padded) slots so a shard can
        # hold up to overselect x its fair share of globally chosen pages
        n_sel *= fkv.sharded_overselect
    return p, n_pages, n_sink, n_win, n_sel


def quant_info(state):
    """(bits, group_size) of a quantized-pool state, or None when fp.

    Inferred from the state alone: packed int4 pools have half the channel
    width of the device-side buffers, and the scale leaf's group count fixes
    the channel-group size — no config needs threading through the decode
    step."""
    if "pool_scale" not in state:
        return None
    d = state["win_k"].shape[-1]
    bits = 8 if state["pool"].shape[-1] == d else 4
    return bits, d // state["pool_scale"].shape[-1]


def state_bytes(state) -> int:
    """Physical bytes of every leaf of a decode state (packed int8/int4 pool
    payload at its packed width, fp32 scales included) — the unit the serving
    preemption swap accounts, since a swap round-trips the state verbatim."""
    return sum(leaf.nbytes for leaf in jax.tree.leaves(state)
               if hasattr(leaf, "nbytes"))


def init_kv_state(cfg: ArchConfig, fkv: FreeKVConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    """Per-layer FreeKV decode state."""
    p, n_pages, n_sink, n_win, n_sel = state_dims(cfg, fkv, max_len)
    kv, d, H = cfg.n_kv_heads, cfg.d_head, cfg.n_heads
    bits = fkv.quant_bits
    if bits:
        d_packed = d * bits // 8
        n_g = d // qz.effective_group(fkv.quant_group_size, d)
        pool = {"pool": jnp.zeros((batch, n_pages, kv, 2, p, d_packed),
                                  jnp.int8),
                "pool_scale": jnp.zeros((batch, n_pages, kv, 2, n_g),
                                        jnp.float32)}
    else:
        pool = {"pool": jnp.zeros((batch, n_pages, kv, 2, p, d), dtype)}
    return {
        # host pool, HND hybrid layout (offloaded; memory-kind applied by
        # launcher), packed int8/int4 + fp32 scales when kv_quant is on
        **pool,
        # min/max pooled key summaries per page (paper: Quest-style min-max)
        "summ": jnp.zeros((batch, n_pages, kv, 2, d), dtype),
        # device-resident regions (NHD)
        "sink_k": jnp.zeros((batch, n_sink, kv, d), dtype),
        "sink_v": jnp.zeros((batch, n_sink, kv, d), dtype),
        "win_k": jnp.zeros((batch, n_win, kv, d), dtype),
        "win_v": jnp.zeros((batch, n_win, kv, d), dtype),
        "win_pos": jnp.full((batch, n_win), -1, jnp.int32),
        # speculatively recalled pages, per KV head (group-consistent => n_kv)
        "sel_k": jnp.zeros((batch, kv, n_sel, p, d), dtype),
        "sel_v": jnp.zeros((batch, kv, n_sel, p, d), dtype),
        "sel_idx": jnp.full((batch, kv, n_sel), -1, jnp.int32),
        # previous decode step's query vectors (for correction, §3.3)
        "qprev": jnp.zeros((batch, H, d), dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# per-slot state surgery (continuous batching)
# ---------------------------------------------------------------------------
# The decode state's page tables (pool/summ/sel_idx/win_pos/length) carry the
# batch dimension on axis 0 per layer — or axis 1 for period-stacked pattern
# layers. Continuous batching maps logical requests onto physical batch slots
# by functionally splicing one row in or out; XLA lowers these to in-place
# dynamic-update-slices so a slot refill never copies the other slots' pools.
def slot_write_leaf(dst, src, slot, axis=0):
    """Write ``src``'s singleton batch row into ``dst``'s row ``slot``.

    dst (..., B, ...) with batch on ``axis``; src identical but batch size 1;
    ``slot`` may be a traced scalar (one compile serves every slot)."""
    upd = jax.lax.index_in_dim(src, 0, axis, keepdims=False).astype(dst.dtype)
    return jax.lax.dynamic_update_index_in_dim(dst, upd, slot, axis)


def slot_read_leaf(arr, slot, axis=0):
    """Extract row ``slot`` as a singleton-batch array (inverse of write)."""
    return jax.lax.dynamic_index_in_dim(arr, slot, axis, keepdims=True)


# ---------------------------------------------------------------------------
# layout conversions
# ---------------------------------------------------------------------------
def nhd_pages_to_hnd(k_pages, v_pages):
    """(B, n, p, kv, d) K and V -> pool block (B, n, kv, 2, p, d) (HND)."""
    k = k_pages.transpose(0, 1, 3, 2, 4)   # (B,n,kv,p,d)
    v = v_pages.transpose(0, 1, 3, 2, 4)
    return jnp.stack([k, v], axis=3)       # (B,n,kv,2,p,d)


def hnd_to_nhd_kv(block):
    """pool block (B, ..., kv, 2, p, d) -> (k, v) each (B, ..., kv, p, d)."""
    return block[..., 0, :, :], block[..., 1, :, :]


# ---------------------------------------------------------------------------
# bulk (prefill) pool construction
# ---------------------------------------------------------------------------
def prefill_fill_pool(state, k, v, length):
    """Insert a prefill's K/V (B, T, kv, d) into pool + window + sink.

    T must be the (static) prefill length; ``length`` (B,) <= T gives per-row
    valid lengths (rows are right-aligned at position length-1).
    For simplicity rows share T in this framework (continuous batching pads).
    """
    B, T, kv, d = k.shape
    n_pages_total = state["pool"].shape[1]
    p = state["pool"].shape[4]
    n_full = T // p
    kp = k[:, : n_full * p].reshape(B, n_full, p, kv, d)
    vp = v[:, : n_full * p].reshape(B, n_full, p, kv, d)
    hnd = nhd_pages_to_hnd(kp, vp)
    qi = quant_info(state)
    if qi is None:
        pool = jax.lax.dynamic_update_slice(
            state["pool"], hnd.astype(state["pool"].dtype), (0, 0, 0, 0, 0, 0))
        scale_update = {}
    else:
        bits, g = qi
        qblk, qsc = qz.quantize_block(hnd, bits, g)
        pool = jax.lax.dynamic_update_slice(
            state["pool"], qblk, (0, 0, 0, 0, 0, 0))
        scale_update = {"pool_scale": jax.lax.dynamic_update_slice(
            state["pool_scale"], qsc, (0, 0, 0, 0, 0))}
    summ = jnp.stack([kp.min(axis=2), kp.max(axis=2)], axis=3)  # (B,n,kv,2,d)
    summaries = jax.lax.dynamic_update_slice(
        state["summ"], summ.astype(state["summ"].dtype), (0, 0, 0, 0, 0))

    n_sink = state["sink_k"].shape[1]
    n_win = state["win_k"].shape[1]
    sink_k = k[:, :n_sink]
    sink_v = v[:, :n_sink]
    win_k = k[:, T - n_win: T]
    win_v = v[:, T - n_win: T]
    # ring layout: token at absolute position q lives in slot q % n_win
    tail_pos = jnp.arange(T - n_win, T)
    slots = tail_pos % n_win
    wk = jnp.zeros_like(state["win_k"]).at[:, slots].set(win_k.astype(state["win_k"].dtype))
    wv = jnp.zeros_like(state["win_v"]).at[:, slots].set(win_v.astype(state["win_v"].dtype))
    wpos = jnp.full_like(state["win_pos"], -1).at[:, slots].set(
        jnp.broadcast_to(tail_pos, (B, n_win)).astype(jnp.int32))
    return dict(state, pool=pool, summ=summaries, **scale_update,
                sink_k=sink_k.astype(state["sink_k"].dtype),
                sink_v=sink_v.astype(state["sink_v"].dtype),
                win_k=wk, win_v=wv, win_pos=wpos,
                length=jnp.broadcast_to(length, (B,)).astype(jnp.int32))


# ---------------------------------------------------------------------------
# decode-time append + page offload (NHD -> HND transpose amortized here)
# ---------------------------------------------------------------------------
def append_token(state, k_new, v_new):
    """Append one token's K/V (B, kv, d); offload a page when one completes.

    The page completion test is per-row; the pool scatter is masked so rows not
    at a page boundary write nothing (a no-op row writes to its current page
    position with zero-effect data is avoided via index clamping + where).
    """
    B, n_win, kv, d = state["win_k"].shape
    p = state["pool"].shape[4]
    pos = state["length"]                          # (B,) position of new token
    slot = pos % n_win
    bidx = jnp.arange(B)
    win_k = state["win_k"].at[bidx, slot].set(k_new.astype(state["win_k"].dtype))
    win_v = state["win_v"].at[bidx, slot].set(v_new.astype(state["win_v"].dtype))
    win_pos = state["win_pos"].at[bidx, slot].set(pos)

    new_len = pos + 1
    page_done = (new_len % p) == 0                 # (B,)
    page_idx = new_len // p - 1                    # page just completed
    # gather the completed page's tokens from the ring: positions
    # [page_idx*p, page_idx*p + p) -> slots (pos % n_win)
    tok_pos = page_idx[:, None] * p + jnp.arange(p)[None, :]      # (B,p)
    tok_slot = tok_pos % n_win
    pk = jnp.take_along_axis(win_k, tok_slot[:, :, None, None], axis=1)  # (B,p,kv,d)
    pv = jnp.take_along_axis(win_v, tok_slot[:, :, None, None], axis=1)
    hnd = nhd_pages_to_hnd(pk[:, None], pv[:, None])[:, 0]        # (B,kv,2,p,d)
    summ = jnp.stack([pk.min(axis=1), pk.max(axis=1)], axis=2)    # (B,kv,2,d)

    tgt = jnp.where(page_done, page_idx, 0)
    qi = quant_info(state)
    if qi is None:
        blk = hnd.astype(state["pool"].dtype)
        scale_update = {}
    else:
        bits, g = qi
        blk, qsc = qz.quantize_block(hnd, bits, g)        # (B,kv,2,p,dp)
        old_sc_row = jnp.take_along_axis(
            state["pool_scale"], tgt[:, None, None, None, None], axis=1)[:, 0]
        scale_update = {"pool_scale": state["pool_scale"].at[bidx, tgt].set(
            jnp.where(page_done[:, None, None, None], qsc, old_sc_row))}
    old_pool_row = jnp.take_along_axis(
        state["pool"], tgt[:, None, None, None, None, None], axis=1)[:, 0]
    old_summ_row = jnp.take_along_axis(
        state["summ"], tgt[:, None, None, None, None], axis=1)[:, 0]
    sel = page_done[:, None, None, None, None]
    pool = state["pool"].at[bidx, tgt].set(
        jnp.where(sel, blk, old_pool_row))
    summaries = state["summ"].at[bidx, tgt].set(
        jnp.where(sel[..., 0], summ.astype(state["summ"].dtype), old_summ_row))
    return dict(state, win_k=win_k, win_v=win_v, win_pos=win_pos,
                pool=pool, summ=summaries, **scale_update, length=new_len)
