"""Recall primitives: gather selected KV pages from the HND host pool into
NHD device buffers. This is the pure-jnp reference path for the
``(pool, idx) -> (k, v)`` contract; the chunked double-buffered Pallas kernel
(``kernels/recall_gather.py``) implements the same contract with an explicit
2-deep VMEM ring and per-chunk DMA overlap.

Scheduling — *which* pages transfer on vs off the decode critical path
(speculative staging, correction top-up, resident-buffer reuse) — lives one
level up in ``core/recall_pipeline.RecallExecutor``; every retriever routes
its transfers through that executor.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.compat import shard_map


def recall_pages(pool, idx):
    """pool: (B, n_pages, kv, 2, p, d) HND; idx: (B, kv, n_sel) int32 (-1 invalid)
    -> (sel_k, sel_v) each (B, kv, n_sel, p, d) NHD-per-head."""
    B, n_pages, kv, _, p, d = pool.shape
    safe = jnp.clip(idx, 0, n_pages - 1)
    bI = jnp.arange(B)[:, None, None]
    kI = jnp.arange(kv)[None, :, None]
    blk = pool[bI, safe, kI]                      # (B,kv,n_sel,2,p,d)
    blk = jnp.where((idx >= 0)[..., None, None, None], blk, 0)
    return blk[..., 0, :, :], blk[..., 1, :, :]


def recall_values_only(pool, idx):
    """ShadowKV-style: only the V half is transferred (K reconstructed)."""
    B, n_pages, kv, _, p, d = pool.shape
    safe = jnp.clip(idx, 0, n_pages - 1)
    bI = jnp.arange(B)[:, None, None]
    kI = jnp.arange(kv)[None, :, None]
    v = pool[bI, safe, kI, 1]                     # (B,kv,n_sel,p,d)
    return jnp.where((idx >= 0)[..., None, None], v, 0)


def _local_gather(pool, idx):
    B, n_pages, kv = pool.shape[0], pool.shape[1], pool.shape[2]
    safe = jnp.clip(idx, 0, n_pages - 1)
    bI = jnp.arange(B)[:, None, None]
    kI = jnp.arange(kv)[None, :, None]
    blk = pool[bI, safe, kI]
    return jnp.where((idx >= 0)[..., None, None, None], blk, 0)


def recall_pages_sharded(pool, idx, mesh, batch_ok: bool, kv_div: bool):
    """shard_map recall: the GSPMD partitioner turns the fancy gather over a
    sharded pool into a pool-sized masked all-reduce (measured: ~8.6 GB/dev at
    64 devices); doing it shard-local brings collectives to ~0 for
    (batch, kv)-sharded pools and to one selected-pages-sized psum for
    page-sharded pools (long_500k / kv-indivisible archs).
    """
    import jax
    from jax.sharding import PartitionSpec as P

    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    b_spec = ba if batch_ok else None
    if kv_div:
        pool_spec = P(b_spec, None, "model", None, None, None)
        idx_spec = P(b_spec, "model", None)
        out_spec = P(b_spec, "model", None, None, None, None)

        def f(pool_l, idx_l):
            return _local_gather(pool_l, idx_l)

        blk = shard_map(f, mesh=mesh, in_specs=(pool_spec, idx_spec),
                            out_specs=out_spec, check_vma=False)(pool, idx)
    else:
        page_axes = ("model",) if batch_ok else tuple(
            a for a in ("pod", "data", "model") if a in mesh.axis_names)
        pool_spec = P(b_spec, page_axes, None, None, None, None)
        idx_spec = P(b_spec, None, None)
        out_spec = P(b_spec, None, None, None, None, None)

        def f(pool_l, idx_l):
            n_loc = pool_l.shape[1]
            lin = 0
            for a in page_axes:
                lin = lin * mesh.shape[a] + jax.lax.axis_index(a)
            lo = lin * n_loc
            rel = idx_l - lo
            mask = (idx_l >= 0) & (rel >= 0) & (rel < n_loc)
            blk = _local_gather(pool_l, jnp.where(mask, rel, -1))
            return jax.lax.psum(blk, page_axes)

        blk = shard_map(f, mesh=mesh, in_specs=(pool_spec, idx_spec),
                            out_specs=out_spec, check_vma=False)(pool, idx)
    return blk[..., 0, :, :], blk[..., 1, :, :]


def recall_bytes(idx, p, d, itemsize=2, kv_and_v=True):
    """Bytes moved host->device for a recall (cost-model input)."""
    import numpy as np
    n = int(np.sum(np.asarray(idx) >= 0))
    return n * (2 if kv_and_v else 1) * p * d * itemsize
