"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar memory,
sequential) [arXiv:2405.04517].

mLSTM train/prefill uses a flash-style chunked formulation of the stabilized
parallel form (scan over KV chunks carrying (m, num, den)); decode is the O(1)
recurrent update on (C, n, m). sLSTM is inherently sequential -> lax.scan.

Simplifications vs the official block (documented in DESIGN.md): no causal conv
in front of q/k, learnable skip/gate structure reduced to up-proj -> mixer ->
silu(z)-gated down-proj. The FreeKV paper's technique does not apply to these
blocks (no KV cache); they exercise the framework's recurrent-state substrate.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init

NEG_INF = -1e30


def xlstm_dims(cfg: ArchConfig):
    di = int(cfg.xlstm_proj_factor * cfg.d_model)
    nh = cfg.n_heads
    di -= di % nh
    dqk = int(cfg.xlstm_qk_dim_factor * di)
    dqk -= dqk % nh
    return di, nh, di // nh, dqk // nh  # di, heads, dv_head, dqk_head


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_init(key, cfg: ArchConfig, dtype=jnp.float32):
    d = cfg.d_model
    di, nh, dv, dqk = xlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "up": dense_init(ks[0], d, 2 * di, dtype),
        "wq": dense_init(ks[1], di, nh * dqk, dtype),
        "wk": dense_init(ks[2], di, nh * dqk, dtype),
        "wv": dense_init(ks[3], di, nh * dv, dtype),
        "wi": dense_init(ks[4], di, nh, jnp.float32),
        "wf": dense_init(ks[5], di, nh, jnp.float32),
        "bf": jnp.full((nh,), 3.0, jnp.float32),  # forget-gate bias -> remember
        "down": dense_init(ks[6], di, d, dtype),
    }


def _mlstm_qkvif(cfg, p, xm):
    B, T, _ = xm.shape
    di, nh, dv, dqk = xlstm_dims(cfg)
    q = (xm @ p["wq"]).reshape(B, T, nh, dqk) / math.sqrt(dqk)
    k = (xm @ p["wk"]).reshape(B, T, nh, dqk)
    v = (xm @ p["wv"]).reshape(B, T, nh, dv)
    log_i = (xm.astype(jnp.float32) @ p["wi"])                     # (B,T,nh)
    log_f = -jax.nn.softplus(-(xm.astype(jnp.float32) @ p["wf"] + p["bf"]))
    return q, k, v, log_i, log_f


def mlstm_forward(cfg: ArchConfig, p, x, return_state=False, chunk=256):
    """x: (B,T,d) -> (B,T,d). CHUNKWISE-STATE stabilized mLSTM: scan over time
    chunks carrying only (C (nh,dqk,dv), n, m) — O(d^2) state, vs the naive
    kv-chunk scan whose carry holds T-sized accumulators (O(T^2/chunk) bwd
    memory, 200+ GB/dev on xlstm train_4k). Within a chunk the quadratic
    stabilized parallel form runs; across chunks the recurrent state carries.
    """
    B, T, d = x.shape
    di, nh, dv, dqk = xlstm_dims(cfg)
    xm, z = jnp.split(x @ p["up"], 2, axis=-1)
    q, k, v, log_i, log_f = _mlstm_qkvif(cfg, p, xm)

    pad = (-T) % chunk
    if pad:  # pad with log_i = -inf => padded steps update nothing
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=NEG_INF)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    Tk = T + pad
    ncs = Tk // chunk

    def to_chunks(a):
        return a.reshape(B, ncs, chunk, *a.shape[2:]).swapaxes(0, 1)

    qs, ks, vs = to_chunks(q), to_chunks(k), to_chunks(v)
    is_, fs_ = to_chunks(log_i), to_chunks(log_f)
    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]                  # (t, s): s <= t

    @jax.checkpoint
    def body(carry, xs):
        C, n, m = carry                                    # (B,nh,dqk,dv) ...
        qc, kc, vc, ic, fc = xs
        qf = qc.astype(jnp.float32)
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        b = jnp.cumsum(fc, axis=1)                         # (B,chunk,nh)
        # intra-chunk decay logits d_ts = b_t - b_s + i_s  (s <= t)
        dlog = b[:, :, None, :] - b[:, None, :, :] + ic[:, None, :, :]
        dlog = jnp.where(causal[None, :, :, None], dlog, NEG_INF)
        m_intra = jnp.max(dlog, axis=2)                    # (B,chunk,nh)
        # inter-chunk: state contribution decays by b_t from chunk start
        m_inter = b + m[:, None, :]                        # (B,chunk,nh)
        m_t = jnp.maximum(m_intra, m_inter)
        w = jnp.exp(dlog - m_t[:, :, None, :])             # (B,t,s,nh)
        qk = jnp.einsum("bthd,bshd->bhts", qf, kf)
        sw = qk * w.transpose(0, 3, 1, 2)                  # (B,nh,t,s)
        num = jnp.einsum("bhts,bshd->bthd", sw, vf)
        den = jnp.sum(sw, axis=-1).transpose(0, 2, 1)      # (B,t,nh)
        wI = jnp.exp(m_inter - m_t)                        # (B,t,nh)
        num = num + jnp.einsum("bthd,bhde,bth->bthe", qf, C, wI)
        den = den + jnp.einsum("bthd,bhd->bth", qf, n) * wI
        h = num / jnp.maximum(jnp.abs(den),
                              jnp.exp(-m_t))[..., None]    # (B,t,nh,dv)
        # end-of-chunk state update
        bL = b[:, -1, :]                                   # (B,nh)
        m_state = jnp.maximum(bL + m, jnp.max(bL[:, None] - b + ic, axis=1))
        wS = jnp.exp(bL[:, None] - b + ic - m_state[:, None])  # (B,s,nh)
        C_new = (jnp.exp(bL + m - m_state)[:, :, None, None] * C
                 + jnp.einsum("bsh,bshd,bshe->bhde", wS, kf, vf))
        n_new = (jnp.exp(bL + m - m_state)[:, :, None] * n
                 + jnp.einsum("bsh,bshd->bhd", wS, kf))
        return (C_new, n_new, m_state), h.astype(x.dtype)

    C0 = jnp.zeros((B, nh, dqk, dv), jnp.float32)
    n0 = jnp.zeros((B, nh, dqk), jnp.float32)
    m0 = jnp.full((B, nh), NEG_INF, jnp.float32)
    (C, n, m), hs = jax.lax.scan(body, (C0, n0, m0), (qs, ks, vs, is_, fs_))
    h = hs.swapaxes(0, 1).reshape(B, Tk, di)[:, :T]
    out = (h * jax.nn.silu(z)) @ p["down"]
    if return_state:
        return out, {"C": C, "n": n, "m": m}
    return out


def mlstm_init_state(cfg: ArchConfig, batch, dtype=jnp.float32):
    di, nh, dv, dqk = xlstm_dims(cfg)
    return {"C": jnp.zeros((batch, nh, dqk, dv), jnp.float32),
            "n": jnp.zeros((batch, nh, dqk), jnp.float32),
            "m": jnp.full((batch, nh), NEG_INF, jnp.float32)}


def mlstm_decode_step(cfg: ArchConfig, p, x, state):
    """x: (B,1,d) -> (y (B,1,d), state). Stabilized recurrent update."""
    B = x.shape[0]
    di, nh, dv, dqk = xlstm_dims(cfg)
    xm, z = jnp.split(x @ p["up"], 2, axis=-1)
    q, k, v, log_i, log_f = _mlstm_qkvif(cfg, p, xm)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]
    log_i, log_f = log_i[:, 0], log_f[:, 0]                         # (B,nh)
    m_new = jnp.maximum(log_f + state["m"], log_i)
    fw = jnp.exp(log_f + state["m"] - m_new)[..., None]
    iw = jnp.exp(log_i - m_new)[..., None]
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    C = fw[..., None] * state["C"] + iw[..., None] * kf[..., :, None] * vf[..., None, :]
    n = fw * state["n"] + iw * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, di).astype(x.dtype)
    out = ((h * jax.nn.silu(z[:, 0])) @ p["down"])[:, None, :]
    return out, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_init(key, cfg: ArchConfig, dtype=jnp.float32):
    d = cfg.d_model
    di, nh, dv, _ = xlstm_dims(cfg)
    dh = di // nh
    ks = jax.random.split(key, 4)
    return {
        "up": dense_init(ks[0], d, 2 * di, dtype),
        "W": dense_init(ks[1], di, 4 * di, jnp.float32),   # i,f,z,o pre-activations
        "R": (jax.random.normal(ks[2], (nh, 4 * dh, dh)) / math.sqrt(dh)
              ).astype(jnp.float32),
        "b": jnp.concatenate([jnp.zeros((di,)), jnp.full((di,), 3.0),
                              jnp.zeros((2 * di,))]).astype(jnp.float32),
        "down": dense_init(ks[3], di, d, dtype),
    }


def slstm_init_state(cfg: ArchConfig, batch, dtype=jnp.float32):
    di, nh, _, _ = xlstm_dims(cfg)
    z = jnp.zeros((batch, di), jnp.float32)
    return {"h": z, "c": z, "n": jnp.ones((batch, di), jnp.float32),
            "m": jnp.zeros((batch, di), jnp.float32)}


def _slstm_cell(cfg, p, xt, st):
    """xt: (B,di) fp32 pre-activations input; st: state dict."""
    di = xt.shape[-1] // 4 * 0 + st["h"].shape[-1]
    nh = cfg.n_heads
    dh = di // nh
    B = xt.shape[0]
    hh = st["h"].reshape(B, nh, dh)
    rec = jnp.einsum("bhd,hgd->bhg", hh, p["R"]).reshape(B, 4 * di // nh * nh)
    # note: R maps dh -> 4*dh per head; reshape groups per head then interleave
    rec = jnp.einsum("bhd,hgd->bhg", hh, p["R"])            # (B,nh,4dh)
    rec = rec.reshape(B, nh, 4, dh).transpose(0, 2, 1, 3).reshape(B, 4 * di)
    pre = xt + rec + p["b"]
    ig, fg, zg, og = jnp.split(pre, 4, axis=-1)
    log_i = ig
    log_f = -jax.nn.softplus(-fg)
    m_new = jnp.maximum(log_f + st["m"], log_i)
    iw = jnp.exp(log_i - m_new)
    fw = jnp.exp(log_f + st["m"] - m_new)
    c = fw * st["c"] + iw * jnp.tanh(zg)
    n = fw * st["n"] + iw
    h = jax.nn.sigmoid(og) * c / jnp.maximum(n, 1e-6)
    return {"h": h, "c": c, "n": n, "m": m_new}


def slstm_forward(cfg: ArchConfig, p, x, return_state=False):
    B, T, d = x.shape
    di, nh, _, _ = xlstm_dims(cfg)
    xm, z = jnp.split(x @ p["up"], 2, axis=-1)
    pre = xm.astype(jnp.float32) @ p["W"]                    # (B,T,4di)

    def step(st, xt):
        st = _slstm_cell(cfg, p, xt, st)
        return st, st["h"]

    # time-chunked + checkpointed (see ssm.py) — sLSTM is inherently
    # sequential; remat bounds the backward residency to one chunk
    ck = 256
    T_ = pre.shape[1]
    pad = (-T_) % ck
    xs = pre.transpose(1, 0, 2)
    if pad:
        xs = jnp.pad(xs, ((0, pad), (0, 0), (0, 0)))
    nc = (T_ + pad) // ck
    xs = xs.reshape(nc, ck, *xs.shape[1:])

    @jax.checkpoint
    def chunk(st, xs_c):
        return jax.lax.scan(step, st, xs_c)

    st0 = slstm_init_state(cfg, B)
    stT, hs = jax.lax.scan(chunk, st0, xs)
    hs = hs.reshape(nc * ck, B, -1)[:T_]
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    out = (h * jax.nn.silu(z)) @ p["down"]
    if return_state:
        return out, stT
    return out


def slstm_decode_step(cfg: ArchConfig, p, x, state):
    xm, z = jnp.split(x @ p["up"], 2, axis=-1)
    pre = xm[:, 0].astype(jnp.float32) @ p["W"]
    st = _slstm_cell(cfg, p, pre, state)
    out = ((st["h"].astype(x.dtype) * jax.nn.silu(z[:, 0])) @ p["down"])[:, None, :]
    return out, st
