"""Basic layers: norms, RoPE, MLPs, embeddings. Pure-functional, params = dicts."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in, d_out, dtype=jnp.float32):
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def norm_init(cfg: ArchConfig, d, dtype=jnp.float32):
    p = {"w": jnp.ones((d,), dtype)}
    if cfg.norm == "layernorm":
        p["b"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(cfg: ArchConfig, p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (xf * p["w"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(x.dtype)


def softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def act_fn(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# ---------------------------------------------------------------------------
# RoPE (supports partial rotary via rope_fraction)
# ---------------------------------------------------------------------------
def rope_freqs(cfg: ArchConfig, d_head=None):
    d_head = d_head or cfg.d_head
    d_rot = int(d_head * cfg.rope_fraction)
    d_rot -= d_rot % 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot))
    return inv, d_rot


def apply_rope(cfg: ArchConfig, x, positions):
    """x: (..., T, n_heads, d_head); positions: (..., T) int32."""
    inv, d_rot = rope_freqs(cfg, x.shape[-1])
    if d_rot == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., T, d_rot/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = xr[..., : d_rot // 2], xr[..., d_rot // 2:]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rot.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Gated / plain MLP
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ArchConfig, d_in, d_hidden, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d_in, d_hidden, dtype),
         "down": dense_init(ks[1], d_hidden, d_in, dtype)}
    if cfg.gated_mlp:
        p["gate"] = dense_init(ks[2], d_in, d_hidden, dtype)
    return p


def apply_mlp(cfg: ArchConfig, p, x):
    h = x @ p["up"]
    if cfg.gated_mlp:
        h = act_fn(cfg.act)(x @ p["gate"]) * h
    else:
        h = act_fn(cfg.act)(h)
    return h @ p["down"]


# ---------------------------------------------------------------------------
# Embedding / LM head (vocab padded for sharding; padded logits masked)
# ---------------------------------------------------------------------------
def embed_init(key, cfg: ArchConfig, dtype=jnp.float32):
    v = cfg.padded_vocab()
    p = {"tok": (jax.random.normal(key, (v, cfg.d_model), jnp.float32)
                 * (1.0 / jnp.sqrt(cfg.d_model))).astype(dtype)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(jax.random.fold_in(key, 1), cfg.d_model, v, dtype)
    return p


def embed_tokens(cfg: ArchConfig, p, tokens):
    x = jnp.take(p["tok"], tokens, axis=0)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    return x


def lm_logits(cfg: ArchConfig, p, x, mesh=None):
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    logits = x @ w
    if mesh is not None and "model" in mesh.axis_names \
            and logits.shape[-1] % mesh.shape["model"] == 0:
        # shard logits over vocab immediately: softcap/masking/CE then all
        # run vocab-parallel (GSPMD otherwise computes them at full vocab)
        import math as _math
        from jax.sharding import NamedSharding, PartitionSpec as P
        ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        nb = _math.prod(mesh.shape[a] for a in ba) if ba else 1
        bspec = ba if logits.shape[0] % max(nb, 1) == 0 else None
        spec = P(bspec, *([None] * (logits.ndim - 2)), "model")
        logits = jax.lax.with_sharding_constraint(
            logits, NamedSharding(mesh, spec))
    logits = softcap(logits, cfg.final_logit_softcap)
    v, vp = cfg.vocab_size, cfg.padded_vocab()
    if vp != v:
        mask = jnp.arange(vp) < v
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    return logits
