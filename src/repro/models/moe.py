"""Mixture-of-Experts FFN with fine-grained routed experts + shared experts
(DeepSeekMoE-style) and top-k routing (GShard-style capacity, sort-based dispatch).

Distribution: expert-parallel over the ``model`` mesh axis using shard_map with
*replicated activations* — each model shard computes only its local experts for the
tokens routed to them and the outputs are combined with a single psum. On TPU this
replaces the GPU all-to-all with the all-reduce Megatron-style TP already pays,
which is ICI-friendly (see DESIGN.md §5).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ArchConfig
from repro.models.layers import act_fn, dense_init, mlp_init, apply_mlp

CAPACITY_FACTOR = 1.25


def moe_init(key, cfg: ArchConfig, dtype=jnp.float32):
    d, de, E = cfg.d_model, cfg.d_expert or cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 6)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),  # router kept fp32
        "wg": (jax.random.normal(ks[1], (E, d, de)) * scale).astype(dtype),
        "wu": (jax.random.normal(ks[2], (E, d, de)) * scale).astype(dtype),
        "wd": (jax.random.normal(ks[3], (E, de, d)) / math.sqrt(de)).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg, d, de * cfg.n_shared_experts, dtype)
    return p


def _capacity(n_tokens: int, n_experts: int, top_k: int) -> int:
    c = int(math.ceil(n_tokens * top_k / n_experts * CAPACITY_FACTOR))
    return max(4, -(-c // 4) * 4)


def _route(cfg: ArchConfig, router_w, x):
    """x (N,d) -> gates (N,E) fp32, topk_idx (N,k), topk_w (N,k) renormalized."""
    logits = x.astype(jnp.float32) @ router_w
    gates = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(gates, cfg.moe_top_k)
    topk_w = topk_w / jnp.maximum(jnp.sum(topk_w, axis=-1, keepdims=True), 1e-9)
    return gates, topk_idx, topk_w


def _dispatch_local(x, topk_idx, topk_w, e_lo, n_local, capacity):
    """Sort-based dispatch to the local expert slice [e_lo, e_lo+n_local).

    Returns xg (E_loc, C, d), weight (E_loc, C), token ids (E_loc, C) into x
    (value N = padding). Tokens routed to non-local experts are dropped here —
    their owners handle them on other shards.
    """
    N, k = topk_idx.shape
    flat_e = topk_idx.reshape(-1) - e_lo                       # (N*k,)
    flat_w = topk_w.reshape(-1)
    local = (flat_e >= 0) & (flat_e < n_local)
    sort_key = jnp.where(local, flat_e, n_local)               # non-local last
    order = jnp.argsort(sort_key, stable=True)
    se = sort_key[order]                                       # sorted expert ids
    start = jnp.searchsorted(se, jnp.arange(n_local))
    slot = jnp.arange(N * k) - start[jnp.clip(se, 0, n_local - 1)]
    keep = (se < n_local) & (slot < capacity)
    tok = order // k
    e_idx = jnp.where(keep, se, n_local)                       # drop row
    s_idx = jnp.where(keep, slot, 0)
    tok_mat = jnp.full((n_local + 1, capacity), N, jnp.int32)
    tok_mat = tok_mat.at[e_idx, s_idx].set(jnp.where(keep, tok, N).astype(jnp.int32),
                                           mode="drop")
    w_mat = jnp.zeros((n_local + 1, capacity), flat_w.dtype)
    w_mat = w_mat.at[e_idx, s_idx].set(jnp.where(keep, flat_w[order], 0.0),
                                       mode="drop")
    tok_mat, w_mat = tok_mat[:n_local], w_mat[:n_local]
    x_pad = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)], axis=0)
    return x_pad[tok_mat], w_mat, tok_mat


def _expert_ffn(cfg: ArchConfig, wg, wu, wd, xg):
    h = jnp.einsum("ecd,edh->ech", xg, wu)
    if cfg.gated_mlp:
        h = act_fn(cfg.act)(jnp.einsum("ecd,edh->ech", xg, wg)) * h
    else:
        h = act_fn(cfg.act)(h)
    return jnp.einsum("ech,ehd->ecd", h, wd)


def _moe_shard(cfg: ArchConfig, x, router_w, wg, wu, wd, e_lo, capacity):
    """Single-shard MoE over a local expert slice. x: (N, d)."""
    N, d = x.shape
    n_local = wg.shape[0]
    gates, topk_idx, topk_w = _route(cfg, router_w, x)
    xg, w_mat, tok_mat = _dispatch_local(x, topk_idx, topk_w, e_lo, n_local, capacity)
    out = _expert_ffn(cfg, wg, wu, wd, xg)                      # (E_loc, C, d)
    # accumulate in the compute dtype: each token receives <= top_k adds, and
    # the f32 (N, d) accumulator dominates the train_4k backward carry
    y = jnp.zeros((N + 1, d), x.dtype)
    y = y.at[tok_mat].add(out * w_mat[..., None].astype(x.dtype))
    y = y[:N]
    # load-balance aux loss (per-token so the caller can take a global mean)
    E = cfg.n_experts
    onehot = jax.nn.one_hot(topk_idx, E, dtype=jnp.float32).sum(1)  # (N,E)
    f = jnp.mean(onehot, axis=0)
    p_mean = jnp.mean(gates, axis=0)
    aux = E * jnp.sum(f * p_mean) / cfg.moe_top_k
    return y, jnp.full((N,), aux, jnp.float32)


def apply_moe(cfg: ArchConfig, params, x, mesh=None, data_axes=None,
              ep_axis="model"):
    """x: (B,T,d) -> (y (B,T,d), aux (B,T)). EP via shard_map when mesh given."""
    B, T, d = x.shape
    xf = x.reshape(B * T, d)
    if mesh is None or ep_axis not in mesh.axis_names \
            or cfg.n_experts % mesh.shape[ep_axis] != 0:
        cap = _capacity(B * T, cfg.n_experts, cfg.moe_top_k)
        y, aux = _moe_shard(cfg, xf, params["router"], params["wg"], params["wu"],
                            params["wd"], 0, cap)
    else:
        ep = mesh.shape[ep_axis]
        n_local = cfg.n_experts // ep
        if data_axes is None:
            data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        d_axes = tuple(a for a in data_axes if a in mesh.axis_names)
        n_data = math.prod(mesh.shape[a] for a in d_axes) if d_axes else 1
        if (B * T) % max(n_data, 1) != 0:      # tiny batches: replicate tokens
            d_axes, n_data = (), 1
        n_loc_tokens = (B * T) // max(n_data, 1)
        cap = _capacity(n_loc_tokens, cfg.n_experts, cfg.moe_top_k)

        def shard_fn(xl, rw, wg, wu, wd):
            j = jax.lax.axis_index(ep_axis)
            y, aux = _moe_shard(cfg, xl, rw, wg, wu, wd, j * n_local, cap)
            # combine in bf16: the f32 (N, d) psum buffer is 2x the size and
            # shows up replicated in the train_4k memory analysis
            y = jax.lax.psum(y.astype(x.dtype), ep_axis)
            return y, aux

        bspec = P(d_axes if d_axes else None, None)
        y, aux = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(bspec, P(None, None), P(ep_axis, None, None),
                      P(ep_axis, None, None), P(ep_axis, None, None)),
            out_specs=(bspec, P(d_axes if d_axes else None)),
            check_vma=False,
        )(xf, params["router"], params["wg"], params["wu"], params["wd"])
    y = y.astype(x.dtype).reshape(B, T, d)
    if "shared" in params:
        y = y + apply_mlp(cfg, params["shared"], x)
    return y, aux.reshape(B, T)


def capacity_keep_mask(topk_idx, n_experts: int, capacity: int):
    """Which (token, k) routing assignments survive the capacity cut.

    Mirrors ``_dispatch_local``'s arrival order exactly: assignments are
    ranked per expert by flat (token, k) index (the stable sort key), and
    ranks >= capacity are dropped. Returns (N, k) bool."""
    N, k = topk_idx.shape
    flat_e = topk_idx.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
    arrival = jnp.cumsum(onehot, axis=0) - onehot          # exclusive rank
    slot = jnp.take_along_axis(arrival, flat_e[:, None], axis=1)[:, 0]
    return (slot < capacity).reshape(N, k)


def moe_dense_reference(cfg: ArchConfig, params, x):
    """O(E) dense oracle: every expert computes every token (tests only).

    Capacity-aware: assignments ``_dispatch_local`` would drop (per-expert
    arrival rank >= capacity) contribute zero here too, so the oracle matches
    the sort-based dispatch exactly — including under imbalanced routing."""
    B, T, d = x.shape
    xf = x.reshape(B * T, d)
    gates, topk_idx, topk_w = _route(cfg, params["router"], xf)
    cap = _capacity(B * T, cfg.n_experts, cfg.moe_top_k)
    keep = capacity_keep_mask(topk_idx, cfg.n_experts, cap)
    full_w = jnp.zeros_like(gates).at[
        jnp.arange(xf.shape[0])[:, None], topk_idx].set(
        jnp.where(keep, topk_w, 0.0))
    outs = _expert_ffn(cfg, params["wg"], params["wu"], params["wd"],
                       jnp.broadcast_to(xf, (cfg.n_experts,) + xf.shape))
    y = jnp.einsum("ne,end->nd", full_w, outs.astype(jnp.float32))
    y = y.astype(x.dtype).reshape(B, T, d)
    if "shared" in params:
        y = y + apply_mlp(cfg, params["shared"], x)
    return y
