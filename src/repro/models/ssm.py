"""Mamba-1 selective SSM block (for jamba's hybrid layers).

Train/prefill: lax.scan over time (single HLO while-loop, keeps the 512-device
dry-run HLO small). Decode: O(1) recurrent update on (conv_buf, h) state.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init


def mamba_dims(cfg: ArchConfig):
    di = cfg.ssm_expand * cfg.d_model
    dt_rank = max(1, math.ceil(cfg.d_model / 16))
    return di, dt_rank, cfg.ssm_d_state, cfg.ssm_d_conv


def mamba_init(key, cfg: ArchConfig, dtype=jnp.float32):
    d = cfg.d_model
    di, dt_rank, ds, dk = mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (dk, di)) / math.sqrt(dk)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * ds, dtype),
        "dt_w": dense_init(ks[3], dt_rank, di, dtype),
        "dt_b": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(A),                    # fp32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d, dtype),
    }


def _ssm_inputs(cfg, p, xc):
    """xc: (B,T,di) post-conv. Returns dt (B,T,di), Bm, Cm (B,T,ds)."""
    _, dt_rank, ds, _ = mamba_dims(cfg)
    dbl = xc @ p["x_proj"]
    dt, Bm, Cm = jnp.split(dbl, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_w"] + p["dt_b"])
    return dt.astype(jnp.float32), Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def _causal_conv(p, x):
    """depthwise causal conv: x (B,T,di) -> (B,T,di)."""
    dk = p["conv_w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (dk - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1], :] * p["conv_w"][i] for i in range(dk))
    return jax.nn.silu(out + p["conv_b"])


def _di_shard(mesh, a, B):
    """Keep (..., di) mamba activations sharded over 'model' (di = expand*d
    divides the model axis for every assigned arch); GSPMD otherwise
    materializes them replicated + f32 (4.3 GB each on jamba train_4k)."""
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()) \
            or a.shape[-1] % mesh.shape["model"] != 0:
        return a
    import math as _math
    from jax.sharding import NamedSharding, PartitionSpec as P
    ba = tuple(x for x in ("pod", "data") if x in mesh.axis_names)
    nb = _math.prod(mesh.shape[x] for x in ba) if ba else 1
    bspec = ba if B % max(nb, 1) == 0 else None
    spec = P(bspec, *([None] * (a.ndim - 2)), "model")
    return jax.lax.with_sharding_constraint(a, NamedSharding(mesh, spec))


def mamba_forward(cfg: ArchConfig, p, x, return_state=False, mesh=None):
    """x: (B,T,d) -> (B,T,d) [, decode state]."""
    B, T, d = x.shape
    di, _, ds, dk = mamba_dims(cfg)
    xm, z = jnp.split(x @ p["in_proj"], 2, axis=-1)
    xm = _di_shard(mesh, xm, B)
    z = _di_shard(mesh, z, B)
    xc = _causal_conv(p, xm)
    dt, Bm, Cm = _ssm_inputs(cfg, p, xc)
    dt = _di_shard(mesh, dt, B)
    A = -jnp.exp(p["A_log"])                                # (di, ds)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp                               # (B,di),(B,di),(B,ds)
        xt = xt.astype(jnp.float32)
        dA = jnp.exp(dtt[..., None] * A)                    # (B,di,ds)
        h = dA * h + (dtt * xt)[..., None] * Bt[:, None, :]
        y = jnp.einsum("bis,bs->bi", h, Ct) + p["D"] * xt
        return h, y.astype(x.dtype)

    # time-chunked scan with per-chunk gradient checkpointing: a flat scan's
    # backward stores the (B,di,ds) carry for every timestep (4.3 GB/layer at
    # T=4096 for jamba); per-chunk remat keeps only chunk boundaries.
    # xs stay bf16 (upcast per step); h carry is f32 and di-sharded.
    ck = 256
    pad = (-T) % ck
    xs = (xc.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2))
    if pad:
        xs = tuple(jnp.pad(a, ((0, pad), (0, 0), (0, 0))) for a in xs)
    nc = (T + pad) // ck
    xs = tuple(a.reshape(nc, ck, *a.shape[1:]) for a in xs)

    @jax.checkpoint
    def chunk(h, xs_c):
        return jax.lax.scan(step, h, xs_c)

    h0 = _di_shard(mesh, jnp.zeros((B, di, ds), jnp.float32).swapaxes(1, 2),
                   B).swapaxes(1, 2)
    hT, ys = jax.lax.scan(chunk, h0, xs)
    ys = ys.reshape(nc * ck, B, di)[:T]
    y = ys.transpose(1, 0, 2).astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if return_state:
        conv_buf = jnp.pad(xm, ((0, 0), (dk - 1, 0), (0, 0)))[:, -(dk - 1):, :]
        return out, {"h": hT, "conv": conv_buf}
    return out


def mamba_init_state(cfg: ArchConfig, batch, dtype=jnp.float32):
    di, _, ds, dk = mamba_dims(cfg)
    return {"h": jnp.zeros((batch, di, ds), jnp.float32),
            "conv": jnp.zeros((batch, dk - 1, di), dtype)}


def mamba_decode_step(cfg: ArchConfig, p, x, state):
    """x: (B,1,d); state {'h': (B,di,ds), 'conv': (B,dk-1,di)} -> (y, state)."""
    B = x.shape[0]
    di, _, ds, dk = mamba_dims(cfg)
    xm, z = jnp.split(x[:, 0] @ p["in_proj"], 2, axis=-1)   # (B,di)
    win = jnp.concatenate([state["conv"], xm[:, None, :]], axis=1)  # (B,dk,di)
    xc = jax.nn.silu(jnp.einsum("bki,ki->bi", win, p["conv_w"]) + p["conv_b"])
    dt, Bm, Cm = _ssm_inputs(cfg, p, xc[:, None, :])
    dt, Bm, Cm = dt[:, 0], Bm[:, 0], Cm[:, 0]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)
    h = dA * state["h"] + (dt * xc.astype(jnp.float32))[..., None] * Bm[:, None, :]
    y = jnp.einsum("bis,bs->bi", h, Cm) + p["D"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"h": h, "conv": win[:, 1:, :]}
