"""Model assembly: decoder-only / encoder-decoder LMs over heterogeneous block
patterns (attention, sliding attention, MoE, Mamba, mLSTM/sLSTM), with three
entry points used by the launchers and the dry-run:

  forward_train(cfg, params, batch)                 -> (loss, metrics)
  prefill(cfg, fkv, params, batch)                  -> (logits_last, state)
  serve_step(cfg, fkv, params, state, tokens)       -> (logits, state)

Layers are laid out as ``prelude + pattern * n_periods``; the pattern part is
parameter-stacked and driven by ``jax.lax.scan`` so the lowered HLO stays
O(|pattern|) for the 512-device compiles.

Modality frontends (audio frames / vision patches) are STUBS per the assignment
carve-out: ``batch["frontend"]`` carries precomputed embeddings of shape
(B, n_frontend_tokens, d_model).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.configs.base import (ArchConfig, FreeKVConfig, ATTN, ATTN_LOCAL,
                                MAMBA, MLSTM, SLSTM, DENSE, MOE, NONE)
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm, xlstm
from repro.core.retrieval import make_retriever, StreamingRetriever


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------
def _init_layer(key, cfg: ArchConfig, lk, dtype, cross=False):
    mixer, ffn = lk
    ks = jax.random.split(key, 8)
    p = {"norm1": L.norm_init(cfg, cfg.d_model, dtype)}
    if mixer in (ATTN, ATTN_LOCAL):
        p["mixer"] = attn.attn_init(ks[0], cfg, dtype)
    elif mixer == MAMBA:
        p["mixer"] = ssm.mamba_init(ks[0], cfg, dtype)
    elif mixer == MLSTM:
        p["mixer"] = xlstm.mlstm_init(ks[0], cfg, dtype)
    elif mixer == SLSTM:
        p["mixer"] = xlstm.slstm_init(ks[0], cfg, dtype)
    else:
        raise ValueError(mixer)
    if cfg.post_block_norm:
        p["postnorm1"] = L.norm_init(cfg, cfg.d_model, dtype)
    if cross:  # encoder-decoder: cross-attention sublayer
        p["xnorm"] = L.norm_init(cfg, cfg.d_model, dtype)
        p["xattn"] = attn.attn_init(ks[1], cfg, dtype)
    if ffn != NONE:
        p["norm2"] = L.norm_init(cfg, cfg.d_model, dtype)
        if ffn == DENSE:
            p["ffn"] = L.mlp_init(ks[2], cfg, cfg.d_model, cfg.d_ff, dtype)
        else:
            p["ffn"] = moe_mod.moe_init(ks[2], cfg, dtype)
        if cfg.post_block_norm:
            p["postnorm2"] = L.norm_init(cfg, cfg.d_model, dtype)
    return p


def init_params(cfg: ArchConfig, key, dtype=jnp.float32):
    keys = jax.random.split(key, 8)
    params = {
        "embed": L.embed_init(keys[0], cfg, dtype),
        "final_norm": L.norm_init(cfg, cfg.d_model, dtype),
    }
    cross = cfg.is_encoder_decoder
    params["prelude"] = tuple(
        _init_layer(jax.random.fold_in(keys[1], i), cfg, lk, dtype, cross)
        for i, lk in enumerate(cfg.prelude))
    pattern_params = []
    for pos, lk in enumerate(cfg.pattern):
        pks = jax.random.split(jax.random.fold_in(keys[2], pos), cfg.n_periods)
        stacked = jax.vmap(
            lambda k: _init_layer(k, cfg, lk, dtype, cross))(pks)
        pattern_params.append(stacked)
    params["pattern"] = tuple(pattern_params)
    if cfg.is_encoder_decoder:
        eks = jax.random.split(keys[3], cfg.n_encoder_layers)
        params["encoder"] = {
            "layers": jax.vmap(
                lambda k: _init_layer(k, cfg, (ATTN, DENSE), dtype))(eks),
            "final_norm": L.norm_init(cfg, cfg.d_model, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# retrievers per pattern position
# ---------------------------------------------------------------------------
def _retrievers(cfg: ArchConfig, fkv: FreeKVConfig, mesh=None):
    from repro.core.sharded_retrieval import (TPGroupShardedRetriever,
                                              tp_serving_active)
    tp = tp_serving_active(cfg, fkv, mesh)

    def make(lk):
        mixer, _ = lk
        if mixer == ATTN:
            return make_retriever(cfg, fkv, mesh=mesh)   # TP-aware factory
        if mixer == ATTN_LOCAL:
            def mk(c):
                return StreamingRetriever(c, fkv, window=cfg.sliding_window,
                                          n_sink=0)
            if tp:                       # sliding windows shard per KV head too
                return TPGroupShardedRetriever(cfg, fkv, mesh, mk)
            return mk(cfg)
        return None
    return ([make(lk) for lk in cfg.prelude], [make(lk) for lk in cfg.pattern])


def _compute_mesh(fkv: FreeKVConfig, mesh):
    """The mesh the backbone compute (projections, FFN/MoE, norms, logits)
    should see. Under KV-head-group serving TP the backbone is REPLICATED —
    weights and activations identical on every shard; only the retrieval
    state is sharded — so the weight-resharding / sequence-parallel
    constraints are skipped: they would shard the weights and replace
    replicated matmuls with partial-contraction psums, breaking tp-vs-1
    bit-identity."""
    return None if fkv.tp_serving else mesh


# ---------------------------------------------------------------------------
# single-layer application
# ---------------------------------------------------------------------------
def _residual(cfg, p, x, out, which):
    if cfg.post_block_norm:
        out = L.apply_norm(cfg, p["postnorm" + which], out)
    return x + out


def _apply_ffn(cfg, lk, p, x, mesh):
    _, ffn = lk
    if ffn == NONE:
        return x, jnp.zeros(x.shape[:2], jnp.float32)
    h = L.apply_norm(cfg, p["norm2"], x)
    if ffn == DENSE:
        out, aux = L.apply_mlp(cfg, p["ffn"], h), jnp.zeros(x.shape[:2], jnp.float32)
    else:
        out, aux = moe_mod.apply_moe(cfg, p["ffn"], h, mesh=mesh)
    return _residual(cfg, p, x, out, "2"), aux


ROW_PARALLEL_KEYS = ("down", "wo", "wd", "out_proj", "x_proj")


# ``jax.lax.optimization_barrier`` has no differentiation rule on the
# jax-0.4.x line (one landed upstream later). The barrier is semantically the
# identity, so give it one: identity JVP (and therefore identity transpose),
# keeping the primal barrier in the saved-activation path under remat while
# letting gradients flow straight through.
@jax.custom_jvp
def _opt_barrier(x):
    return jax.lax.optimization_barrier(x)


@_opt_barrier.defjvp
def _opt_barrier_jvp(primals, tangents):
    return _opt_barrier(primals[0]), tangents[0]


def _gather_for_compute(cfg, mesh, lp):
    """Force Megatron-style compute shardings on a layer's weights:
    column-parallel (out dim @ model) for up/gate/qkv, row-parallel (in dim @
    model) for down/wo. Without this, GSPMD resolves the FSDP-stored weights
    by partial-contraction + an f32 activation all-reduce (measured 6.4 GB
    per dense-FFN layer on jamba train_4k). MoE expert tensors are left
    alone (shard_map's in_specs do the equivalent)."""
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return lp
    from jax.sharding import NamedSharding, PartitionSpec as P
    mp = mesh.shape["model"]
    head_ok = cfg.n_heads % mp == 0 and cfg.n_kv_heads % mp == 0

    def fix(path, w):
        if not hasattr(w, "ndim") or w.ndim < 2:
            return w
        key = str(getattr(path[-1], "key", path[-1]))
        if key in ("wg", "wu", "wd") and w.ndim == 3:
            return w                              # MoE: shard_map reshards
        wsc = jax.lax.with_sharding_constraint
        if key in ("wq", "wk", "wv", "wo") and not head_ok:
            # heads don't divide the model axis: shard the d_model (input)
            # dim instead — outputs psum to replicated (small for decode-era
            # head counts) and the GRADS stay sharded (replicated grads cost
            # 4.8 GB/dev on jamba train)
            if w.shape[0] % mp == 0:
                return wsc(w, NamedSharding(mesh, P("model", None)))
            return wsc(w, NamedSharding(mesh, P(None, None)))
        if key in ROW_PARALLEL_KEYS:
            if w.shape[0] % mp == 0:
                return wsc(w, NamedSharding(mesh, P("model", None)))
            return wsc(w, NamedSharding(mesh, P(None, None)))
        if w.shape[-1] % mp == 0:
            return wsc(w, NamedSharding(
                mesh, P(*([None] * (w.ndim - 1)), "model")))
        return wsc(w, NamedSharding(mesh, P(*([None] * w.ndim))))

    return jax.tree_util.tree_map_with_path(fix, lp)


def _maybe_seq_shard(cfg, mesh, q):
    """Sequence-parallel attention for archs whose head count does not divide
    the model axis (gemma2 8H, smollm 15H, whisper 6H): shard q (and the
    flash-scan accumulators) over T on 'model' instead of replicating heads —
    16x less redundant attention compute/memory on those archs."""
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return q, None
    mp = mesh.shape["model"]
    if (cfg.n_heads % mp == 0 and cfg.n_kv_heads % mp == 0) \
            or q.shape[1] % mp != 0:
        return q, None
    import math as _math
    from jax.sharding import NamedSharding, PartitionSpec as P
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    nb = _math.prod(mesh.shape[a] for a in ba) if ba else 1
    bspec = ba if q.shape[0] % max(nb, 1) == 0 else None
    spec = NamedSharding(mesh, P(bspec, "model", None, None))
    return jax.lax.with_sharding_constraint(q, spec), spec


def _bshard(mesh, x):
    """Pin the residual stream's batch sharding. GSPMD loses it through the
    recurrent scans / odd-dim reshapes (measured: full global-batch f32
    activations on xlstm/stablelm train_4k) — one constraint per layer
    boundary keeps every downstream activation batch-sharded."""
    if mesh is None:
        return x
    import math as _math
    from jax.sharding import NamedSharding, PartitionSpec as P
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    nb = _math.prod(mesh.shape[a] for a in ba) if ba else 1
    if not ba or x.shape[0] % nb != 0:
        return x
    # batch pinned, everything else UNCONSTRAINED: a full P(ba, None, None)
    # would force T/d replicated and blow up the remat stack (internvl2:
    # 24 -> 152 GB/dev measured)
    unc = [P.UNCONSTRAINED] * (x.ndim - 1)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(ba, *unc)))


def _apply_layer_seq(cfg, lk, p, x, positions, mesh=None, enc_out=None,
                     window_override=None):
    """Full-sequence (train / prefill compute) path. Returns (x, aux, extras)
    where extras carries what prefill needs (q_last, k, v post-rope)."""
    mixer, _ = lk
    x = _bshard(mesh, x)
    p = _gather_for_compute(cfg, mesh, p)
    h = L.apply_norm(cfg, p["norm1"], x)
    extras = {}
    if mixer in (ATTN, ATTN_LOCAL):
        q, k, v = attn.qkv_proj(cfg, p["mixer"], h, positions)
        window = cfg.sliding_window if mixer == ATTN_LOCAL else None
        q, seq_spec = _maybe_seq_shard(cfg, mesh, q)
        o = attn.attention_auto(cfg, q, k, v, positions, positions,
                                causal=True, window=window)
        if seq_spec is not None:
            o = jax.lax.with_sharding_constraint(o, seq_spec)
        out = attn.out_proj(cfg, p["mixer"], o)
        extras = {"q_last": q[:, -1], "k": k, "v": v}
    elif mixer == MAMBA:
        out, st = ssm.mamba_forward(cfg, p["mixer"], h, return_state=True,
                                    mesh=mesh)
        extras = {"state": st}
    elif mixer == MLSTM:
        out, st = xlstm.mlstm_forward(cfg, p["mixer"], h, return_state=True)
        extras = {"state": st}
    elif mixer == SLSTM:
        out, st = xlstm.slstm_forward(cfg, p["mixer"], h, return_state=True)
        extras = {"state": st}
    x = _residual(cfg, p, x, out, "1")
    if enc_out is not None:  # encoder-decoder cross-attention
        hx = L.apply_norm(cfg, p["xnorm"], x)
        qx, _, _ = attn.qkv_proj(cfg, p["xattn"], hx, positions, rope=False)
        ek, ev, epos = enc_out
        o = attn.attention_dense(cfg, qx, ek, ev, positions, epos, causal=False)
        x = x + attn.out_proj(cfg, p["xattn"], o)
        extras["xk"], extras["xv"] = ek, ev
    x, aux = _apply_ffn(cfg, lk, p, x, mesh)
    return x, aux, extras


# ---------------------------------------------------------------------------
# encoder (whisper): bidirectional attention over frontend embeddings
# ---------------------------------------------------------------------------
def _encode(cfg: ArchConfig, params, frontend):
    B, F, _ = frontend.shape
    positions = jnp.broadcast_to(jnp.arange(F)[None], (B, F))
    x = frontend

    def body(x, lp):
        h = L.apply_norm(cfg, lp["norm1"], x)
        q, k, v = attn.qkv_proj(cfg, lp["mixer"], h, positions)
        o = attn.attention_auto(cfg, q, k, v, positions, positions, causal=False)
        x = x + attn.out_proj(cfg, lp["mixer"], o)
        x, _ = _apply_ffn(cfg, (ATTN, DENSE), lp, x, None)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return L.apply_norm(cfg, params["encoder"]["final_norm"], x)


def _enc_kv(cfg, lp, enc_x):
    """Cross-attention K/V from encoder output for one decoder layer."""
    B, F, _ = enc_x.shape
    pos = jnp.broadcast_to(jnp.arange(F)[None], (B, F))
    _, k, v = attn.qkv_proj(cfg, lp["xattn"], enc_x, pos, rope=False)
    return k, v, pos


# ---------------------------------------------------------------------------
# embedding of a batch (tokens [+ frontend prefix for VLM-style archs])
# ---------------------------------------------------------------------------
def _embed_inputs(cfg: ArchConfig, params, batch):
    tokens = batch["tokens"]
    x = L.embed_tokens(cfg, params["embed"], tokens)
    n_front = 0
    if (cfg.frontend is not None and not cfg.is_encoder_decoder
            and "frontend" in batch):
        fe = batch["frontend"].astype(x.dtype)
        x = jnp.concatenate([fe, x], axis=1)
        n_front = fe.shape[1]
    B, T = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    return x, positions, n_front


# ---------------------------------------------------------------------------
# train forward
# ---------------------------------------------------------------------------
def forward_train(cfg: ArchConfig, params, batch, mesh=None, remat=True):
    """batch: tokens (B,T), loss_mask (B,T) optional, frontend optional."""
    x, positions, n_front = _embed_inputs(cfg, params, batch)
    enc_x = None
    if cfg.is_encoder_decoder:
        enc_x = _encode(cfg, params, batch["frontend"])

    aux_total = 0.0
    for lp, lk in zip(params["prelude"], cfg.prelude):
        enc = _enc_kv(cfg, lp, enc_x) if enc_x is not None else None
        x, aux, _ = _apply_layer_seq(cfg, lk, lp, x, positions, mesh, enc)
        aux_total += aux.mean()

    def period(x, lps):
        aux_p = 0.0
        for pos_i, lk in enumerate(cfg.pattern):
            lp = lps[pos_i]
            enc = _enc_kv(cfg, lp, enc_x) if enc_x is not None else None
            x, aux, _ = _apply_layer_seq(cfg, lk, lp, x, positions, mesh, enc)
            aux_p += aux.mean()
        return x, aux_p

    body = jax.checkpoint(period) if remat else period

    def _shard_saved(x):
        # sequence-parallel activation checkpointing: what enters the remat
        # region is what gets SAVED for backward — shard its T dim over
        # 'model' (16x smaller stack) and barrier so XLA cannot hoist an f32
        # convert into the save (2x, measured on stablelm train_4k)
        if mesh is not None and "model" in mesh.axis_names \
                and x.shape[1] % mesh.shape["model"] == 0:
            import math as _math
            from jax.sharding import NamedSharding, PartitionSpec as P
            ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            nb = _math.prod(mesh.shape[a] for a in ba) if ba else 1
            bspec = ba if x.shape[0] % max(nb, 1) == 0 else None
            x = jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(bspec, "model", None)))
        return _opt_barrier(x)

    def scan_body(x, lps):
        return body(_shard_saved(x), lps)

    x, auxs = jax.lax.scan(scan_body, x, params["pattern"])
    aux_total += auxs.sum()

    x = _bshard(mesh, L.apply_norm(cfg, params["final_norm"], x))
    logits = L.lm_logits(cfg, params["embed"], x[:, n_front:], mesh=mesh)
    tokens = batch["tokens"]
    tgt = tokens[:, 1:]
    per_tok = _cross_entropy(cfg, mesh, logits[:, :-1], tgt)
    mask = batch.get("loss_mask", jnp.ones_like(tokens))[:, 1:]
    ce = jnp.sum(per_tok * mask) / jnp.maximum(jnp.sum(mask), 1)
    loss = ce + cfg.router_aux_loss * aux_total
    return loss, {"ce": ce, "aux": aux_total,
                  "tokens": jnp.sum(mask)}


def _cross_entropy(cfg, mesh, logits, tgt):
    """Per-token CE. Under a mesh this is VOCAB-PARALLEL via shard_map:
    logits stay sharded (B, T, V/model) through fwd AND bwd — GSPMD otherwise
    replicates the (B,T,V) f32 logits cotangent per device (measured
    202 GB/dev for gemma2's 256K vocab at train_4k)."""
    if mesh is None or "model" not in mesh.axis_names \
            or logits.shape[-1] % mesh.shape["model"] != 0:
        lg = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        ll = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
        return lse - ll

    import math as _math
    from jax.sharding import PartitionSpec as P
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    nb = _math.prod(mesh.shape[a] for a in ba) if ba else 1
    bspec = ba if logits.shape[0] % max(nb, 1) == 0 else None
    V_loc = logits.shape[-1] // mesh.shape["model"]

    def ce_shard(lg, t):
        j = jax.lax.axis_index("model")
        lg = lg.astype(jnp.float32)
        # stop_gradient: max-shift cancels in the lse gradient; pmax has no
        # differentiation rule
        m = jax.lax.pmax(jnp.max(jax.lax.stop_gradient(lg), axis=-1),
                         "model")
        e = jnp.exp(lg - m[..., None])
        s = jax.lax.psum(jnp.sum(e, axis=-1), "model")
        lse = m + jnp.log(s)
        rel = t - j * V_loc
        hit = (rel >= 0) & (rel < V_loc)
        ll_loc = jnp.take_along_axis(
            lg, jnp.clip(rel, 0, V_loc - 1)[..., None], axis=-1)[..., 0]
        ll = jax.lax.psum(jnp.where(hit, ll_loc, 0.0), "model")
        return lse - ll

    return shard_map(
        ce_shard, mesh=mesh,
        in_specs=(P(bspec, None, "model"), P(bspec, None)),
        out_specs=P(bspec, None), check_vma=False)(logits, tgt)


# ---------------------------------------------------------------------------
# prefill: run the prompt, build per-layer decode states
# ---------------------------------------------------------------------------
def _init_layer_state(cfg, fkv, lk, retr, batch_size, max_len, dtype,
                      enc_shape=None):
    mixer, _ = lk
    if mixer in (ATTN, ATTN_LOCAL):
        st = retr.init_state(batch_size, max_len, dtype)
        if cfg.is_encoder_decoder:
            F = enc_shape
            st["xk"] = jnp.zeros((batch_size, F, cfg.n_kv_heads, cfg.d_head), dtype)
            st["xv"] = jnp.zeros((batch_size, F, cfg.n_kv_heads, cfg.d_head), dtype)
        return st
    if mixer == MAMBA:
        return ssm.mamba_init_state(cfg, batch_size, dtype)
    if mixer == MLSTM:
        return xlstm.mlstm_init_state(cfg, batch_size, dtype)
    if mixer == SLSTM:
        return xlstm.slstm_init_state(cfg, batch_size, dtype)
    raise ValueError(mixer)


def init_decode_state(cfg: ArchConfig, fkv: FreeKVConfig, batch_size: int,
                      max_len: int, dtype=jnp.bfloat16):
    pre_r, pat_r = _retrievers(cfg, fkv)
    F = cfg.n_frontend_tokens or None
    pre = tuple(_init_layer_state(cfg, fkv, lk, r, batch_size, max_len, dtype, F)
                for lk, r in zip(cfg.prelude, pre_r))
    pat = []
    for lk, r in zip(cfg.pattern, pat_r):
        one = _init_layer_state(cfg, fkv, lk, r, batch_size, max_len, dtype, F)
        pat.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_periods,) + a.shape), one))
    out = {"prelude": pre, "pattern": tuple(pat),
           "pos": jnp.zeros((batch_size,), jnp.int32)}
    if fkv.draft_len > 0:
        from repro.core import drafter
        out["draft_tab"] = drafter.init_draft_tab(batch_size, cfg.vocab_size)
    return out


def _prefill_layer_state(cfg, fkv, lk, retr, extras, max_len, dtype, enc=None):
    mixer, _ = lk
    if mixer in (ATTN, ATTN_LOCAL):
        B = extras["k"].shape[0]
        st = retr.init_state(B, max_len, dtype)
        st = retr.prefill(st, extras["k"], extras["v"], extras["q_last"])
        if enc is not None:
            st["xk"], st["xv"] = (extras["xk"].astype(dtype),
                                  extras["xv"].astype(dtype))
        return st
    return extras["state"]


def prefill(cfg: ArchConfig, fkv: FreeKVConfig, params, batch, max_len: int,
            mesh=None, state_dtype=jnp.bfloat16, return_kv=False,
            build_state: bool = True):
    """Returns (last-position logits (B, vocab), decode state).

    With ``return_kv`` also returns the per-layer post-RoPE K/V of the prompt
    ({"prelude": ((k, v) | None, ...), "pattern": ((k, v) stacked over
    periods, ...)}) for the serving prefix cache; non-attention mixers yield
    None entries. ``build_state=False`` skips the retriever state build and
    returns ``state=None`` — the chunked-prefill opening chunk uses it (with
    ``return_kv``) when more chunks follow: its state would be rebuilt from
    the accumulated K/V at the final chunk anyway, and tiny opening chunks
    need not satisfy the paged-state layout's minimum prompt span."""
    x, positions, n_front = _embed_inputs(cfg, params, batch)
    enc_x = _encode(cfg, params, batch["frontend"]) if cfg.is_encoder_decoder \
        else None
    pre_r, pat_r = _retrievers(cfg, fkv, mesh)
    cmesh = _compute_mesh(fkv, mesh)

    def _kv_of(lk, ex):
        return (ex["k"], ex["v"]) if lk[0] in (ATTN, ATTN_LOCAL) else None

    pre_states, pre_kv = [], []
    for lp, lk, r in zip(params["prelude"], cfg.prelude, pre_r):
        enc = _enc_kv(cfg, lp, enc_x) if enc_x is not None else None
        x, _, ex = _apply_layer_seq(cfg, lk, lp, x, positions, cmesh, enc)
        if build_state:
            pre_states.append(_prefill_layer_state(
                cfg, fkv, lk, r, ex, max_len, state_dtype, enc))
        pre_kv.append(_kv_of(lk, ex))

    def scan_body(x, lps):
        sts, kvs = [], []
        for pos_i, lk in enumerate(cfg.pattern):
            lp = lps[pos_i]
            enc = _enc_kv(cfg, lp, enc_x) if enc_x is not None else None
            x, _, ex = _apply_layer_seq(cfg, lk, lp, x, positions, cmesh, enc)
            if build_state:
                sts.append(_prefill_layer_state(cfg, fkv, lk, pat_r[pos_i],
                                                ex, max_len, state_dtype, enc))
            kvs.append(_kv_of(lk, ex) if return_kv else None)
        return x, (tuple(sts), tuple(kvs))

    x, (pat_states, pat_kv) = jax.lax.scan(scan_body, x, params["pattern"])
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["embed"], x[:, -1])
    B, T = x.shape[:2]
    state = None if not build_state else {
        "prelude": tuple(pre_states), "pattern": pat_states,
        "pos": jnp.full((B,), T, jnp.int32)}
    if return_kv:
        return logits, state, {"prelude": tuple(pre_kv), "pattern": pat_kv}
    return logits, state


# ---------------------------------------------------------------------------
# prefill extension: run only a prompt suffix over cached prefix K/V
# ---------------------------------------------------------------------------
def supports_kv_extend(cfg: ArchConfig) -> bool:
    """Prefix-cache extension needs every token's context to live in K/V form:
    attention-only stacks, no encoder-decoder cross state, no frontend prefix.
    Recurrent mixers (mamba/xlstm) compress history into a state that cannot
    be sliced per token, so those configs take the full-prefill path."""
    return (not cfg.is_encoder_decoder and cfg.frontend is None
            and all(m in (ATTN, ATTN_LOCAL) for m, _ in cfg.layers))


def _apply_layer_extend(cfg, lk, lp, x, q_pos, kv_pos, pk, pv, mesh):
    """One layer of suffix prefill: queries at q_pos attend over cached prefix
    K/V concatenated with the suffix's fresh K/V."""
    mixer, _ = lk
    x = _bshard(mesh, x)
    lp = _gather_for_compute(cfg, mesh, lp)
    h = L.apply_norm(cfg, lp["norm1"], x)
    q, k, v = attn.qkv_proj(cfg, lp["mixer"], h, q_pos)
    k_full = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
    v_full = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
    window = cfg.sliding_window if mixer == ATTN_LOCAL else None
    o = attn.attention_auto(cfg, q, k_full, v_full, q_pos, kv_pos,
                            causal=True, window=window)
    x = _residual(cfg, lp, x, attn.out_proj(cfg, lp["mixer"], o), "1")
    x, _ = _apply_ffn(cfg, lk, lp, x, mesh)
    return x, {"q_last": q[:, -1], "k": k_full, "v": v_full,
               "k_new": k, "v_new": v}


def prefill_extend(cfg: ArchConfig, fkv: FreeKVConfig, params, batch,
                   prefix_kv, max_len: int, mesh=None,
                   state_dtype=jnp.bfloat16, build_state: bool = True):
    """Prefill ``batch["tokens"]`` (B, S) as the continuation of a cached
    prefix whose per-layer post-RoPE K/V is ``prefix_kv`` ({"prelude":
    ((k, v), ...) with k (B, Tp, kv, dh), "pattern": ((k, v) stacked
    (n_periods, B, Tp, kv, dh), ...)}).

    Skips the transformer forward for the prefix span — only the suffix is
    embedded and attended (over prefix+suffix K/V); the paged decode state is
    rebuilt from the concatenated K/V via each retriever's ``prefill``.
    Returns (logits, state, suffix_kv) where suffix_kv mirrors prefix_kv's
    structure with T=S (for prefix-cache insertion of the full prompt).

    ``build_state=False`` skips the retriever state rebuild and returns
    ``state=None`` — the chunked-prefill path uses it for every chunk except
    the last, where rebuilding pages/rings from the growing concatenated K/V
    would be O(chunks x tokens) work that is discarded at the next chunk.
    """
    assert supports_kv_extend(cfg), \
        f"{cfg.name}: prefix-cache extension requires an attention-only stack"
    tokens = batch["tokens"]
    x = L.embed_tokens(cfg, params["embed"], tokens)
    B, S = tokens.shape
    if prefix_kv["prelude"]:
        Tp = prefix_kv["prelude"][0][0].shape[1]
    else:
        Tp = prefix_kv["pattern"][0][0].shape[2]
    q_pos = jnp.broadcast_to(jnp.arange(Tp, Tp + S)[None], (B, S))
    kv_pos = jnp.broadcast_to(jnp.arange(Tp + S)[None], (B, Tp + S))
    pre_r, pat_r = _retrievers(cfg, fkv, mesh)
    cmesh = _compute_mesh(fkv, mesh)

    pre_states, pre_kv = [], []
    for lp, lk, r, pkv in zip(params["prelude"], cfg.prelude, pre_r,
                              prefix_kv["prelude"]):
        x, ex = _apply_layer_extend(cfg, lk, lp, x, q_pos, kv_pos,
                                    pkv[0], pkv[1], cmesh)
        if build_state:
            st = r.init_state(B, max_len, state_dtype)
            pre_states.append(r.prefill(st, ex["k"], ex["v"], ex["q_last"]))
        pre_kv.append((ex["k_new"], ex["v_new"]))

    def scan_body(x, xs):
        lps, pkvs = xs
        sts, kvs = [], []
        for pos_i, lk in enumerate(cfg.pattern):
            x, ex = _apply_layer_extend(cfg, lk, lps[pos_i], x, q_pos, kv_pos,
                                        pkvs[pos_i][0], pkvs[pos_i][1], cmesh)
            if build_state:
                st = pat_r[pos_i].init_state(B, max_len, state_dtype)
                sts.append(pat_r[pos_i].prefill(st, ex["k"], ex["v"],
                                                ex["q_last"]))
            kvs.append((ex["k_new"], ex["v_new"]))
        return x, (tuple(sts), tuple(kvs))

    x, (pat_states, pat_kv) = jax.lax.scan(
        scan_body, x, (params["pattern"], prefix_kv["pattern"]))
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["embed"], x[:, -1])
    state = None if not build_state else {
        "prelude": tuple(pre_states), "pattern": pat_states,
        "pos": jnp.full((B,), Tp + S, jnp.int32)}
    return logits, state, {"prelude": tuple(pre_kv), "pattern": pat_kv}


# ---------------------------------------------------------------------------
# decode: one token through all layers (serve_step)
# ---------------------------------------------------------------------------
def _apply_layer_decode(cfg, fkv, lk, retr, lp, x, pos, st, mesh, q_proxy):
    mixer, _ = lk
    lp = _gather_for_compute(cfg, mesh, lp)
    h = L.apply_norm(cfg, lp["norm1"], x)                 # (B,1,d)
    B = x.shape[0]
    q_cur = q_proxy
    info = None
    if mixer in (ATTN, ATTN_LOCAL):
        positions = pos[:, None]
        q, k, v = attn.qkv_proj(cfg, lp["mixer"], h, positions)
        o, st2, info = retr.decode(
            {k2: v2 for k2, v2 in st.items() if k2 not in ("xk", "xv")},
            q[:, 0], k[:, 0], v[:, 0], q_proxy=q_proxy)
        if "xk" in st:
            st2["xk"], st2["xv"] = st["xk"], st["xv"]
        st = st2
        out = attn.out_proj(cfg, lp["mixer"], o[:, None])
        q_cur = q[:, 0]
    elif mixer == MAMBA:
        out, st = ssm.mamba_decode_step(cfg, lp["mixer"], h, st)
    elif mixer == MLSTM:
        out, st = xlstm.mlstm_decode_step(cfg, lp["mixer"], h, st)
    elif mixer == SLSTM:
        out, st = xlstm.slstm_decode_step(cfg, lp["mixer"], h, st)
    x = _residual(cfg, lp, x, out, "1")
    if mixer in (ATTN, ATTN_LOCAL) and "xk" in st:        # cross-attention
        hx = L.apply_norm(cfg, lp["xnorm"], x)
        qx, _, _ = attn.qkv_proj(cfg, lp["xattn"], hx, pos[:, None], rope=False)
        F = st["xk"].shape[1]
        epos = jnp.broadcast_to(jnp.arange(F)[None], (B, F))
        o = attn.attention_dense(cfg, qx, st["xk"], st["xv"], pos[:, None],
                                 epos, causal=False)
        x = x + attn.out_proj(cfg, lp["xattn"], o)
    x, _ = _apply_ffn(cfg, lk, lp, x, mesh)
    return x, st, q_cur, info


def _info_stats(info, B):
    if info is None:
        z = jnp.zeros((B,), jnp.float32)
        return {k: z for k in DECODE_STAT_KEYS}
    z = jnp.zeros((B,), jnp.int32)
    reused = info.get("reused_pages", z)
    return {"corrected": jnp.sum(info["corrected"], 1).astype(jnp.float32),
            "kv_heads": jnp.full((B,), info["corrected"].shape[1], jnp.float32),
            "sync_pages": info["sync_pages"].astype(jnp.float32),
            "async_pages": info["async_pages"].astype(jnp.float32),
            "reused_pages": reused.astype(jnp.float32),
            "sim_sum": jnp.sum(info["similarity"], 1).astype(jnp.float32),
            "sim_cnt": jnp.full((B,), info["similarity"].shape[1], jnp.float32),
            # speculation-quality telemetry (retrievers that don't model
            # residency report zeros; see docs/observability.md)
            "sel_pages": info.get("sel_pages", z).astype(jnp.float32),
            "spec_hit_pages": info.get("spec_hit_pages", z).astype(jnp.float32),
            "churn_pages": info.get("churn_pages", z).astype(jnp.float32)}


def serve_step(cfg: ArchConfig, fkv: FreeKVConfig, params, state, tokens,
               mesh=None, collect_stats=False):
    """tokens (B,1) -> (logits (B, vocab), new state[, stats]). One decode step.

    ``stats`` (when requested) aggregates per-layer retrieval info — corrected
    KV-head counts, sync/async recalled pages, query similarity — consumed by
    the serving engine and the latency cost model."""
    x = L.embed_tokens(cfg, params["embed"], tokens)
    B = x.shape[0]
    pos = state["pos"]
    pre_r, pat_r = _retrievers(cfg, fkv, mesh)
    cmesh = _compute_mesh(fkv, mesh)
    q_proxy = jnp.zeros((x.shape[0], cfg.n_heads, cfg.d_head), x.dtype)

    stats_acc = _info_stats(None, B)
    new_pre = []
    for lp, lk, r, st in zip(params["prelude"], cfg.prelude, pre_r,
                             state["prelude"]):
        x, st, q_proxy, info = _apply_layer_decode(
            cfg, fkv, lk, r, lp, x, pos, st, cmesh, q_proxy)
        new_pre.append(st)
        s = _info_stats(info if lk[0] == ATTN else None, B)
        stats_acc = {k: stats_acc[k] + s[k] for k in stats_acc}

    # NOTE: per-layer decode states live in the scan CARRY (read via
    # dynamic_index, written back via dynamic_update) rather than as xs->ys.
    # xs/ys would give the while-loop separate input and output buffers for
    # the KV pool (2x the pool in temps, measured 18 GB/dev on
    # deepseek-moe decode_32k); carried buffers are aliased in place.
    def scan_body(carry, xs):
        x, q_proxy, acc, states = carry
        lps, i = xs
        new_states = []
        for pos_i, lk in enumerate(cfg.pattern):
            st_i = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                states[pos_i])
            x, st, q_proxy, info = _apply_layer_decode(
                cfg, fkv, lk, pat_r[pos_i], lps[pos_i], x, pos, st_i,
                cmesh, q_proxy)
            new_states.append(jax.tree.map(
                lambda a, n: jax.lax.dynamic_update_index_in_dim(
                    a, n.astype(a.dtype), i, 0), states[pos_i], st))
            s = _info_stats(info if lk[0] == ATTN else None, B)
            acc = {k: acc[k] + s[k] for k in acc}
        return (x, q_proxy, acc, tuple(new_states)), None

    (x, _, stats_acc, new_pat), _ = jax.lax.scan(
        scan_body, (x, q_proxy, stats_acc, state["pattern"]),
        (params["pattern"], jnp.arange(cfg.n_periods)))
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["embed"], x[:, -1])
    # dict(state, ...) so extra top-level lanes (e.g. the spec-decode
    # draft_tab) ride through the non-drafted path untouched.
    new_state = dict(state, prelude=tuple(new_pre), pattern=new_pat,
                     pos=pos + 1)
    if collect_stats:
        return logits, new_state, stats_acc
    return logits, new_state


# ---------------------------------------------------------------------------
# host-sync-free decode: fused sampling + k-step-ahead device loop
# ---------------------------------------------------------------------------
# canonical per-step retrieval stat keys (the _info_stats contract); the
# serving scheduler and the decode window's stat blocks share this tuple.
# sel/spec_hit/churn are the speculation-quality telemetry: selected page
# slots, selected pages already resident from the previous speculation, and
# pages entering the top-k — accumulated on device in the (k, B) stat
# blocks and pulled only at sync boundaries (repro.obs).
DECODE_STAT_KEYS = ("corrected", "kv_heads", "sync_pages", "async_pages",
                    "reused_pages", "sim_sum", "sim_cnt", "sel_pages",
                    "spec_hit_pages", "churn_pages")


def serve_step_sampled(cfg: ArchConfig, fkv: FreeKVConfig, params, state,
                       loop, sampler, mesh=None):
    """One fused decode step: ``serve_step`` + on-device sampling + finished
    mask. Nothing here ever touches the host — full (B, vocab) logits never
    leave the device.

    ``loop`` is the device-resident decode-loop carry (one lane per batch
    slot, all shapes (B,) unless noted):

      cur    int32   token fed to this step
      key    uint32 (B, 2)  per-request PRNG key (sampling stream seed)
      count  int32   tokens generated so far for the slot's request
      limit  int32   the request's max_new_tokens
      eos    int32   eos token id, -1 for none
      fin    bool    slot finished (or empty) — its lane is masked

    Returns (state, loop, tok (B,), valid (B,), stats): ``tok`` is this
    step's sampled token (greedy path bit-identical to host argmax),
    ``valid[s]`` marks whether slot s was live entering the step (its token
    counts; finished lanes keep stepping — row computation is slot-local —
    but their tokens and stats are discarded by the scheduler). Token ``i``
    of a request is always sampled with ``fold_in(request_key, i)``, so
    sample streams are independent of co-scheduling and sync cadence."""
    from repro.serving import sampling
    logits, state, stats = serve_step(cfg, fkv, params, state,
                                      loop["cur"][:, None], mesh=mesh,
                                      collect_stats=True)
    keys = sampling.step_keys(loop["key"], loop["count"])
    tok = sampling.sample_step(logits, sampler, keys)
    valid = ~loop["fin"]
    count = loop["count"] + valid.astype(jnp.int32)
    fin = loop["fin"] | (count >= loop["limit"]) | (tok == loop["eos"])
    loop = dict(loop, cur=jnp.where(valid, tok, loop["cur"]),
                count=count, fin=fin)
    return state, loop, tok, valid, stats


def decode_window(cfg: ArchConfig, fkv: FreeKVConfig, params, state, loop,
                  sampler, k_max: int, mesh=None):
    """Dispatch up to ``k_max`` fused decode steps with zero host round
    trips: a ``lax.while_loop`` whose carry holds the decode state, the loop
    lanes, and (k_max, B) token / valid / stat blocks the host pulls once
    per sync.

    The loop exits early when every lane is finished, or — when
    ``loop["stop_turnover"]`` is set (the scheduler has queued admissions
    waiting) — as soon as any lane that was live at window start finishes,
    so a freed slot is refilled at the next host boundary instead of idling
    out the window. Returns (state, loop, toks (k_max, B), valid (k_max, B),
    stats {key: (k_max, B)}, n_steps). Rows past ``n_steps`` are zero.

    Donation contract: callers jit this with ``donate_argnums`` over
    ``state`` and ``loop`` (see ``serving.engine``); the while-loop carry
    aliases the KV slot pool in place, so the pool is never copied — not
    per step, and not per window."""
    B = loop["cur"].shape[0]
    start_live = ~loop["fin"]
    toks0 = jnp.zeros((k_max, B), jnp.int32)
    valid0 = jnp.zeros((k_max, B), jnp.bool_)
    stats0 = {k: jnp.zeros((k_max, B), jnp.float32) for k in DECODE_STAT_KEYS}

    def cond(carry):
        j, _, lp, _, _, _ = carry
        live = jnp.any(~lp["fin"])
        turned = lp["stop_turnover"] & jnp.any(lp["fin"] & start_live)
        return (j < k_max) & live & ~turned

    def body(carry):
        j, st, lp, toks, valid, stats = carry
        st, lp, tok, ok, s = serve_step_sampled(cfg, fkv, params, st, lp,
                                                sampler, mesh=mesh)
        toks = jax.lax.dynamic_update_index_in_dim(toks, tok, j, 0)
        valid = jax.lax.dynamic_update_index_in_dim(valid, ok, j, 0)
        stats = {k: jax.lax.dynamic_update_index_in_dim(stats[k], s[k], j, 0)
                 for k in stats}
        return j + 1, st, lp, toks, valid, stats

    n, state, loop, toks, valid, stats = jax.lax.while_loop(
        cond, body, (jnp.int32(0), state, loop, toks0, valid0, stats0))
    return state, loop, toks, valid, stats, n


# ---------------------------------------------------------------------------
# speculative decoding: drafted block verify + in-place rollback
# ---------------------------------------------------------------------------
def supports_spec_decode(cfg: ArchConfig, fkv: FreeKVConfig) -> bool:
    """Speculative decoding needs every drafted row to run the exact
    sequential retrieval step (attention-only stacks, pool-backed retrievers
    with a rewindable selection buffer) and a deterministic batched backbone
    (dense FFN; MoE routing over a drafted block is not row-wise guaranteed).
    The page-sharded fused step keeps its own selection schedule and is
    excluded; KV-head-group ``tp_serving`` composes (the TP wrapper forwards
    the rollback hooks)."""
    return (fkv.draft_len > 0
            and fkv.method in ("freekv", "arkvale", "infinigen")
            and not fkv.sharded_retrieval
            and supports_kv_extend(cfg)
            and all(f in (DENSE, NONE) for _, f in cfg.layers))


def _apply_layer_verify(cfg, fkv, lk, retr, lp, x, pos, st, mesh,
                        q_proxy_rows):
    """One layer over a drafted block x (B, S, d): the backbone (norms, QKV
    projection, out-projection, FFN) runs batched over the S rows — bitwise
    row-identical to S single-row passes — while retrieval + attention run
    per row through the exact sequential ``retr.decode``, appending all S
    rows. Returns (x, st, q_rows, stats_rows (S-stacked), undo) where undo =
    (ring snapshot, per-row rewind probes) feeds the post-acceptance
    rollback."""
    from repro.core import retrieval as retrieval_mod
    mixer, _ = lk
    lp = _gather_for_compute(cfg, mesh, lp)
    h = L.apply_norm(cfg, lp["norm1"], x)
    B, S = x.shape[:2]
    positions = pos[:, None] + jnp.arange(S)[None, :]
    q, k, v = attn.qkv_proj(cfg, lp["mixer"], h, positions)      # (B,S,H,d)
    snap = retrieval_mod.ring_snapshot(st, S)

    def step(carry_st, inp):
        qj, kj, vj, qpj = inp
        o, st2, info = retr.decode(carry_st, qj, kj, vj, q_proxy=qpj)
        s = _info_stats(info if mixer == ATTN else None, B)
        return st2, (o, retr.draft_probe(st2), s)

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), q_proxy_rows.transpose(1, 0, 2, 3))
    st, (o_rows, probe_rows, stats_rows) = jax.lax.scan(step, st, xs)
    out = attn.out_proj(cfg, lp["mixer"], o_rows.transpose(1, 0, 2, 3))
    x = _residual(cfg, lp, x, out, "1")
    x, _ = _apply_ffn(cfg, lk, lp, x, mesh)
    return x, st, q, stats_rows, (snap, probe_rows)


def _rewind_layer(retr, st, keep_len, undo, last_row, keep):
    """Roll one layer's state back to the accepted prefix: restore the
    selection lanes from the last committed row's probe (one staged recall,
    doubling as the next block's prefetch) and undo rejected ring writes."""
    from repro.core import retrieval as retrieval_mod
    snap, probe_rows = undo
    B = keep.shape[0]
    probe = jax.tree.map(lambda a: a[last_row, jnp.arange(B)], probe_rows)
    st = retr.draft_rewind(st, keep_len, probe)
    return retrieval_mod.ring_restore(st, snap, keep)


def serve_step_verify(cfg: ArchConfig, fkv: FreeKVConfig, params, state,
                      tokens, mesh=None):
    """One target pass over a drafted block: tokens (B, S) with row 0 the
    committed current token and rows 1..S-1 the drafted continuation.

    Returns (logits (B, S, vocab), state with all S rows appended,
    stats_rows {key: (S, B)}, undo info for ``_rewind_state``). Every row's
    logits are bitwise what S sequential ``serve_step`` calls produce, so
    accept-longest-prefix acceptance preserves exact sample streams."""
    x = L.embed_tokens(cfg, params["embed"], tokens)
    B, S = tokens.shape
    pos = state["pos"]
    pre_r, pat_r = _retrievers(cfg, fkv, mesh)
    cmesh = _compute_mesh(fkv, mesh)
    q_proxy = jnp.zeros((B, S, cfg.n_heads, cfg.d_head), x.dtype)

    stats_rows = {k: jnp.zeros((S, B), jnp.float32) for k in DECODE_STAT_KEYS}
    new_pre, pre_undo = [], []
    for lp, lk, r, st in zip(params["prelude"], cfg.prelude, pre_r,
                             state["prelude"]):
        x, st, q_proxy, rows, undo = _apply_layer_verify(
            cfg, fkv, lk, r, lp, x, pos, st, cmesh, q_proxy)
        new_pre.append(st)
        pre_undo.append(undo)
        stats_rows = {k: stats_rows[k] + rows[k] for k in stats_rows}

    def scan_body(carry, xs):
        x, q_proxy, acc, states = carry
        lps, i = xs
        undos = []
        for pos_i, lk in enumerate(cfg.pattern):
            st_i = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                states[pos_i])
            x, st, q_proxy, rows, undo = _apply_layer_verify(
                cfg, fkv, lk, pat_r[pos_i], lps[pos_i], x, pos, st_i,
                cmesh, q_proxy)
            states = states[:pos_i] + (jax.tree.map(
                lambda a, n: jax.lax.dynamic_update_index_in_dim(
                    a, n.astype(a.dtype), i, 0), states[pos_i], st),) \
                + states[pos_i + 1:]
            undos.append(undo)
            acc = {k: acc[k] + rows[k] for k in acc}
        return (x, q_proxy, acc, states), tuple(undos)

    (x, _, stats_rows, new_pat), pat_undos = jax.lax.scan(
        scan_body, (x, q_proxy, stats_rows, state["pattern"]),
        (params["pattern"], jnp.arange(cfg.n_periods)))
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["embed"], x)
    new_state = dict(state, prelude=tuple(new_pre), pattern=new_pat)
    return logits, new_state, stats_rows, (pre_undo, pat_undos, pre_r, pat_r)


def _rewind_state(cfg, state, undo_info, m, last_row):
    """Roll every layer back to the m committed rows (per slot) and advance
    ``pos`` by m."""
    pre_undo, pat_undos, pre_r, pat_r = undo_info
    keep_len = state["pos"] + m

    new_pre = [
        _rewind_layer(r, st, keep_len, undo, last_row, m)
        for st, r, undo in zip(state["prelude"], pre_r, pre_undo)]

    def rewind_body(states, xs):
        undos_i, i = xs
        for pos_i in range(len(cfg.pattern)):
            st_i = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                states[pos_i])
            st2 = _rewind_layer(pat_r[pos_i], st_i, keep_len, undos_i[pos_i],
                                last_row, m)
            states = states[:pos_i] + (jax.tree.map(
                lambda a, n: jax.lax.dynamic_update_index_in_dim(
                    a, n.astype(a.dtype), i, 0), states[pos_i], st2),) \
                + states[pos_i + 1:]
        return states, None

    new_pat, _ = jax.lax.scan(rewind_body, state["pattern"],
                              (pat_undos, jnp.arange(cfg.n_periods)))
    return dict(state, prelude=tuple(new_pre), pattern=new_pat,
                pos=state["pos"] + m)


def serve_step_spec(cfg: ArchConfig, fkv: FreeKVConfig, params, state, loop,
                    sampler, mesh=None):
    """One fused speculative decode iteration: draft -> batched verify ->
    accept-longest-prefix -> in-place rollback -> drafter update.

    The drafted block is [cur, d_1..d_L] (S = 1 + draft_len rows). Row j is
    scored with the same per-request key ``fold_in(request_key, count + j)``
    the sequential path would use, and row j >= 1 is emitted iff every
    earlier row matched its draft, produced no eos, and the request limit was
    not reached — exactly the tokens m sequential steps would emit, so
    greedy AND sampled outputs are bit-identical to ``draft_len=0``.

    Returns (state, loop, toks (S, B), emit (S, B), stats {key: (S, B)}):
    row-major blocks the spec decode window stacks into its (k, S, B)
    machinery. Everything stays on device (no host syncs)."""
    from repro.core import drafter
    from repro.serving import sampling
    B = loop["cur"].shape[0]
    S = fkv.draft_len + 1
    cur = loop["cur"]
    drafted = drafter.propose(state["draft_tab"], cur, fkv.draft_len)
    toks = jnp.concatenate([cur[:, None], drafted], axis=1)       # (B, S)

    logits, state, stats_rows, undo_info = serve_step_verify(
        cfg, fkv, params, state, toks, mesh=mesh)

    counts_j = loop["count"][None, :] + jnp.arange(S)[:, None]    # (S, B)

    def samp(lg_j, cnt_j):
        keys = sampling.step_keys(loop["key"], cnt_j)
        return sampling.sample_step(lg_j, sampler, keys)

    e = jax.vmap(samp)(logits.transpose(1, 0, 2), counts_j)       # (S, B)

    live0 = ~loop["fin"]
    emits = [live0]
    for j in range(1, S):
        prev_e = e[j - 1]
        cont = ((drafted[:, j - 1] == prev_e) & (prev_e != loop["eos"])
                & (loop["count"] + j < loop["limit"]))
        emits.append(emits[-1] & cont)
    emit = jnp.stack(emits)                                       # (S, B)
    m = jnp.sum(emit.astype(jnp.int32), axis=0)                   # (B,)
    last_row = jnp.clip(m - 1, 0, S - 1)

    state = _rewind_state(cfg, state, undo_info, m, last_row)

    e_last = e[last_row, jnp.arange(B)]
    valid_any = m > 0
    count = loop["count"] + m
    fin = loop["fin"] | (valid_any & ((e_last == loop["eos"])
                                      | (count >= loop["limit"])))
    loop = dict(loop, cur=jnp.where(valid_any, e_last, cur), count=count,
                fin=fin)

    stream = jnp.concatenate([cur[:, None], e.T], axis=1)         # (B, S+1)
    emit_ext = jnp.concatenate([live0[:, None], emit.T], axis=1)
    state = dict(state, draft_tab=drafter.update(state["draft_tab"],
                                                 stream, emit_ext))
    return state, loop, e, emit, stats_rows


def decode_window_spec(cfg: ArchConfig, fkv: FreeKVConfig, params, state,
                       loop, sampler, k_max: int, mesh=None):
    """Speculative variant of ``decode_window``: up to ``k_max`` drafted
    verify iterations with zero host round trips, (k_max, S, B) token /
    emit / stat blocks pulled once per sync. Same early-exit and donation
    contract as ``decode_window``; up to S tokens commit per iteration."""
    B = loop["cur"].shape[0]
    S = fkv.draft_len + 1
    start_live = ~loop["fin"]
    toks0 = jnp.zeros((k_max, S, B), jnp.int32)
    valid0 = jnp.zeros((k_max, S, B), jnp.bool_)
    stats0 = {k: jnp.zeros((k_max, S, B), jnp.float32)
              for k in DECODE_STAT_KEYS}

    def cond(carry):
        j, _, lp, _, _, _ = carry
        live = jnp.any(~lp["fin"])
        turned = lp["stop_turnover"] & jnp.any(lp["fin"] & start_live)
        return (j < k_max) & live & ~turned

    def body(carry):
        j, st, lp, toks, valid, stats = carry
        st, lp, tok, ok, s = serve_step_spec(cfg, fkv, params, st, lp,
                                             sampler, mesh=mesh)
        toks = jax.lax.dynamic_update_index_in_dim(toks, tok, j, 0)
        valid = jax.lax.dynamic_update_index_in_dim(valid, ok, j, 0)
        stats = {k: jax.lax.dynamic_update_index_in_dim(stats[k], s[k], j, 0)
                 for k in stats}
        return j + 1, st, lp, toks, valid, stats

    n, state, loop, toks, valid, stats = jax.lax.while_loop(
        cond, body, (jnp.int32(0), state, loop, toks0, valid0, stats0))
    return state, loop, toks, valid, stats, n
