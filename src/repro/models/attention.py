"""GQA attention: params, full/sliding training+prefill paths (chunked, flash-style),
and dense attention over a budget-sized device cache for decode.

Decode-time retrieval (FreeKV & baselines) lives in ``repro.core``; this module
provides the math they share.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, dense_init, softcap

NEG_INF = -1e30


def attn_init(key, cfg: ArchConfig, dtype=jnp.float32, cross=False):
    d, dh = cfg.d_model, cfg.d_head
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, cfg.n_heads * dh, dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * dh, dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * dh, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * dh, d, dtype),
    }


def qkv_proj(cfg: ArchConfig, p, x, positions, rope=True):
    """x: (B,T,d) -> q (B,T,H,dh), k/v (B,T,Hkv,dh); RoPE applied to q,k."""
    B, T, _ = x.shape
    q = (x @ p["wq"]).reshape(B, T, cfg.n_heads, cfg.d_head)
    k = (x @ p["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.d_head)
    v = (x @ p["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.d_head)
    if rope:
        q = apply_rope(cfg, q, positions)
        k = apply_rope(cfg, k, positions)
    return q, k, v


def out_proj(cfg: ArchConfig, p, o):
    B, T = o.shape[:2]
    return o.reshape(B, T, cfg.n_heads * cfg.d_head) @ p["wo"]


def _scale(cfg: ArchConfig):
    return cfg.attn_scale if cfg.attn_scale is not None else 1.0 / (cfg.d_head ** 0.5)


def _mask_bias(pos_q, pos_k, causal=True, window=None):
    """(B,Tq),(B,Tk) -> additive bias (B,1,Tq,Tk). pos_k < 0 marks invalid slots."""
    dq = pos_q[:, :, None]
    dk = pos_k[:, None, :]
    ok = dk >= 0
    if causal:
        ok &= dk <= dq
    if window is not None:
        ok &= dk > dq - window
    return jnp.where(ok, 0.0, NEG_INF)[:, None, :, :]


def attention_dense(cfg: ArchConfig, q, k, v, pos_q, pos_k, causal=True, window=None):
    """Reference attention. q:(B,Tq,H,dh) k,v:(B,Tk,Hkv,dh) -> (B,Tq,H,dh)."""
    B, Tq, H, dh = q.shape
    G = cfg.group_size
    qg = q.reshape(B, Tq, cfg.n_kv_heads, G, dh)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, k).astype(jnp.float32) * _scale(cfg)
    s = softcap(s, cfg.attn_logit_softcap)
    bias = _mask_bias(pos_q, pos_k, causal, window)  # (B,1,Tq,Tk)
    s = s + bias[:, :, None, :, :]
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bskd->btkgd", w.astype(v.dtype), v)
    return o.reshape(B, Tq, H, dh)


# §Perf knob: overrides the flash KV-chunk size (None -> per-call default).
# Larger chunks cut the (B,kv,G,Tq,dh) f32 accumulator's HBM round trips
# (bytes ~ Tq*Tk/chunk) at the cost of a larger live score block.
CHUNK_OVERRIDE = None


def attention_chunked(cfg: ArchConfig, q, k, v, pos_q, pos_k, causal=True,
                      window=None, chunk=512):
    if CHUNK_OVERRIDE is not None:
        chunk = CHUNK_OVERRIDE
    """Flash-style attention: lax.scan over KV chunks with running (max, sum).

    Keeps peak memory at O(Tq * chunk) instead of O(Tq * Tk) — used for the 32K
    prefill path so the dry-run memory analysis reflects a production kernel.
    """
    B, Tq, H, dh = q.shape
    Tk = k.shape[1]
    if Tk % chunk:
        pad = chunk - Tk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_k = jnp.pad(pos_k, ((0, 0), (0, pad)), constant_values=-1)
        Tk += pad
    nck = Tk // chunk
    G = cfg.group_size
    qg = (q.reshape(B, Tq, cfg.n_kv_heads, G, dh).astype(jnp.float32) * _scale(cfg))

    ks = k.reshape(B, nck, chunk, cfg.n_kv_heads, dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nck, chunk, cfg.n_kv_heads, dh).transpose(1, 0, 2, 3, 4)
    ps = pos_k.reshape(B, nck, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, pc = xs
        s = jnp.einsum("btkgd,bskd->bkgts", qg, kc.astype(jnp.float32))
        s = softcap(s, cfg.attn_logit_softcap)
        bias = _mask_bias(pos_q, pc, causal, window)  # (B,1,Tq,chunk)
        s = s + bias[:, :, None, :, :]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgts,bskd->bkgtd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, cfg.n_kv_heads, G, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, cfg.n_kv_heads, G, Tq), jnp.float32)
    a0 = jnp.zeros((B, cfg.n_kv_heads, G, Tq, dh), jnp.float32)
    # checkpoint per KV chunk: the scan's backward otherwise stores the
    # (B,kv,G,Tq,chunk) score intermediates for every chunk step
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                  (ks, vs, ps))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Tq, H, dh).astype(q.dtype)


def attention_auto(cfg: ArchConfig, q, k, v, pos_q, pos_k, causal=True, window=None):
    # dense path only for small products; production shapes (4K train, 32K
    # prefill) take the chunked flash path so the scores matrix never
    # materializes (bounds dry-run temp memory)
    if q.shape[1] * k.shape[1] <= 2048 * 2048:
        return attention_dense(cfg, q, k, v, pos_q, pos_k, causal, window)
    return attention_chunked(cfg, q, k, v, pos_q, pos_k, causal, window)


# ---------------------------------------------------------------------------
# Decode attention over a paged device cache (budget-sized)
# ---------------------------------------------------------------------------
def decode_attention_paged(cfg: ArchConfig, q, cache_k, cache_v, cache_pos, pos_q,
                           window=None):
    """Single-token decode attention over the device-resident page cache.

    q:        (B, 1, H, dh)
    cache_k/v:(B, n_slots, p, Hkv, dh)  — NHD page layout (paper's device layout)
    cache_pos:(B, n_slots, p) int32, -1 = invalid slot
    Returns (B, 1, H, dh).
    """
    B, n_slots, p, Hkv, dh = cache_k.shape
    k = cache_k.reshape(B, n_slots * p, Hkv, dh)
    v = cache_v.reshape(B, n_slots * p, Hkv, dh)
    pos_k = cache_pos.reshape(B, n_slots * p)
    return attention_dense(cfg, q, k, v, pos_q, pos_k, causal=True, window=window)
