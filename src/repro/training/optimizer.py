"""Hand-rolled AdamW + LR schedules (optax is not available in this env).

Optimizer-state dtype is configurable: fp32 by default; bf16 for the largest
dry-run configs (jamba-398B) where fp32 m/v would not fit a single pod —
recorded in EXPERIMENTS.md §Dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"     # float32 | bfloat16


def lr_at(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    dt = jnp.dtype(cfg.state_dtype)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(dt), v_new.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
