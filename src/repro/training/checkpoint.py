"""Minimal pytree checkpointing (orbax not available): flattened-path npz."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(tree)
    tmp = path + ".tmp.npz"
    np.savez(tmp, **flat)
    os.replace(tmp, path)


def restore(path: str, like):
    """Restore into the structure of ``like`` (shapes/dtypes must match)."""
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_k, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_k)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
