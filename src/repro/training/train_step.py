"""Training step: loss/grad/update, jit- and pjit-compatible."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import forward_train
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, mesh=None,
                    remat=True):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        loss, metrics = forward_train(cfg, params, batch, mesh=mesh, remat=remat)
        return loss, metrics

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        # barrier: without it XLA fuses the optimizer's f32 casts INTO the
        # backward scan, accumulating all stacked grads in f32 (2x memory,
        # measured on jamba train_4k)
        grads = jax.lax.optimization_barrier(grads)
        params, opt_state, opt_metrics = adamw_update(
            grads, opt_state, params, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def init_train(cfg: ArchConfig, opt_cfg: AdamWConfig, key,
               dtype=jnp.float32):
    from repro.models.model import init_params
    params = init_params(cfg, key, dtype)
    return params, adamw_init(params, opt_cfg)
