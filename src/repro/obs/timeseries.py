"""Sliding-window time-series aggregators over the serving telemetry stream.

The PR 6 registry (``repro.obs.registry``) is *cumulative*: counters and
fixed-bucket histograms over a whole run. That is the right substrate for
end-of-run summaries and CI gates, but a live serving deployment needs the
complementary view — "what are p99 TTFT and tokens/s *right now*" — i.e.
rolling statistics over the last W seconds, continuously evicting old
samples. This module provides that:

* :class:`WindowStat` — a ring-buffer (bounded deque) of ``(t, value)``
  samples inside a sliding time window, with exact rolling min/mean/max and
  **exact** p50/p90/p99 over the in-window samples (numpy-``linear``
  interpolation semantics, so tests can check against ``np.percentile`` on
  the same sliding slice). Used for TTFT, ITL (per-token gaps), queue wait,
  decode-step latency, slot occupancy, speculative hit rate.
* :class:`WindowRate` — a ring buffer of ``(t, weight)`` events giving a
  rolling events/s and weight/s over the window plus exact cumulative
  totals. Used for tokens/s, completions/s, preemption / swap / cancel
  rates.
* :class:`TimeSeriesBoard` — a named get-or-create collection of both,
  with a schema-versioned :meth:`TimeSeriesBoard.snapshot` (the shape
  ``validate_timeseries_snapshot`` and ``tools/check_obs.py`` check, and
  the payload the HTTP front-end serves at ``/stats``).

Feeding happens on the scheduler thread (``serving/scheduler.py`` calls
``observe``/``event`` at the same places it feeds the cumulative
histograms); snapshots are taken from the asyncio front-end thread, so the
board holds one lock around sample mutation and snapshotting. All
timestamps share one clock (``time.perf_counter`` by default — the
scheduler feeds ``run_t0 + run_relative_t`` so trace/metrics timelines
agree); eviction is purely time-based, the ``max_samples`` ring bound only
caps memory under pathological rates.

Standard serving series names are collected in :data:`SERIES` for the
docs/validator; the board accepts arbitrary names (same policy as the
registry).
"""
from __future__ import annotations

import json
import math
import threading
import time
from collections import deque
from typing import Callable, Dict, Optional

TIMESERIES_SCHEMA_VERSION = 1

# default sliding window (seconds) — short enough that smoke runs populate
# and rotate it, long enough to smooth sync-boundary burstiness
DEFAULT_WINDOW_S = 10.0
# ring-buffer bound per series: memory cap, NOT the window semantics
DEFAULT_MAX_SAMPLES = 8192

# canonical serving series (docs/observability.md catalogs these; the
# scheduler feeds them whenever a TimeSeriesBoard is attached)
SERIES = {
    "stats": {
        "ttft_s": "enqueue -> first token, per finished first token",
        "itl_s": "per-token inter-token gap",
        "queue_wait_s": "enqueue -> prefill start",
        "decode_step_s": "per decode step latency",
        "slot_occupancy": "live slots / pool size, sampled per step",
        "spec_hit_rate": "per-step speculative page-hit rate",
    },
    "rates": {
        "tokens": "generated tokens (weight 1 per token) -> tokens/s",
        "completions": "finished requests",
        "cancellations": "client-cancelled requests",
        "preemptions": "requests swapped out to host",
        "swap_bytes": "weight = bytes swapped out+in",
    },
}


def _percentile_sorted(vals, q: float) -> float:
    """numpy 'linear' percentile over an already-sorted list; q in [0,1]."""
    n = len(vals)
    if n == 0:
        return 0.0
    if n == 1:
        return float(vals[0])
    pos = q * (n - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return float(vals[lo] * (1.0 - frac) + vals[hi] * frac)


class WindowStat:
    """Rolling value distribution over a sliding time window.

    Samples are ``(t, v)`` pairs in a bounded deque (ring buffer); every
    read first evicts samples older than ``now - window_s``. Percentiles
    are exact over the surviving samples (numpy-``linear``)."""

    __slots__ = ("name", "window_s", "samples")

    def __init__(self, name: str, window_s: float = DEFAULT_WINDOW_S,
                 max_samples: int = DEFAULT_MAX_SAMPLES):
        if window_s <= 0:
            raise ValueError(f"{name}: window_s must be positive")
        self.name = name
        self.window_s = float(window_s)
        self.samples: deque = deque(maxlen=max_samples)

    def observe(self, v: float, t: float) -> None:
        self.samples.append((float(t), float(v)))

    def _trim(self, now: float) -> None:
        cutoff = now - self.window_s
        s = self.samples
        while s and s[0][0] < cutoff:
            s.popleft()

    def values(self, now: float) -> list:
        self._trim(now)
        return [v for _, v in self.samples]

    def summary(self, now: float) -> dict:
        vals = sorted(self.values(now))
        n = len(vals)
        return {
            "window_s": self.window_s,
            "count": n,
            "mean": sum(vals) / n if n else 0.0,
            "min": vals[0] if n else 0.0,
            "max": vals[-1] if n else 0.0,
            "p50": _percentile_sorted(vals, 0.50),
            "p90": _percentile_sorted(vals, 0.90),
            "p99": _percentile_sorted(vals, 0.99),
        }


class WindowRate:
    """Rolling event/weight rate over a sliding time window, plus exact
    cumulative totals (the totals never evict, so they match the registry
    counters)."""

    __slots__ = ("name", "window_s", "samples", "total_events",
                 "total_weight")

    def __init__(self, name: str, window_s: float = DEFAULT_WINDOW_S,
                 max_samples: int = DEFAULT_MAX_SAMPLES):
        if window_s <= 0:
            raise ValueError(f"{name}: window_s must be positive")
        self.name = name
        self.window_s = float(window_s)
        self.samples: deque = deque(maxlen=max_samples)
        self.total_events = 0
        self.total_weight = 0.0

    def event(self, weight: float = 1.0, t: float = 0.0) -> None:
        self.samples.append((float(t), float(weight)))
        self.total_events += 1
        self.total_weight += float(weight)

    def _trim(self, now: float) -> None:
        cutoff = now - self.window_s
        s = self.samples
        while s and s[0][0] < cutoff:
            s.popleft()

    def summary(self, now: float) -> dict:
        self._trim(now)
        events = len(self.samples)
        weight = sum(w for _, w in self.samples)
        return {
            "window_s": self.window_s,
            "events": events,
            "weight": weight,
            "events_per_s": events / self.window_s,
            "weight_per_s": weight / self.window_s,
            "total_events": self.total_events,
            "total_weight": self.total_weight,
        }


class TimeSeriesBoard:
    """Named sliding-window series with a schema-versioned snapshot.

    Thread-safe: the scheduler thread feeds ``observe``/``event`` while the
    front-end thread snapshots — one lock covers both (feeds are a deque
    append under the lock; snapshots trim + sort, still cheap at ring-bound
    sizes)."""

    def __init__(self, window_s: float = DEFAULT_WINDOW_S,
                 max_samples: int = DEFAULT_MAX_SAMPLES,
                 clock: Callable[[], float] = time.perf_counter):
        self.window_s = float(window_s)
        self.max_samples = int(max_samples)
        self.clock = clock
        self._stats: Dict[str, WindowStat] = {}
        self._rates: Dict[str, WindowRate] = {}
        self._lock = threading.Lock()

    # -- get-or-create -------------------------------------------------
    def stat(self, name: str,
             window_s: Optional[float] = None) -> WindowStat:
        s = self._stats.get(name)
        if s is None:
            with self._lock:
                s = self._stats.get(name)
                if s is None:
                    s = self._stats[name] = WindowStat(
                        name, window_s or self.window_s, self.max_samples)
        return s

    def rate(self, name: str,
             window_s: Optional[float] = None) -> WindowRate:
        r = self._rates.get(name)
        if r is None:
            with self._lock:
                r = self._rates.get(name)
                if r is None:
                    r = self._rates[name] = WindowRate(
                        name, window_s or self.window_s, self.max_samples)
        return r

    # -- feeding (scheduler thread) -------------------------------------
    def observe(self, name: str, v: float, t: Optional[float] = None) -> None:
        s = self.stat(name)                    # creation has its own locking
        with self._lock:
            s.observe(v, self.clock() if t is None else t)

    def event(self, name: str, weight: float = 1.0,
              t: Optional[float] = None) -> None:
        r = self.rate(name)
        with self._lock:
            r.event(weight, self.clock() if t is None else t)

    # -- snapshot (front-end thread) ------------------------------------
    def snapshot(self, now: Optional[float] = None,
                 extra: Optional[dict] = None) -> dict:
        now = self.clock() if now is None else now
        with self._lock:
            snap = {
                "schema_version": TIMESERIES_SCHEMA_VERSION,
                "unix_time": time.time(),
                "now": now,
                "window_s": self.window_s,
                "stats": {n: s.summary(now)
                          for n, s in sorted(self._stats.items())},
                "rates": {n: r.summary(now)
                          for n, r in sorted(self._rates.items())},
            }
        if extra:
            snap["extra"] = extra
        return snap

    def snapshot_line(self, now: Optional[float] = None,
                      extra: Optional[dict] = None) -> str:
        return json.dumps(self.snapshot(now, extra), sort_keys=True)


_STAT_KEYS = ("window_s", "count", "mean", "min", "max", "p50", "p90", "p99")
_RATE_KEYS = ("window_s", "events", "weight", "events_per_s", "weight_per_s",
              "total_events", "total_weight")


def validate_timeseries_snapshot(snap: dict) -> list:
    """Schema check for :meth:`TimeSeriesBoard.snapshot` dicts (shared by
    tests, ``tools/check_obs.py`` and the ``/stats`` endpoint validation).
    Returns a list of problems (empty = valid)."""
    errors = []
    if not isinstance(snap, dict):
        return ["timeseries snapshot is not an object"]
    if snap.get("schema_version") != TIMESERIES_SCHEMA_VERSION:
        errors.append(f"schema_version != {TIMESERIES_SCHEMA_VERSION}")
    for key in ("unix_time", "now", "window_s"):
        if not isinstance(snap.get(key), (int, float)):
            errors.append(f"missing/non-numeric {key!r}")
    for sect, keys in (("stats", _STAT_KEYS), ("rates", _RATE_KEYS)):
        body = snap.get(sect)
        if not isinstance(body, dict):
            errors.append(f"missing section {sect!r}")
            continue
        for name, entry in body.items():
            if not isinstance(entry, dict):
                errors.append(f"{sect}.{name}: not an object")
                continue
            for k in keys:
                v = entry.get(k)
                if not isinstance(v, (int, float)) or not math.isfinite(v):
                    errors.append(f"{sect}.{name}.{k}: missing or "
                                  "non-finite")
            if sect == "stats" and all(
                    isinstance(entry.get(p), (int, float))
                    for p in ("p50", "p90", "p99")):
                if not entry["p50"] <= entry["p90"] <= entry["p99"]:
                    errors.append(f"stats.{name}: percentiles not monotone")
            if sect == "rates" and isinstance(entry.get("events"), (int,
                                                                    float)):
                if entry["events"] < 0 or entry.get("total_events", 0) \
                        < entry["events"]:
                    errors.append(f"rates.{name}: window events exceed "
                                  "totals")
    return errors
