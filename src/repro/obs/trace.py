"""Trace spans: Chrome-trace / Perfetto JSON for the serving pipeline.

``TraceRecorder`` buffers Trace Event Format events (the JSON Perfetto and
``chrome://tracing`` load natively) and writes them with
:meth:`TraceRecorder.write`:

* request-lifecycle spans — one Perfetto *thread* per request uid with
  ``request/queued`` -> ``request/prefill`` -> ``request/decode`` spans
  and a ``request/done`` instant (scheduler emits these at finish time
  from the ``RequestMetrics`` timestamps, so tracing adds no bookkeeping
  to the hot path);
* engine spans — ``engine/decode_window`` per host sync with the fused
  step count / pulled bytes in ``args``, split into per-step
  ``engine/decode_step`` spans on the engine track;
* recall-pipeline spans — per-step ``recall/topup`` (blocking correction
  top-up) on the engine track and ``recall/staged`` (overlapped
  speculative stage) on a separate DMA track, so the hidden-fraction
  claim is visually auditable as overlap. In simulation the DMA span
  durations are **modeled** from block counts at ``MODEL_LINK_BW``
  (mirrors ``benchmarks/_common.HwModel.host_link_bw``) — the event
  ``args`` carry the exact byte counts;
* counter tracks — ``speculation/hit_rate`` and
  ``speculation/correction_rate`` sampled once per sync boundary, giving
  the paper's accuracy-side signal as a timeline.

The same span names are exported as ``jax.named_scope`` annotations via
:func:`annotate` (used inside the jitted retrieval path), so a real
``jax.profiler`` trace lines up with the host-side spans by name.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Dict, List, Optional

import jax

# modeled host<->device link bandwidth for simulated DMA span durations;
# keep in sync with benchmarks/_common.HwModel.host_link_bw
MODEL_LINK_BW = 20e9

# --- span taxonomy (docs/observability.md) -----------------------------
SPAN_REQUEST_QUEUED = "request/queued"
SPAN_REQUEST_PREFILL = "request/prefill"
SPAN_REQUEST_DECODE = "request/decode"
SPAN_REQUEST_DONE = "request/done"
SPAN_DECODE_WINDOW = "engine/decode_window"
SPAN_DECODE_STEP = "engine/decode_step"
# one drafted-block verify iteration (speculative decoding): args carry the
# live-slot count plus proposed/accepted/committed token counts, so the
# accepted-tokens-per-target-step distribution is readable off the trace
SPAN_SPEC_VERIFY = "engine/spec_verify"
SPAN_PREFILL_CHUNK = "engine/prefill_chunk"
SPAN_SCHED_PREEMPT = "sched/preempt"
SPAN_SCHED_RESUME = "sched/resume"
SPAN_SCHED_CANCEL = "sched/cancel"
SPAN_RECALL_SELECT = "recall/select"
SPAN_RECALL_CORRECTION = "recall/correction"
SPAN_RECALL_TOPUP = "recall/topup"
SPAN_RECALL_STAGED = "recall/staged"
SPAN_RECALL_REUSE = "recall/reuse"
SPAN_ATTN_COMPUTE = "attn/compute"

# Perfetto pid/tid layout: one process for the engine, one for requests
PID_ENGINE = 1
PID_REQUESTS = 2
TID_ENGINE = 1
TID_DMA = 2


def annotate(name: str):
    """``jax.named_scope`` on the shared span names — free at runtime
    (HLO metadata only), and it makes ``jax.profiler`` traces line up
    with the host-side Perfetto spans."""
    try:
        return jax.named_scope(name)
    except Exception:                      # pragma: no cover - old jax
        return contextlib.nullcontext()


class TraceRecorder:
    """Buffers Chrome-trace events; ``enabled=False`` makes every method
    a cheap no-op so the recorder can be threaded unconditionally."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.events: List[dict] = []
        self._origin: Optional[float] = None
        self._names: Dict[tuple, str] = {}
        if enabled:
            self._meta(PID_ENGINE, None, "process_name", "serve-engine")
            self._meta(PID_ENGINE, TID_ENGINE, "thread_name", "decode")
            self._meta(PID_ENGINE, TID_DMA, "thread_name", "recall-dma")
            self._meta(PID_REQUESTS, None, "process_name", "requests")

    # -- clock ---------------------------------------------------------
    def set_origin(self, t: Optional[float] = None) -> None:
        """Anchor ts=0; scheduler calls this with its run-start time so
        span timestamps equal the RequestMetrics timeline."""
        self._origin = time.perf_counter() if t is None else t

    def _us(self, t_s: float) -> float:
        return t_s * 1e6

    # -- event emitters (ts/dur in seconds, run-relative) ---------------
    def _meta(self, pid: int, tid: Optional[int], what: str, name: str):
        ev = {"ph": "M", "pid": pid, "name": what, "args": {"name": name}}
        if tid is not None:
            ev["tid"] = tid
        self.events.append(ev)

    def name_request_track(self, uid: int) -> None:
        if not self.enabled or (PID_REQUESTS, uid) in self._names:
            return
        self._names[(PID_REQUESTS, uid)] = f"req {uid}"
        self._meta(PID_REQUESTS, uid, "thread_name", f"req {uid}")

    def complete(self, name: str, ts_s: float, dur_s: float, *,
                 pid: int = PID_ENGINE, tid: int = TID_ENGINE,
                 args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        ev = {"name": name, "ph": "X", "ts": self._us(ts_s),
              "dur": max(self._us(dur_s), 0.0), "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, ts_s: float, *, pid: int = PID_ENGINE,
                tid: int = TID_ENGINE,
                args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        ev = {"name": name, "ph": "i", "ts": self._us(ts_s), "pid": pid,
              "tid": tid, "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, ts_s: float, values: Dict[str, float], *,
                pid: int = PID_ENGINE) -> None:
        if not self.enabled:
            return
        self.events.append({"name": name, "ph": "C", "ts": self._us(ts_s),
                            "pid": pid, "args": dict(values)})

    # -- high-level helpers ---------------------------------------------
    def request_lifecycle(self, rm) -> None:
        """Emit queued/prefill/decode spans + done instant for a finished
        request from its RequestMetrics timestamps."""
        if not self.enabled:
            return
        uid = rm.uid
        self.name_request_track(uid)
        q = {"uid": uid, "prompt_tokens": rm.prompt_tokens}
        if rm.prefill_start_t is not None:
            self.complete(SPAN_REQUEST_QUEUED, rm.enqueue_t,
                          rm.prefill_start_t - rm.enqueue_t,
                          pid=PID_REQUESTS, tid=uid, args=q)
        if rm.prefill_start_t is not None and rm.first_token_t is not None:
            self.complete(SPAN_REQUEST_PREFILL, rm.prefill_start_t,
                          rm.first_token_t - rm.prefill_start_t,
                          pid=PID_REQUESTS, tid=uid,
                          args={"prefix_hit_tokens": rm.prefix_hit_tokens,
                                "padded": rm.padded_prompt_tokens})
        if rm.first_token_t is not None and rm.finish_t is not None:
            self.complete(SPAN_REQUEST_DECODE, rm.first_token_t,
                          rm.finish_t - rm.first_token_t,
                          pid=PID_REQUESTS, tid=uid,
                          args={"new_tokens": rm.new_tokens})
        if rm.finish_t is not None:
            self.instant(SPAN_REQUEST_DONE, rm.finish_t, pid=PID_REQUESTS,
                         tid=uid, args={"uid": uid})

    def recall_step(self, ts_s: float, dur_s: float, *, sync_pages: float,
                    async_pages: float, reused_pages: float,
                    page_block_bytes: float) -> None:
        """Per-step recall stage spans: the blocking top-up lives on the
        decode track (it is on the critical path); the speculative stage
        for the *next* step runs on the DMA track in parallel with the
        step's compute. Durations are modeled (bytes / MODEL_LINK_BW) in
        simulation; args carry the exact page/byte counts."""
        if not self.enabled:
            return
        if sync_pages > 0:
            b = sync_pages * page_block_bytes
            self.complete(SPAN_RECALL_TOPUP, ts_s,
                          min(b / MODEL_LINK_BW, dur_s),
                          tid=TID_ENGINE,
                          args={"pages": sync_pages, "bytes": b,
                                "modeled": True})
        if async_pages > 0:
            b = async_pages * page_block_bytes
            self.complete(SPAN_RECALL_STAGED, ts_s,
                          min(b / MODEL_LINK_BW, dur_s),
                          tid=TID_DMA,
                          args={"pages": async_pages, "bytes": b,
                                "modeled": True, "hidden": True})
        if reused_pages > 0:
            self.instant(SPAN_RECALL_REUSE, ts_s, tid=TID_DMA,
                         args={"pages": reused_pages})

    # -- export ----------------------------------------------------------
    def chrome_trace(self) -> dict:
        return {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.obs.trace"},
        }

    def write(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.chrome_trace(), f)


def validate_chrome_trace(doc: dict) -> List[str]:
    """Well-formedness check shared by tests and tools/check_obs.py.
    Returns a list of problems (empty = valid)."""
    errors: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["missing traceEvents key"]
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        return ["traceEvents is not a list"]
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        for key in ("ph", "pid", "name"):
            if key not in ev:
                errors.append(f"event {i}: missing {key!r}")
        ph = ev.get("ph")
        if ph in ("X", "i", "C") and "ts" not in ev:
            errors.append(f"event {i}: {ph!r} event missing ts")
        if ph == "X":
            if "dur" not in ev or not isinstance(ev["dur"], (int, float)) \
                    or ev["dur"] < 0:
                errors.append(f"event {i}: X event needs dur >= 0")
            if "tid" not in ev:
                errors.append(f"event {i}: X event missing tid")
    return errors
