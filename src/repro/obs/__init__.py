"""Request-to-kernel observability plane (docs/observability.md).

Three cooperating pieces:

* :mod:`repro.obs.registry` — streaming metrics registry (counters,
  gauges, fixed-bucket histograms with p50/p90/p99); the single
  aggregation substrate behind ``serving/metrics.EngineMetrics``.
* :mod:`repro.obs.trace` — Chrome-trace/Perfetto span recorder for the
  request lifecycle and the recall pipeline, plus ``jax.named_scope``
  annotation hooks on the same span names.
* speculation-quality telemetry — per-step speculative page-hit rate,
  corrected-head count, and selection churn, accumulated **on device**
  inside ``decode_window``'s ``(k, B)`` stat blocks and pulled only at
  sync boundaries (``nonsync_host_bytes`` stays 0 by construction).

``Observability`` bundles the run-level switches; ``ServeEngine`` takes
one and hands it to the scheduler. Metric *values* live in the
per-run registry owned by ``EngineMetrics`` (``eng.last_metrics``), so
exporters always see exactly one run's worth of data.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.obs.registry import (  # noqa: F401  (re-exports)
    COUNT_BUCKETS,
    LATENCY_BUCKETS,
    RATE_BUCKETS,
    SNAPSHOT_SCHEMA_VERSION,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    exponential_buckets,
    linear_buckets,
)
from repro.obs.timeseries import (  # noqa: F401
    DEFAULT_WINDOW_S,
    TIMESERIES_SCHEMA_VERSION,
    TimeSeriesBoard,
    WindowRate,
    WindowStat,
    validate_timeseries_snapshot,
)
from repro.obs.trace import (  # noqa: F401
    SPAN_ATTN_COMPUTE,
    SPAN_DECODE_STEP,
    SPAN_DECODE_WINDOW,
    SPAN_RECALL_CORRECTION,
    SPAN_RECALL_REUSE,
    SPAN_RECALL_SELECT,
    SPAN_RECALL_STAGED,
    SPAN_RECALL_TOPUP,
    SPAN_REQUEST_DECODE,
    SPAN_REQUEST_DONE,
    SPAN_REQUEST_PREFILL,
    SPAN_REQUEST_QUEUED,
    TraceRecorder,
    annotate,
    validate_chrome_trace,
)


@dataclass
class Observability:
    """Run-level observability switches handed to ``ServeEngine``.

    ``enabled`` gates per-step histogram/trace work in the scheduler
    (the registry-backed counters in ``EngineMetrics`` always run — they
    replace the old dataclass fields and cost the same). ``trace`` is
    the span recorder; construct with ``TraceRecorder(enabled=False)``
    to keep lifecycle spans off. ``timeseries`` is the optional
    sliding-window board (``repro.obs.timeseries``) the scheduler feeds
    rolling TTFT/ITL/tokens-per-s/occupancy series into — the payload the
    HTTP front-end serves live at ``/stats``; ``None`` (the default)
    skips all windowed work.
    """

    enabled: bool = True
    trace: TraceRecorder = field(
        default_factory=lambda: TraceRecorder(enabled=False))
    timeseries: "TimeSeriesBoard | None" = None

    @classmethod
    def off(cls) -> "Observability":
        return cls(enabled=False, trace=TraceRecorder(enabled=False))

    @classmethod
    def full(cls) -> "Observability":
        return cls(enabled=True, trace=TraceRecorder(enabled=True),
                   timeseries=TimeSeriesBoard())


def validate_snapshot(snap: dict) -> list:
    """Schema check for ``MetricsRegistry.snapshot()`` dicts / JSONL
    lines (shared by tests and tools/check_obs.py). Returns problems."""
    errors = []
    if not isinstance(snap, dict):
        return ["snapshot is not an object"]
    if snap.get("schema_version") != SNAPSHOT_SCHEMA_VERSION:
        errors.append(f"schema_version != {SNAPSHOT_SCHEMA_VERSION}")
    for sect in ("counters", "gauges", "histograms"):
        if not isinstance(snap.get(sect), dict):
            errors.append(f"missing section {sect!r}")
    for sect in ("counters", "gauges"):
        for name, v in (snap.get(sect) or {}).items():
            if not isinstance(v, (int, float)):
                errors.append(f"{sect}.{name}: non-numeric value")
    for name, h in (snap.get("histograms") or {}).items():
        if not isinstance(h, dict):
            errors.append(f"histograms.{name}: not an object")
            continue
        for key in ("count", "sum", "mean", "p50", "p90", "p99",
                    "buckets", "bucket_counts"):
            if key not in h:
                errors.append(f"histograms.{name}: missing {key!r}")
        bc, b = h.get("bucket_counts"), h.get("buckets")
        if isinstance(bc, list) and isinstance(b, list) \
                and len(bc) != len(b) + 1:
            errors.append(f"histograms.{name}: bucket_counts must have "
                          "len(buckets)+1 entries")
        if isinstance(bc, list) and isinstance(h.get("count"), (int, float)) \
                and sum(bc) != h["count"]:
            errors.append(f"histograms.{name}: bucket_counts don't sum "
                          "to count")
    return errors
