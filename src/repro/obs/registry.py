"""Streaming metrics registry: counters, gauges, fixed-bucket histograms.

The registry is the serving stack's single aggregation substrate
(``serving/metrics.EngineMetrics`` backs its accumulator fields onto it):
every scalar the engine used to keep in an ad-hoc dataclass field is a
named :class:`Counter`/:class:`Gauge` here, and per-request / per-step
latency and speculation-quality distributions land in fixed-bucket
:class:`Histogram` objects with exact counts and interpolated
p50/p90/p99.

Design constraints (see docs/observability.md):

* **Low overhead.** ``observe``/``inc`` are a few Python float ops plus a
  ``bisect`` — no locks (the serving loop is single-threaded host code),
  no label cardinality machinery. Everything the registry records is
  either host bookkeeping the scheduler already does or values pulled at
  an existing sync boundary; it never adds a device round trip.
* **Fixed buckets.** Histograms carry their bucket upper bounds at
  construction; percentile queries interpolate linearly inside the
  containing bucket, so quantiles are deterministic functions of the
  bucket counts (snapshot-stable, mergeable across runs).
* **Two exporters.** :meth:`MetricsRegistry.to_prometheus` emits the
  Prometheus text exposition format (``# TYPE`` lines, cumulative
  ``_bucket{le=...}`` series); :meth:`MetricsRegistry.snapshot` emits a
  stable JSON-able dict (one JSONL line per call via
  :meth:`snapshot_line`), schema-versioned for the CI validator
  (``tools/check_obs.py``).
"""
from __future__ import annotations

import bisect
import json
import math
import re
import threading
import time
from typing import Dict, List, Optional, Sequence

SNAPSHOT_SCHEMA_VERSION = 1

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    """Sanitize a metric name for the Prometheus exposition format."""
    return _NAME_RE.sub("_", name)


def linear_buckets(start: float, width: float, count: int) -> List[float]:
    return [start + width * i for i in range(count)]


def exponential_buckets(start: float, factor: float,
                        count: int) -> List[float]:
    return [start * factor ** i for i in range(count)]


# default latency buckets: 50us .. ~55s, x2 per bucket — wide enough for
# both smoke runs on CPU simulation and real accelerator serving
LATENCY_BUCKETS = exponential_buckets(50e-6, 2.0, 21)
# rates in [0, 1]: 5% resolution plus tight head/tail buckets
RATE_BUCKETS = [0.0, 0.01, 0.05, 0.1, 0.15, 0.2, 0.25, 0.3, 0.35, 0.4,
                0.45, 0.5, 0.55, 0.6, 0.65, 0.7, 0.75, 0.8, 0.85, 0.9,
                0.95, 0.99, 1.0]
# page / head counts per step: 1..4096, x2
COUNT_BUCKETS = [0.0] + exponential_buckets(1.0, 2.0, 13)


class Counter:
    """Monotonic (by convention) accumulator. ``set`` exists so legacy
    ``EngineMetrics`` attribute assignment keeps working."""

    __slots__ = ("name", "help", "_value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self._value += v

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        return self._value


class Gauge(Counter):
    """Last-write-wins scalar (occupancy, wall clock, in-flight drops)."""

    __slots__ = ()


class Histogram:
    """Fixed-bucket histogram with exact count/sum and interpolated
    percentiles.

    ``buckets`` are inclusive upper bounds; an implicit +inf bucket
    catches overflow. ``percentile(q)`` walks the cumulative counts to
    the containing bucket and interpolates linearly inside it (the +inf
    bucket clamps to the highest finite bound — and to the max observed
    value, which is tracked exactly).
    """

    __slots__ = ("name", "help", "buckets", "counts", "_count", "_sum",
                 "_min", "_max")

    def __init__(self, name: str, buckets: Sequence[float], help: str = ""):
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError(f"{name}: buckets must be sorted and non-empty")
        self.name = name
        self.help = help
        self.buckets = [float(b) for b in buckets]
        self.counts = [0] * (len(self.buckets) + 1)   # +1 = +inf overflow
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self._count += 1
        self._sum += v
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    @property
    def min(self) -> float:
        return self._min if self._count else 0.0

    @property
    def max(self) -> float:
        return self._max if self._count else 0.0

    def percentile(self, q: float) -> float:
        """q in [0, 1]; 0 with no observations."""
        if self._count == 0:
            return 0.0
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q={q} outside [0, 1]")
        target = q * self._count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            lo = self.buckets[i - 1] if i > 0 else min(self._min, 0.0)
            hi = self.buckets[i] if i < len(self.buckets) else self._max
            if cum + c >= target:
                frac = (target - cum) / c
                return min(lo + frac * (hi - lo), self._max)
            cum += c
        return self._max

    def summary(self) -> dict:
        return {
            "count": self._count,
            "sum": self._sum,
            "mean": self.mean,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(0.50),
            "p90": self.percentile(0.90),
            "p99": self.percentile(0.99),
        }


class MetricsRegistry:
    """Name -> metric map with get-or-create accessors and two exporters.

    Live-scrape safe: metric *creation* and the exporters take a lock, so
    the HTTP front-end can render ``/metrics`` while the scheduler thread
    registers new series. The hot path (inc/observe on an existing metric,
    reached via a plain dict ``get``) stays lock-free."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._lock = threading.Lock()

    # -- get-or-create -------------------------------------------------
    def counter(self, name: str, help: str = "") -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.get(name)
                if c is None:
                    c = self._counters[name] = Counter(name, help)
        return c

    def gauge(self, name: str, help: str = "") -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.get(name)
                if g is None:
                    g = self._gauges[name] = Gauge(name, help)
        return g

    def histogram(self, name: str, buckets: Optional[Sequence[float]] = None,
                  help: str = "") -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.get(name)
                if h is None:
                    h = self._histograms[name] = Histogram(
                        name,
                        buckets if buckets is not None else LATENCY_BUCKETS,
                        help)
        return h

    # -- exporters -----------------------------------------------------
    def snapshot(self, extra: Optional[dict] = None) -> dict:
        """Stable JSON-able view (schema checked by tools/check_obs.py)."""
        with self._lock:
            return self._snapshot_locked(extra)

    def _snapshot_locked(self, extra: Optional[dict] = None) -> dict:
        snap = {
            "schema_version": SNAPSHOT_SCHEMA_VERSION,
            "unix_time": time.time(),
            "counters": {n: c.value for n, c in sorted(self._counters.items())},
            "gauges": {n: g.value for n, g in sorted(self._gauges.items())},
            "histograms": {
                n: {**h.summary(),
                    "buckets": h.buckets,
                    "bucket_counts": list(h.counts)}
                for n, h in sorted(self._histograms.items())
            },
        }
        if extra:
            snap["extra"] = extra
        return snap

    def snapshot_line(self, extra: Optional[dict] = None) -> str:
        return json.dumps(self.snapshot(extra), sort_keys=True)

    def write_jsonl(self, path: str, extra: Optional[dict] = None) -> None:
        with open(path, "a", encoding="utf-8") as f:
            f.write(self.snapshot_line(extra) + "\n")

    def to_prometheus(self) -> str:
        """Prometheus text exposition (0.0.4): counters, gauges, and
        histograms with cumulative ``le`` buckets."""
        with self._lock:
            return self._to_prometheus_locked()

    def _to_prometheus_locked(self) -> str:
        out: List[str] = []
        for n, c in sorted(self._counters.items()):
            pn = _prom_name(n)
            if c.help:
                out.append(f"# HELP {pn} {c.help}")
            out.append(f"# TYPE {pn} counter")
            out.append(f"{pn} {c.value:g}")
        for n, g in sorted(self._gauges.items()):
            pn = _prom_name(n)
            if g.help:
                out.append(f"# HELP {pn} {g.help}")
            out.append(f"# TYPE {pn} gauge")
            out.append(f"{pn} {g.value:g}")
        for n, h in sorted(self._histograms.items()):
            pn = _prom_name(n)
            if h.help:
                out.append(f"# HELP {pn} {h.help}")
            out.append(f"# TYPE {pn} histogram")
            cum = 0
            for b, c in zip(h.buckets, h.counts):
                cum += c
                out.append(f'{pn}_bucket{{le="{b:g}"}} {cum}')
            out.append(f'{pn}_bucket{{le="+Inf"}} {h.count}')
            out.append(f"{pn}_sum {h.sum:g}")
            out.append(f"{pn}_count {h.count}")
        return "\n".join(out) + "\n"
