"""qwen25-7b — the paper's second efficiency-evaluation model
(Qwen-2.5-7B-Instruct) [arXiv:2412.15115]."""
from repro.configs.base import ArchConfig, ATTN, DENSE

CONFIG = ArchConfig(
    name="qwen25-7b", family="dense", source="arXiv:2412.15115",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab_size=152064,
    pattern=((ATTN, DENSE),), n_periods=28,
    rope_theta=1000000.0,
)
