"""Config dataclasses for architectures, input shapes, meshes and FreeKV.

Every assigned architecture gets one module in this package defining
``CONFIG: ArchConfig`` with the exact dimensions from the assignment table
(source paper / model card cited in the module docstring).

Layer structure is expressed as ``prelude + pattern * n_periods`` where each
layer is a ``(mixer, ffn)`` pair. This lets the model stack params per pattern
position and run ``jax.lax.scan`` over periods, keeping HLO size O(pattern)
instead of O(n_layers) — essential for the 512-device dry-run compiles.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer kinds
# ---------------------------------------------------------------------------
# mixer kinds
ATTN = "attn"            # global softmax attention (GQA)
ATTN_LOCAL = "attn_local"  # sliding-window attention
MAMBA = "mamba"          # Mamba-1 selective SSM
MLSTM = "mlstm"          # xLSTM matrix-memory block
SLSTM = "slstm"          # xLSTM scalar-memory block
# ffn kinds
DENSE = "dense"
MOE = "moe"
NONE = "none"            # block has no separate FFN (xLSTM blocks)

Layer = Tuple[str, str]  # (mixer, ffn)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    source: str                      # citation from the assignment table

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # layer layout: prelude (unscanned) + pattern * n_periods (scanned)
    prelude: Tuple[Layer, ...] = ()
    pattern: Tuple[Layer, ...] = ((ATTN, DENSE),)
    n_periods: int = 0               # 0 -> derived: (n_layers-len(prelude))/len(pattern)

    d_head: int = 0                  # 0 -> d_model // n_heads
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu | gelu
    gated_mlp: bool = True
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0       # stablelm uses partial rotary
    sliding_window: int = 4096       # for ATTN_LOCAL mixers
    attn_logit_softcap: Optional[float] = None    # gemma2
    final_logit_softcap: Optional[float] = None   # gemma2
    post_block_norm: bool = False    # gemma2 pre+post norms
    tie_embeddings: bool = False
    attn_scale: Optional[float] = None  # None -> 1/sqrt(d_head)

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_expert: int = 0                # routed-expert hidden dim (fine-grained MoE)
    router_aux_loss: float = 0.01

    # SSM (mamba)
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2

    # xLSTM
    xlstm_qk_dim_factor: float = 0.5
    xlstm_proj_factor: float = 2.0

    # encoder-decoder / modality frontend
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    frontend: Optional[str] = None   # audio | vision | None
    n_frontend_tokens: int = 0       # stub embedding count (audio frames / patches)

    max_position_embeddings: int = 1 << 20

    # ---------------------------------------------------------------
    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.n_periods == 0:
            body = self.n_layers - len(self.prelude)
            assert body % len(self.pattern) == 0, (
                f"{self.name}: {body} layers not divisible by pattern "
                f"{len(self.pattern)}")
            object.__setattr__(self, "n_periods", body // len(self.pattern))
        assert len(self.prelude) + len(self.pattern) * self.n_periods == self.n_layers

    # -- derived -----------------------------------------------------
    @property
    def group_size(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def layers(self) -> Tuple[Layer, ...]:
        return self.prelude + self.pattern * self.n_periods

    def has_mixer(self, kind: str) -> bool:
        return any(m == kind for m, _ in self.layers)

    @property
    def uses_attention(self) -> bool:
        return self.has_mixer(ATTN) or self.has_mixer(ATTN_LOCAL)

    @property
    def uses_moe(self) -> bool:
        return any(f == MOE for _, f in self.layers)

    def padded_vocab(self, multiple: int = 512) -> int:
        return ((self.vocab_size + multiple - 1) // multiple) * multiple

    # parameter count estimate (for roofline MODEL_FLOPS = 6 N D)
    def param_counts(self) -> dict:
        """Returns {'total': N, 'active': N_active} (active counts top-k experts)."""
        d, dh = self.d_model, self.d_head
        emb = self.padded_vocab() * d * (1 if self.tie_embeddings else 2)
        total = active = emb
        for mixer, ffn in self.layers:
            if mixer in (ATTN, ATTN_LOCAL):
                p = d * dh * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * dh * d
            elif mixer == MAMBA:
                di = self.ssm_expand * d
                p = (d * di * 2 + di * self.ssm_d_conv
                     + di * (self.ssm_d_state * 2 + 2) + di * d)
            elif mixer in (MLSTM, SLSTM):
                di = int(self.xlstm_proj_factor * d)
                dqk = int(self.xlstm_qk_dim_factor * di)
                p = d * (2 * dqk + 2 * di) + di * d + 3 * di
            else:
                raise ValueError(mixer)
            total += p
            active += p
            if ffn == DENSE:
                f = d * self.d_ff * (3 if self.gated_mlp else 2)
                total += f
                active += f
            elif ffn == MOE:
                de = self.d_expert or self.d_ff
                per = d * de * (3 if self.gated_mlp else 2)
                total += per * (self.n_experts + self.n_shared_experts) + d * self.n_experts
                active += per * (self.moe_top_k + self.n_shared_experts) + d * self.n_experts
        if self.is_encoder_decoder:
            # encoder layers (attention + dense ffn) + cross-attention in decoder
            p = (d * dh * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * dh * d
                 + d * self.d_ff * (3 if self.gated_mlp else 2))
            total += p * self.n_encoder_layers
            active += p * self.n_encoder_layers
            xattn = (d * dh * (self.n_heads + 2 * self.n_kv_heads)
                     + self.n_heads * dh * d) * self.n_layers
            total += xattn
            active += xattn
        return {"total": total, "active": active}


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# FreeKV runtime config
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class FreeKVConfig:
    method: str = "freekv"      # freekv | full | streaming | raas | quest |
                                # arkvale | shadowkv | infinigen | centroid
    # ``retriever`` is an alias for ``method`` (the serving-facing name):
    # FreeKVConfig(retriever="centroid") == FreeKVConfig(method="centroid").
    # When both are given, ``retriever`` wins.
    retriever: str = ""
    page_size: int = 32
    budget: int = 2048          # B — tokens resident on device
    n_sink: int = 128           # S
    n_window: int = 128         # W (ring buffer of recent tokens)
    tau: float = 0.8            # correction threshold (0.9 for long-generation)
    summary: str = "minmax"     # minmax | mean | bounding
    group_pool: str = "mean_softmax"  # MeanS (paper's choice); also max_q, mean_q,
                                      # max_qk, mean_qk, max_softmax
    offload: str = "sim"        # sim | host  (host = pinned_host memory kind)
    use_kernels: bool = False   # Pallas kernels (interpret on CPU) vs jnp path
    # §4 system side: overlapped double-buffered streamed recall. When True
    # the speculative recall for step t+1 is *staged* off the critical path
    # (core/recall_pipeline.RecallExecutor) and only a correction top-up —
    # pages for corrected heads not already resident in the previous buffer —
    # blocks step t. Greedy outputs are bit-identical to the synchronous
    # path; only the transfer schedule (and hence sync/async page counts)
    # changes. Applies to freekv (speculative) and shadowkv (V-only delta).
    recall_overlap: bool = True
    # pages per DMA chunk in the double-buffered recall kernel's VMEM ring
    # (0 = auto: min(8, n_sel)); only used when use_kernels=True
    recall_chunk_pages: int = 0
    # Quantized host KV tier (src/repro/quant): store the offloaded pool at
    # int8 / packed int4 with symmetric per-page, per-kv-head fp32 scales.
    # Pages quantize once at offload time (page completion / prefill) and
    # dequantize fused into the recall gather; summaries/selection stay
    # full-precision, so only recalled page *content* changes. "none" is
    # bit-identical to the unquantized framework (no extra state leaves).
    kv_quant: str = "none"      # none | int8 | int4
    # channels per fp32 scale group along d_head (0 = one scale per page
    # half); must divide d_head. Smaller groups = tighter error, more
    # scale bytes per transferred block.
    quant_group_size: int = 0
    skip_first_layer: bool = True  # standard practice: no compression on layer 0
    # Host-sync-free decode loop (serving/scheduler + models.decode_window):
    # sampling runs on device inside the jitted step (per-slot PRNG key
    # streams threaded through the loop carry; the greedy path is
    # bit-identical to host-side argmax) and the engine dispatches up to
    # ``sync_interval`` decode steps per host synchronization. Between syncs
    # zero bytes cross the host boundary; tokens, finished masks and
    # per-step retrieval stats accumulate in device blocks pulled once per
    # sync. The device loop exits early when every slot finishes, or — when
    # the admission queue is non-empty — at the first slot turnover, so
    # occupancy matches the per-step scheduler. sync_interval=1 keeps the
    # per-step cadence (still on-device sampling, still donated state).
    sync_interval: int = 8
    # False = synchronous reference path: full (B, vocab) logits fetched to
    # the host every step and sampled there. Greedy outputs are bit-identical
    # either way (and sampled outputs too: both paths share the per-slot
    # fold_in(key_uid, token_index) streams).
    sample_on_device: bool = True
    # Chunked prefill (serving/scheduler + engine.PrefillJob): admission no
    # longer runs a whole prompt's prefill inline — the prompt is split into
    # chunks of at most ``prefill_chunk_tokens`` tokens, each executed as a
    # ``model.prefill_extend`` continuation of the chunks before it, and the
    # scheduler interleaves one chunk budget per decode window so co-batched
    # decoders stall for at most one chunk's compute instead of the whole
    # prefill. The final chunk builds the paged decode state from the full
    # concatenated K/V — the same math as the prefix-cache extension path —
    # so greedy outputs are bit-identical to whole-shot prefill. 0 = off
    # (whole-shot at admission, the previous behavior). Requires an
    # attention-only stack (``model.supports_kv_extend``); other configs
    # silently fall back to whole-shot.
    prefill_chunk_tokens: int = 0
    # Priority-aware preemption (serving/scheduler + SlotPool.swap_out/in):
    # when the pool is full and a queued request's priority strictly exceeds
    # the lowest-priority running request's, the victim's entire paged KV —
    # pool pages (packed int8/int4 under kv_quant), quant scales, sink and
    # window rings, selection buffers, summaries — is swapped to host memory
    # and the slot is handed over; the victim resumes later via an exact
    # round-trip of the packed representation, so its remaining tokens are
    # bit-identical to an uninterrupted run. False = never preempt.
    preempt: bool = False
    # Pallas kernel execution mode: "auto" = compiled on TPU, interpret
    # elsewhere (the CPU backend cannot lower Mosaic); "interpret" /
    # "compiled" force it (kernels/ops.resolve_interpret).
    kernel_interpret: str = "auto"
    # ShadowKV-like baseline
    svd_rank: int = 160
    # RaaS-like baseline
    raas_decay: int = 512
    # pool page-count padding multiple (512 for production meshes so the page
    # dim shards over any axis combination; 1 for small tests)
    pool_pad_pages: int = 1
    # beyond-paper (paper §6 cites top-p sparsity as orthogonal): dynamic
    # page budget — keep the smallest page set whose pooled softmax mass
    # reaches select_top_p (capped at the static budget). 0 = off.
    select_top_p: float = 0.0
    # beyond-paper (§Perf): shard-local selection + recall + LSE-merged
    # partial attention over the page-sharded pool — removes the cross-shard
    # recall psum and distributes decode attention over the model axis.
    # Selection becomes top-(n_sel/model) PER page shard (approximate).
    sharded_retrieval: bool = False
    # opt2 mitigation (§Perf): each shard over-selects osx candidates and a
    # tiny score all-gather re-ranks them globally — restores global top-k
    # whenever no shard holds more than os*k/mp of the true top-k.
    sharded_overselect: int = 1
    # Centroid-then-token selection (method="centroid", core/centroid_index):
    # per-(layer, kv-head) k-means-style centroids over the host-pool page
    # summaries turn the per-step selection scan from O(n_pages) into
    # O(centroid_count + candidate pages). Clusters carry hierarchical
    # min-max bounding boxes (cluster box = elementwise min/max over member
    # pages' boxes), so the query-vs-centroid score is a true Quest-style
    # upper bound on every member page's score. Corrected heads always fall
    # back to the exact full scan, so mis-clustered heads are corrected
    # rather than lost (see docs/methods.md).
    centroid_count: int = 16
    # re-center cadence, in completed pages: every N-th page completion the
    # index recomputes the centroid means from the current assignments and
    # reassigns every page against the new means (one cheap k-means
    # iteration); between re-centers pages are assigned incrementally
    # against the frozen snapshot, keeping the index bit-reproducible by a
    # full rebuild at any time (tests/test_centroid_index.py).
    centroid_refresh_interval: int = 4
    # Tensor-parallel serving (ServeEngine(tp>1)): every retrieval-side state
    # leaf (pool + quant scales, summaries, sink/window rings, selection
    # buffers) is sharded per KV-head group over a 1-D ('model',) mesh and
    # the whole retrieval step — selection, recall, overlap pipeline,
    # correction, attention — runs shard-local inside one shard_map per
    # attention layer. Backbone weights/activations stay replicated, so the
    # only cross-shard transfer is the per-head-group attention output
    # all-gather and greedy outputs are BIT-IDENTICAL to tp=1. Exact
    # (per-head full top-k) selection — unlike the page-sharded approximate
    # ``sharded_retrieval`` path, with which it is mutually exclusive.
    tp_serving: bool = False
    # Speculative decoding fused with speculative retrieval (core/drafter +
    # models.serve_step_verify): a device-resident per-slot bigram drafter
    # proposes up to ``draft_len`` tokens per window iteration, one batched
    # target pass scores the (B, 1+draft_len) drafted block — retrieval and
    # attention run per drafted position through the exact sequential decode
    # step, so accept-longest-prefix under the per-request PRNG streams makes
    # greedy outputs BIT-IDENTICAL to draft_len=0 — and the rejected suffix's
    # KV lanes are rolled back in place (one staged recall restores the
    # selection buffers, which doubles as the draft-ahead prefetch for the
    # next block). 0 = off: the decode path traces the exact same graph as
    # before. Requires an attention-only stack and method in
    # {freekv, arkvale, infinigen}; mutually exclusive with
    # ``sharded_retrieval`` (see models.supports_spec_decode).
    draft_len: int = 0

    def __post_init__(self):
        if self.retriever:
            object.__setattr__(self, "method", self.retriever)

    @property
    def quant_bits(self) -> int:
        """Bits per stored pool element (0 = unquantized)."""
        from repro.quant.quantizers import quant_bits
        return quant_bits(self.kv_quant)

    @property
    def n_selectable(self) -> int:
        return self.budget - self.n_sink - self.n_window

    @property
    def budget_pages(self) -> int:
        return self.budget // self.page_size


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


# ---------------------------------------------------------------------------
# Reduced ("smoke") variants — 2 layers, d_model<=512, <=4 experts
# ---------------------------------------------------------------------------
def reduce_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Reduced variant of the same family for CPU smoke tests."""
    pat = cfg.pattern
    # keep one period of the pattern, truncated to <=2 layers but preserving
    # the interesting mixers (e.g. keep the attn layer of jamba's period).
    if len(pat) > 2:
        # one layer per distinct mixer (preserving order), preferring the MoE
        # FFN variant of each so the smoke test exercises routing too
        chosen = {}
        order = []
        for m, f in pat:
            if m not in chosen:
                chosen[m] = f
                order.append(m)
            elif f == MOE:
                chosen[m] = f
        pat = tuple((m, chosen[m]) for m in order[:2])
    prelude = cfg.prelude[:1]
    n_layers = len(prelude) + len(pat)
    d_model = min(cfg.d_model, 256)
    n_heads = 4
    n_kv = max(1, min(cfg.n_kv_heads, 2))
    changes = dict(
        n_layers=n_layers, d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        d_head=d_model // n_heads, d_ff=max(cfg.d_ff and 512, 0) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 1024), prelude=prelude, pattern=pat,
        n_periods=1, sliding_window=64,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        n_frontend_tokens=min(cfg.n_frontend_tokens, 16) if cfg.n_frontend_tokens else 0,
        max_position_embeddings=1 << 16,
    )
    if cfg.n_experts:
        changes.update(n_experts=4, moe_top_k=min(cfg.moe_top_k, 2),
                       n_shared_experts=min(cfg.n_shared_experts, 1),
                       d_expert=128 if cfg.d_expert else 0)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **changes)
