"""deepseek-moe-16b [moe] — DeepSeekMoE: fine-grained experts, 2 shared + 64 routed
top-6 [arXiv:2401.06066]. Layer 0 uses a dense FFN (paper's design); d_ff=1408 is the
routed-expert hidden dim per the assignment table."""
from repro.configs.base import ArchConfig, ATTN, DENSE, MOE

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe", source="arXiv:2401.06066",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=102400,
    prelude=((ATTN, DENSE),), pattern=((ATTN, MOE),), n_periods=27,
    n_experts=64, n_shared_experts=2, moe_top_k=6, d_expert=1408,
    rope_theta=10000.0,
)
