"""jamba-1.5-large-398b [hybrid] — Mamba + attention 1:7 interleave, MoE every other
layer (16 experts top-2) [arXiv:2403.19887]. Period of 8 layers: attention at position
4 (middle of the Jamba block), MoE on odd positions."""
from repro.configs.base import ArchConfig, ATTN, MAMBA, DENSE, MOE

_PERIOD = tuple(
    (ATTN if i == 4 else MAMBA, MOE if i % 2 == 1 else DENSE) for i in range(8)
)

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid", source="arXiv:2403.19887",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab_size=65536,
    pattern=_PERIOD, n_periods=9,
    n_experts=16, n_shared_experts=0, moe_top_k=2, d_expert=24576,
    rope_theta=10000.0,
    ssm_d_state=16, ssm_d_conv=4, ssm_expand=2,
)
