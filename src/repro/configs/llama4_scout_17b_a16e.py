"""llama4-scout-17b-a16e [moe] — 16 routed experts top-1 + 1 shared expert, early
fusion [hf:meta-llama/Llama-4-Scout-17B-16E]. All layers MoE (Scout)."""
from repro.configs.base import ArchConfig, ATTN, MOE

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab_size=202048,
    pattern=((ATTN, MOE),), n_periods=48,
    n_experts=16, n_shared_experts=1, moe_top_k=1, d_expert=8192,
    rope_theta=500000.0,
)
