"""granite-3-8b [dense] — GQA [hf:ibm-granite/granite-3.0-2b-base]. Vocab 49155 is not
divisible by the 16-way model axis; ArchConfig.padded_vocab() pads to 49664 for
sharding (Megatron practice), padded logits masked."""
from repro.configs.base import ArchConfig, ATTN, DENSE

CONFIG = ArchConfig(
    name="granite-3-8b", family="dense", source="hf:ibm-granite/granite-3.0-2b-base",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12800,
    vocab_size=49155,
    pattern=((ATTN, DENSE),), n_periods=40,
    rope_theta=10000.0, tie_embeddings=True,
)
