"""stablelm-3b [dense] — MHA (kv=32), LayerNorm, partial rotary (25%)
[hf:stabilityai/stablelm-2-1_6b scaled per assignment dims]."""
from repro.configs.base import ArchConfig, ATTN, DENSE

CONFIG = ArchConfig(
    name="stablelm-3b", family="dense", source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=6912,
    vocab_size=50304,
    pattern=((ATTN, DENSE),), n_periods=32,
    norm="layernorm", rope_fraction=0.25, rope_theta=10000.0,
)
