"""llama31-8b — the paper's primary efficiency-evaluation model
(Llama-3.1-8B-Instruct) [arXiv:2407.21783]."""
from repro.configs.base import ArchConfig, ATTN, DENSE

CONFIG = ArchConfig(
    name="llama31-8b", family="dense", source="arXiv:2407.21783",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=128256,
    pattern=((ATTN, DENSE),), n_periods=32,
    rope_theta=500000.0,
)
