"""whisper-tiny [audio] — encoder-decoder, conv/mel frontend STUBBED per the carve-out
[arXiv:2212.04356]: input_specs() provides precomputed frame embeddings (1500 x d).
LayerNorm + non-gated GELU MLP, MHA (kv=6). Positions use RoPE in this repro
(adaptation: original uses sinusoidal/learned; noted in DESIGN.md)."""
from repro.configs.base import ArchConfig, ATTN, DENSE

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio", source="arXiv:2212.04356",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab_size=51865,
    pattern=((ATTN, DENSE),), n_periods=4,
    norm="layernorm", act="gelu", gated_mlp=False,
    is_encoder_decoder=True, n_encoder_layers=4,
    frontend="audio", n_frontend_tokens=1500,
)
