"""gemma2-2b [dense] — local+global alternating attention, logit softcaps, pre+post
block norms, d_head=256, tied embeddings [arXiv:2408.00118]."""
from repro.configs.base import ArchConfig, ATTN, ATTN_LOCAL, DENSE

CONFIG = ArchConfig(
    name="gemma2-2b", family="dense", source="arXiv:2408.00118",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_ff=9216,
    vocab_size=256000, d_head=256,
    pattern=((ATTN_LOCAL, DENSE), (ATTN, DENSE)), n_periods=13,
    act="gelu", sliding_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    post_block_norm=True, tie_embeddings=True,
    rope_theta=10000.0,
)
