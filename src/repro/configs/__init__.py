"""Architecture config registry.

``get_config(name)`` returns the exact assigned config; ``--arch <id>`` in the
launchers resolves through this registry. ASSIGNED is the 10-arch pool assigned
to this paper; PAPER_MODELS are the models FreeKV itself evaluates on.
"""
from importlib import import_module

from repro.configs.base import (  # noqa: F401
    ArchConfig, FreeKVConfig, MeshConfig, ShapeConfig,
    SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
    SINGLE_POD, MULTI_POD, reduce_for_smoke,
    ATTN, ATTN_LOCAL, MAMBA, MLSTM, SLSTM, DENSE, MOE, NONE,
)

_MODULES = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "xlstm-350m": "xlstm_350m",
    "internvl2-26b": "internvl2_26b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "granite-3-8b": "granite_3_8b",
    "whisper-tiny": "whisper_tiny",
    "stablelm-3b": "stablelm_3b",
    "gemma2-2b": "gemma2_2b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "smollm-360m": "smollm_360m",
    "llama31-8b": "llama31_8b",
    "qwen25-7b": "qwen25_7b",
}

ASSIGNED = (
    "deepseek-moe-16b", "xlstm-350m", "internvl2-26b", "llama4-scout-17b-a16e",
    "granite-3-8b", "whisper-tiny", "stablelm-3b", "gemma2-2b",
    "jamba-1.5-large-398b", "smollm-360m",
)
PAPER_MODELS = ("llama31-8b", "qwen25-7b")


def get_config(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return reduce_for_smoke(get_config(name[: -len("-smoke")]))
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return import_module(f"repro.configs.{_MODULES[name]}").CONFIG


def list_archs():
    return list(_MODULES)
