"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517]. d_ff=0: xLSTM blocks
carry their own up/down projections (proj_factor), no separate FFN. We interleave one
sLSTM per 6 blocks (paper uses sparse sLSTM placement)."""
from repro.configs.base import ArchConfig, MLSTM, SLSTM, NONE

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm", source="arXiv:2405.04517",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304,
    pattern=((MLSTM, NONE),) * 5 + ((SLSTM, NONE),), n_periods=4,
    norm="layernorm", act="gelu", gated_mlp=False,
    xlstm_proj_factor=2.0, xlstm_qk_dim_factor=0.5,
)
