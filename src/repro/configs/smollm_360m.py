"""smollm-360m [dense] — llama-arch small, GQA 15H/kv5, tied embeddings
[hf:HuggingFaceTB/SmolLM-135M scaled per assignment dims]."""
from repro.configs.base import ArchConfig, ATTN, DENSE

CONFIG = ArchConfig(
    name="smollm-360m", family="dense", source="hf:HuggingFaceTB/SmolLM-135M",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab_size=49152,
    pattern=((ATTN, DENSE),), n_periods=32,
    rope_theta=10000.0, tie_embeddings=True,
)
