"""internvl2-26b [vlm] — InternViT + InternLM2 [arXiv:2404.16821]. The vision encoder
+ MLP projector are STUBBED per the assignment carve-out: input_specs() provides
precomputed patch embeddings (n_frontend_tokens x d_model). This config is the
InternLM2-20B-style language backbone (GQA, rmsnorm, silu)."""
from repro.configs.base import ArchConfig, ATTN, DENSE

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm", source="arXiv:2404.16821",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=92553,
    pattern=((ATTN, DENSE),), n_periods=48,
    rope_theta=1000000.0, frontend="vision", n_frontend_tokens=1024,
)
