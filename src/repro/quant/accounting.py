"""Byte accounting for the quantized host KV tier.

All recall-traffic telemetry in the framework counts (kv-head, page) blocks
(``core/recall_pipeline``, ``serving/metrics``); these helpers convert block
counts to bytes under a given ``kv_quant`` mode so the serving engine, the
slot pool, and the benchmarks agree on one definition of the transfer unit:

  dense:  2 * p * d * itemsize                      (K+V halves, fp)
  int8:   2 * p * d * 1      + 2 * n_groups * 4     (payload + fp32 scales)
  int4:   2 * p * (d/2) * 1  + 2 * n_groups * 4

The fp32 scales ride the same DMA as the packed page (they are gathered
per-page alongside the payload), so they count as transferred bytes — the
compression ratios reported by ``benchmarks/quant_quality.py`` include them.
"""
from __future__ import annotations

from repro.quant.quantizers import effective_group, quant_bits

# Nominal dequant throughput (elements/s) for the cost-model estimate of
# dequant overhead in EngineMetrics.summary()["kv_quant"]. Dequant is one
# int->f32 convert + one multiply per element, streaming at HBM-ish rates on
# the target accelerator; the *measured* per-step overhead on this container
# comes from benchmarks/quant_quality.py.
DEQUANT_ELEMS_PER_S = 2.0e10


def scale_bytes_per_block(fkv, d_head: int) -> int:
    """fp32 scale bytes transferred with one (kv-head, page) K+V block."""
    if fkv.kv_quant == "none":
        return 0
    g = effective_group(fkv.quant_group_size, d_head)
    return 2 * (d_head // g) * 4


def page_block_bytes_dense(fkv, d_head: int, itemsize: int = 2) -> int:
    """Unquantized (kv-head, page) K+V block bytes at ``itemsize``/element."""
    return 2 * fkv.page_size * d_head * itemsize


def page_block_bytes(fkv, d_head: int, itemsize: int = 2) -> int:
    """Transferred bytes of one (kv-head, page) block under ``fkv.kv_quant``
    (packed payload + scales; == dense when quantization is off)."""
    bits = quant_bits(fkv.kv_quant)
    if bits == 0:
        return page_block_bytes_dense(fkv, d_head, itemsize)
    payload = 2 * fkv.page_size * (d_head * bits // 8)
    return payload + scale_bytes_per_block(fkv, d_head)


def pool_bytes_detail(state, d_head: int, dense_itemsize: int = 2) -> dict:
    """Physical vs dense-equivalent pool bytes for a decode-state pytree.

    Returns {"payload", "scales", "physical", "dense", "ratio"}: ``payload``
    sums the (possibly packed) pool leaves, ``scales`` the fp32 scale leaves,
    ``dense`` what the same page capacity would occupy unquantized at
    ``dense_itemsize`` bytes/element. Works on any nesting (per-layer dicts,
    the serving slot pool's full state tree)."""
    import jax

    acc = {"payload": 0, "scales": 0, "dense": 0}

    def visit(path, leaf):
        key = str(getattr(path[-1], "key", path[-1]))
        if key == "pool" and hasattr(leaf, "nbytes"):
            acc["payload"] += leaf.nbytes
            n_elems = leaf.size // leaf.shape[-1] * d_head
            acc["dense"] += n_elems * dense_itemsize
        elif key == "pool_scale" and hasattr(leaf, "nbytes"):
            acc["scales"] += leaf.nbytes
        return leaf

    jax.tree_util.tree_map_with_path(visit, state)
    physical = acc["payload"] + acc["scales"]
    return {"payload": acc["payload"], "scales": acc["scales"],
            "physical": physical, "dense": acc["dense"],
            "ratio": acc["dense"] / physical if physical else 1.0}
