"""Symmetric per-page, per-kv-head KV quantizers (pure JAX).

Layout contract (mirrors the HND pool of ``core/paging``):

  * fp pool block   ``(..., 2, p, d)``      — K+V halves of one page
  * int8 pool block ``(..., 2, p, d)``      int8
  * int4 pool block ``(..., 2, p, d//2)``   int8, two nibbles per byte:
    channels ``[0, d/2)`` in the low nibble, ``[d/2, d)`` in the high nibble
    (halves, not interleaved, so channel groups stay contiguous after unpack)
  * scales          ``(..., 2, n_groups)``  float32, ``n_groups = d // g``

Quantization is symmetric absmax: one scale per (page, kv-head, K|V half,
channel group), amax taken over the page's ``p`` tokens x ``g`` channels.
``g = effective_group(group_size, d)`` — ``group_size == 0`` means one scale
per page half (``g = d``). Zero pages get scale 1 so dequant stays exact
zeros. Round-trip error is bounded by ``scale / 2`` per element (plus float
rounding), verified by ``tests/test_quant.py`` property tests.

The gather + dequant reference path (``dequant_recall_pages``) shares the
``(pool, idx) -> (k, v)`` contract of ``core/recall.recall_pages``: invalid
(``idx < 0``) lanes produce exact zeros. The fused kernel
(``kernels/recall_gather.recall_gather_quant``) must match it bit-for-bit in
interpret mode — both dequantize as ``int -> float32 * scale -> out_dtype``.
"""
from __future__ import annotations

import jax.numpy as jnp

_QMAX = {8: 127, 4: 7}


def quant_bits(kv_quant: str) -> int:
    """Bits per stored element for a ``FreeKVConfig.kv_quant`` mode (0=off)."""
    return {"none": 0, "int8": 8, "int4": 4}[kv_quant]


def effective_group(group_size: int, d: int) -> int:
    """Channel-group width per scale; 0 -> whole page half (one scale)."""
    g = group_size if group_size > 0 else d
    if d % g:
        raise ValueError(f"quant_group_size {g} does not divide d_head {d}")
    return g


# ---------------------------------------------------------------------------
# int4 packing (two values per int8 byte, halves layout)
# ---------------------------------------------------------------------------
def pack_int4(q):
    """int8 values in [-8, 7], even last dim d -> int8 packed (..., d//2).

    Byte j holds channel j in the low nibble and channel j + d/2 in the high
    nibble; ``unpack_int4`` is its exact inverse."""
    d = q.shape[-1]
    assert d % 2 == 0, d
    d2 = d // 2
    lo = q[..., :d2] & jnp.int8(0xF)
    hi = q[..., d2:] & jnp.int8(0xF)
    return lo | (hi << 4)


def unpack_int4(packed):
    """int8 packed (..., d//2) -> int8 values in [-8, 7] (..., d)."""
    lo = (packed << 4) >> 4            # arithmetic shifts sign-extend nibbles
    hi = packed >> 4
    return jnp.concatenate([lo, hi], axis=-1)


# ---------------------------------------------------------------------------
# block quantize / dequantize
# ---------------------------------------------------------------------------
def quantize_block(block, bits: int, group_size: int = 0):
    """fp pool block (..., 2, p, d) -> (q int8 (..., 2, p, d_packed),
    scale float32 (..., 2, n_groups))."""
    qmax = _QMAX[bits]
    p, d = block.shape[-2], block.shape[-1]
    g = effective_group(group_size, d)
    n_g = d // g
    xf = block.astype(jnp.float32)
    xg = xf.reshape(*block.shape[:-2], p, n_g, g)
    amax = jnp.abs(xg).max(axis=(-3, -1))              # (..., 2, n_g)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(xg / scale[..., None, :, None]), -qmax, qmax)
    q = q.astype(jnp.int8).reshape(*block.shape[:-2], p, d)
    if bits == 4:
        q = pack_int4(q)
    return q, scale


def dequant_block(q, scale, bits: int, out_dtype=jnp.float32):
    """Inverse of ``quantize_block``: (q, scale) -> fp block (..., 2, p, d)."""
    if bits == 4:
        q = unpack_int4(q)
    p, d = q.shape[-2], q.shape[-1]
    n_g = scale.shape[-1]
    g = d // n_g
    xf = q.astype(jnp.float32).reshape(*q.shape[:-2], p, n_g, g)
    xf = xf * scale.astype(jnp.float32)[..., None, :, None]
    return xf.reshape(*q.shape[:-2], p, d).astype(out_dtype)


# ---------------------------------------------------------------------------
# gather + dequant (the jnp reference recall path; kernel parity target)
# ---------------------------------------------------------------------------
def _gather_blocks(pool, scales, idx):
    B, n_pages, kv = pool.shape[0], pool.shape[1], pool.shape[2]
    safe = jnp.clip(idx, 0, n_pages - 1)
    bI = jnp.arange(B)[:, None, None]
    kI = jnp.arange(kv)[None, :, None]
    return pool[bI, safe, kI], scales[bI, safe, kI]


def dequant_recall_pages(pool, scales, idx, bits: int, out_dtype=jnp.float32):
    """Quantized-pool recall: pool (B, n_pages, kv, 2, p, d_packed) int8;
    scales (B, n_pages, kv, 2, n_g) f32; idx (B, kv, n_sel) int32 (-1 invalid)
    -> (k, v) each (B, kv, n_sel, p, d) in ``out_dtype``, invalid -> zeros."""
    blk, sc = _gather_blocks(pool, scales, idx)        # (B,kv,n,2,p,dp)
    deq = dequant_block(blk, sc, bits, out_dtype)
    deq = jnp.where((idx >= 0)[..., None, None, None], deq,
                    jnp.zeros((), out_dtype))
    return deq[..., 0, :, :], deq[..., 1, :, :]


def dequant_recall_values(pool, scales, idx, bits: int,
                          out_dtype=jnp.float32):
    """ShadowKV-style V-only recall from the quantized pool (half the
    payload; K output is reconstructed elsewhere)."""
    blk, sc = _gather_blocks(pool, scales, idx)
    v = dequant_block(blk[..., 1:, :, :], sc[..., 1:, :], bits, out_dtype)
    v = v[..., 0, :, :]
    return jnp.where((idx >= 0)[..., None, None], v, jnp.zeros((), out_dtype))
