"""Quantized host KV tier: symmetric per-page, per-kv-head int8 / packed-int4
storage for the offloaded pool, with fused dequantization on recall.

FreeKV's recall cost is dominated by host->device bytes per decode step; the
overlapped pipeline (``core/recall_pipeline``) hides that latency but does not
shrink it. This package shrinks it: pages are quantized once at offload time
(page completion / prefill — ``core/paging``), stored packed in the host pool,
and dequantized exactly once on recall — inside the chunked double-buffered
Pallas kernel (``kernels/recall_gather.recall_gather_quant``) or the pure-jnp
reference (``dequant_recall_pages``). Summaries/selection stay full-precision
(they are computed from the raw keys before quantization), so quantization
affects only the *content* of recalled pages, never *which* pages are chosen.

``FreeKVConfig.kv_quant`` selects the mode (``"none"`` | ``"int8"`` |
``"int4"``); ``quant_group_size`` sets the channel-group width per fp32 scale
(0 = one scale per page half). ``"none"`` is bit-identical to the
unquantized framework: no extra state leaves, no graph changes.
"""
from repro.quant.quantizers import (dequant_block, dequant_recall_pages,
                                    dequant_recall_values, effective_group,
                                    pack_int4, quant_bits, quantize_block,
                                    unpack_int4)
from repro.quant.accounting import (DEQUANT_ELEMS_PER_S, page_block_bytes,
                                    page_block_bytes_dense, pool_bytes_detail,
                                    scale_bytes_per_block)

__all__ = [
    "DEQUANT_ELEMS_PER_S", "dequant_block", "dequant_recall_pages",
    "dequant_recall_values", "effective_group", "pack_int4",
    "page_block_bytes", "page_block_bytes_dense", "pool_bytes_detail",
    "quant_bits", "quantize_block", "scale_bytes_per_block", "unpack_int4",
]
