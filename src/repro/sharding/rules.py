"""Sharding rules: pytree paths -> PartitionSpec, MaxText-style but automatic.

Storage strategy (see DESIGN.md §5):
  * weights: 2D-sharded — first dim over the FSDP axes ("data" [+ "pod"]) and
    last dim over "model", whenever divisible (expert tensors: experts dim over
    "model", d over "data"). XLA all-gathers just-in-time (FSDP semantics).
  * batch dims over ("pod","data") when divisible.
  * FreeKV pool: batch over data axes; KV-head dim over "model" when divisible,
    else the *page* dim over "model"; with global batch 1 (long_500k) the page
    dim absorbs all axes (sequence-parallel retrieval).
  * replicate anything indivisible — correctness first, the §Perf loop tunes.

``decode_state_spec``'s KV-head branch is also the single source of truth
for tensor-parallel serving (``ServeEngine(tp>1)``, 1-D ('model',) mesh with
no data axes): the slot pool stores under these shardings
(``serving/kv_slots``) and the per-layer TP shard_map derives its
in/out_specs from the same function (``core/sharded_retrieval
.tp_state_specs``), so storage and compute partitioning cannot diverge.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, FreeKVConfig


def axsize(mesh, names) -> int:
    return math.prod(mesh.shape[n] for n in names)


def _div(n, mesh, names) -> bool:
    return names and all(n2 in mesh.axis_names for n2 in names) \
        and n % axsize(mesh, names) == 0


def batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
def param_spec(mesh, path: str, leaf, fsdp_shard: bool = True) -> P:
    nd = leaf.ndim
    shape = leaf.shape
    fsdp = batch_axes(mesh) if fsdp_shard else ()
    if nd <= 1:
        return P()
    if "embed/tok" in path:
        # (V, d): vocab over "model" so the (tied) LM head produces
        # model-sharded logits feeding the vocab-parallel CE directly;
        # the generic rule's P(data, model) forces a full-vocab f32 logits
        # reshard (67 GB/dev all-gather measured on gemma2 train_4k)
        v = ("model",) if _div(shape[0], mesh, ("model",)) else ()
        dd = fsdp if _div(shape[1], mesh, fsdp) else ()
        return P(v or None, dd or None)
    if nd == 3 and any(k in path for k in ("wg", "wu", "wd")):  # (E, a, b)
        e = ("model",) if _div(shape[0], mesh, ("model",)) else ()
        a = fsdp if _div(shape[1], mesh, fsdp) else ()
        return P(e or None, a or None, None)
    if nd == 3 and "/R" in path:                                 # slstm (nh,4dh,dh)
        return P(None, None, None)
    # generic 2D (+ stacked-period 3D where dim0 is n_periods): shard the two
    # trailing matrix dims
    lead = nd - 2
    d_in, d_out = shape[-2], shape[-1]
    s_in = fsdp if _div(d_in, mesh, fsdp) else ()
    s_out = ("model",) if _div(d_out, mesh, ("model",)) else ()
    return P(*([None] * lead), s_in or None, s_out or None)


def param_shardings(cfg: ArchConfig, mesh, params_shape, fsdp_shard=True):
    def f(path, leaf):
        return NamedSharding(mesh, param_spec(mesh, _path_str(path), leaf,
                                              fsdp_shard=fsdp_shard))
    return jax.tree_util.tree_map_with_path(f, params_shape)


def inference_fsdp(cfg: ArchConfig, mesh, hbm_budget_frac=0.25) -> bool:
    """Inference weight-layout decision: store weights sharded over 'model'
    only (no FSDP dim) when they fit in a fraction of HBM — serving then pays
    ZERO per-step weight all-gathers (the dominant decode collective;
    §Perf log). Giant models (jamba-398B) keep the FSDP dim."""
    mp = mesh.shape["model"] if "model" in mesh.axis_names else 1
    per_dev = cfg.param_counts()["total"] * 2 / mp
    return per_dev > hbm_budget_frac * 16e9  # True -> keep FSDP sharding


# ---------------------------------------------------------------------------
# batches (train / prefill inputs)
# ---------------------------------------------------------------------------
def batch_shardings(cfg: ArchConfig, mesh, batch_shape):
    ba = batch_axes(mesh)

    def f(path, leaf):
        B = leaf.shape[0]
        spec = [ba if _div(B, mesh, ba) else None] + [None] * (leaf.ndim - 1)
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(f, batch_shape)


# ---------------------------------------------------------------------------
# decode state
# ---------------------------------------------------------------------------
def decode_state_spec(cfg: ArchConfig, mesh, path: str, leaf,
                      fkv: FreeKVConfig = None) -> P:
    ba = batch_axes(mesh)
    shape = leaf.shape
    nd = leaf.ndim
    B = shape[0]
    b_ok = _div(B, mesh, ba)
    # stacked-period leading dim: pattern states are (n_periods, B, ...)
    lead = 0
    if "pattern" in path and nd >= 2:
        lead, shape = 1, shape[1:]
        nd -= 1
        B = shape[0]
        b_ok = _div(B, mesh, ba)
    b_spec = ba if b_ok else None

    def out(*rest):
        return P(*([None] * lead), b_spec, *rest)

    key = path.rsplit("/", 1)[-1]
    kv_div = _div(cfg.n_kv_heads, mesh, ("model",))
    sharded_r = bool(fkv and fkv.sharded_retrieval)
    if sharded_r:
        # sharded speculative retrieval (§Perf): pool page-sharded, selected
        # buffers sharded over the n_sel dim — all retrieval ops shard-local
        if key in ("pool", "pool_scale", "summ") \
                and _div(shape[1], mesh, ("model",)):
            return out("model", *([None] * (nd - 2)))
        if key in ("sel_k", "sel_v") and _div(shape[2], mesh, ("model",)):
            return out(None, "model", None, None)
        if key == "sel_idx" and _div(shape[2], mesh, ("model",)):
            return out(None, "model")
    if key in ("pool", "pool_scale", "summ"):
        # (B, n_pages, kv, ...)
        n_pages = shape[1]
        if kv_div:
            return out(None, "model", *([None] * (nd - 3)))
        page_axes = ("model",) if b_ok else tuple(
            a for a in ("pod", "data", "model") if a in mesh.axis_names)
        if _div(n_pages, mesh, page_axes):
            return out(page_axes, *([None] * (nd - 2)))
        return out(*([None] * (nd - 1)))
    if key in ("cent", "cent_mean", "cent_assign", "cent_count"):
        # centroid index (core/centroid_index): kv on axis 2 like summ —
        # cent (B, C, kv, 2, d), cent_mean (B, C, kv, d),
        # cent_assign (B, n_pages, kv), cent_count (B, C, kv)
        return out(None, "model" if kv_div else None, *([None] * (nd - 3)))
    if key in ("sel_k", "sel_v"):                    # (B, kv, n_sel, p, d)
        return out("model" if kv_div else None, None, None, None)
    if key in ("sel_idx",):
        return out("model" if kv_div else None, None)
    if key in ("sink_k", "sink_v", "win_k", "win_v", "k", "v", "xk", "xv"):
        # (B, T, kv, d)
        return out(None, "model" if kv_div else None, None)
    if key in ("k_u",):                              # (B, kv, T, r)
        return out("model" if kv_div else None, None, None)
    if key in ("k_w",):
        return out("model" if kv_div else None, None, None)
    if key in ("keep_k", "keep_v"):
        return out("model" if kv_div else None, None, None, None)
    if key in ("keep_idx", "last_used"):
        return out("model" if kv_div else None, None)
    if key == "qprev":                               # (B, H, d)
        return out("model" if _div(cfg.n_heads, mesh, ("model",)) else None, None)
    if key in ("h",) and nd == 3:                    # mamba (B, di, ds)
        return out("model" if _div(shape[1], mesh, ("model",)) else None, None)
    if key == "conv":                                # (B, dk-1, di)
        return out(None, "model" if _div(shape[2], mesh, ("model",)) else None)
    if key == "C":                                   # mlstm (B, nh, dqk, dv)
        return out(None, None, "model" if _div(shape[3], mesh, ("model",)) else None)
    if key == "n" and nd == 3:
        return out(None, None)
    # scalars / misc (length, pos, m, win_pos, slstm h/c/n/m ...)
    return out(*([None] * (nd - 1)))


def decode_state_shardings(cfg: ArchConfig, mesh, state_shape, fkv=None):
    def f(path, leaf):
        return NamedSharding(
            mesh, decode_state_spec(cfg, mesh, _path_str(path), leaf, fkv))
    return jax.tree_util.tree_map_with_path(f, state_shape)


def replicated(mesh, tree_shape):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree_shape)


def replicated_put(mesh, tree):
    """Place every leaf of ``tree`` replicated over ``mesh``, leaving leaves
    that already carry a mesh sharding untouched.

    Used for the decode-loop carry (tokens, per-slot PRNG keys, finished
    mask — ``serving.scheduler``) under tensor-parallel serving: a freshly
    uploaded lane lands as a single-device array, which the donated window
    jit would otherwise reshard every dispatch; placing it replicated once
    lets the donation alias it in place for the rest of its life."""
    target = NamedSharding(mesh, P())

    def f(leaf):
        if isinstance(getattr(leaf, "sharding", None), NamedSharding):
            return leaf
        return jax.device_put(leaf, target)

    return jax.tree.map(f, tree)
