"""Version shims over moved/renamed JAX APIs.

The codebase targets the current ``jax.shard_map(..., check_vma=...)``
spelling; on the jax-0.4.x line that function still lives at
``jax.experimental.shard_map.shard_map`` and the replication-check kwarg is
named ``check_rep``. This module resolves the right implementation once at
import time so call sites stay on the modern spelling:

    from repro.compat import shard_map
    shard_map(f, mesh=mesh, in_specs=..., out_specs=..., check_vma=False)

The shim is a real fix, not a skip: the sharded recall / vocab-parallel CE /
expert-parallel MoE paths execute under both API generations.
"""
from __future__ import annotations

import inspect

import jax

if hasattr(jax, "shard_map"):
    _impl = jax.shard_map
else:                                  # jax < 0.5: experimental module path
    from jax.experimental.shard_map import shard_map as _impl

# The function location and the check_rep -> check_vma kwarg rename moved
# independently across releases (jax.shard_map existed with check_rep on the
# 0.6.x line), so resolve the kwarg from the signature, not the location.
try:
    _CHECK_KW = ("check_vma"
                 if "check_vma" in inspect.signature(_impl).parameters
                 else "check_rep")
except (ValueError, TypeError):        # builtins without introspectable sigs
    _CHECK_KW = "check_vma"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    return _impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                 **{_CHECK_KW: check_vma})
