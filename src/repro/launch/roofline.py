"""Roofline terms from a compiled dry-run artifact.

    compute term    = HLO_FLOPs_total   / (chips * PEAK_FLOPS)
    memory term     = HLO_bytes_total   / (chips * HBM_BW)
    collective term = collective_bytes  / (chips * ICI_BW)

cost_analysis() reports the *per-device* partitioned module, so totals are
per-device values x chips (the formulas then reduce to per-device / per-chip
peaks). collective_bytes comes from parsing the partitioned HLO: we sum the
result-shape bytes of every all-gather / all-to-all / collective-permute and
2x the operand bytes of all-reduces (ring = reduce-scatter + all-gather),
reduce-scatter counts operand bytes.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

# TPU v5e-class hardware constants (per assignment)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )


def shape_bytes(shape_str: str) -> int:
    """'bf16[128,2048]' or tuple '(f32[8], s32[8])' -> bytes."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str):
    """Returns [(op, result_bytes, line_bytes_charged)] per collective op."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # paired with -start; count once
        res_bytes = shape_bytes(m.group(1))
        op = m.group(2)
        charged = 2 * res_bytes if op == "all-reduce" else res_bytes
        out.append((op, res_bytes, charged))
    return out


def collective_bytes_per_device(hlo_text: str) -> dict:
    per_op = {}
    total = 0
    for op, _, charged in parse_collectives(hlo_text):
        per_op[op] = per_op.get(op, 0) + charged
        total += charged
    return {"total": total, "per_op": per_op,
            "count": len(parse_collectives(hlo_text))}


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> Roofline:
    return Roofline(compute_s=flops_per_dev / PEAK_FLOPS,
                    memory_s=bytes_per_dev / HBM_BW,
                    collective_s=coll_bytes_per_dev / ICI_BW)


def analytic_decode_bytes(cfg, fkv, shape, mesh_shape, fsdp=True) -> float:
    """Exact per-device HBM bytes for one decode step (napkin model):
    weight reads + budget-KV reads (per KV head) + page append + recall reads
    + recurrent-state read/write. Used as the decode memory term because the
    CPU-backend HLO inflates bf16 buffers with f32 round-trips (see
    EXPERIMENTS.md §Method-notes)."""
    import math
    axes = dict(mesh_shape)
    mp = axes.get("model", 1)
    nb = axes.get("data", 1) * axes.get("pod", 1)
    n_dev = mp * nb
    B = shape.global_batch
    B_loc = max(1, B // nb) if B % nb == 0 else B
    it = 2  # bf16
    pc = cfg.param_counts()
    # weights: each device reads its model-axis shard once per step
    w_bytes = pc["active"] * it / mp
    n_attn = sum(1 for m, _ in cfg.layers if m == "attn")
    n_local = sum(1 for m, _ in cfg.layers if m == "attn_local")
    kv, d, p = cfg.n_kv_heads, cfg.d_head, fkv.page_size
    n_sel = max(0, (fkv.budget - fkv.n_sink - fkv.n_window) // p)
    resident = fkv.n_sink + fkv.n_window + p + n_sel * p
    kv_term = B_loc * kv * resident * d * 2 * it
    # kv-head or page sharding splits the budget attention over 'model'
    if cfg.n_kv_heads % mp == 0 or fkv.sharded_retrieval:
        kv_term /= mp
    attn_bytes = kv_term * n_attn
    attn_bytes += (B_loc * kv * min(cfg.sliding_window, 10 ** 9) * d * 2 * it
                   ) * n_local
    # pool append (1 page w) + recall (n_sel pages r) + summaries scan
    n_pages_ctx = shape.seq_len // p
    pool_bytes = B_loc * kv * 2 * p * d * it * (1 + n_sel) * n_attn
    summ_bytes = B_loc * kv * n_pages_ctx * 2 * d * it * n_attn
    if cfg.n_kv_heads % mp == 0 or fkv.sharded_retrieval or B % nb != 0:
        pool_bytes /= mp
        summ_bytes /= mp
    # recurrent states (mamba / xlstm): read + write
    st = 0.0
    for m, _ in cfg.layers:
        if m == "mamba":
            di = cfg.ssm_expand * cfg.d_model
            st += 2 * B_loc * di * cfg.ssm_d_state * 4 / mp
        elif m in ("mlstm", "slstm"):
            di = int(cfg.xlstm_proj_factor * cfg.d_model)
            dqk = int(cfg.xlstm_qk_dim_factor * di)
            st += 2 * B_loc * dqk * (di // max(cfg.n_heads, 1)) * 4
    return w_bytes + attn_bytes + pool_bytes + summ_bytes + st


def model_flops(cfg, shape, n_tokens: int) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode: 2*N_active*D
    per generated token (fwd only), train: 6 N D (fwd+bwd)."""
    pc = cfg.param_counts()
    n_active = pc["active"]
    if shape.mode == "train":
        return 6.0 * n_active * n_tokens
    return 2.0 * n_active * n_tokens
