"""Production meshes. A FUNCTION (not module-level constant) so importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever this host offers (tests / examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def make_tp_mesh(tp: int):
    """1-D ('model',) mesh over the first ``tp`` local devices — the serving
    engine's tensor-parallel mesh (KV-head-group sharding; see
    ``core/sharded_retrieval.TPGroupShardedRetriever``). On CPU, force
    devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    before importing jax."""
    n = len(jax.devices())
    assert n >= tp, (f"tp={tp} needs {tp} devices, have {n} "
                     "(set --xla_force_host_platform_device_count on CPU)")
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:tp]), ("model",))
