"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m-smoke \
        --steps 100 --batch 8 --seq 128 [--model-parallel 1]

Uses whatever devices the host offers (make_host_mesh); the production-mesh
path is exercised by launch/dryrun.py.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import lm_batches
from repro.launch.mesh import make_host_mesh
from repro.sharding import rules
from repro.training import checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m-smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = make_host_mesh(args.model_parallel)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps)
    params, opt_state = init_train(cfg, opt, jax.random.PRNGKey(0))
    with mesh:
        p_sh = rules.param_shardings(cfg, mesh, params)
        params = jax.device_put(params, p_sh)
        step = jax.jit(make_train_step(cfg, opt, mesh=mesh),
                       donate_argnums=(0, 1))
        data = lm_batches(cfg.vocab_size, args.seq, args.batch, seed=0)
        t0 = time.time()
        for i in range(args.steps):
            params, opt_state, m = step(params, opt_state,
                                        {"tokens": jnp.asarray(next(data))})
            if i % args.log_every == 0 or i == args.steps - 1:
                tput = args.batch * args.seq * (i + 1) / (time.time() - t0)
                print(f"step {i:5d} loss={float(m['loss']):.4f} "
                      f"lr={float(m['lr']):.2e} tok/s={tput:.0f}", flush=True)
    if args.ckpt:
        checkpoint.save(args.ckpt, {"params": params, "opt": opt_state})
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
