import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede any jax import: jax locks the device count at first init.
# The 512 placeholder host devices exist ONLY for this dry-run; tests and
# benchmarks see the real single CPU device.

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, ASSIGNED, SHAPES
from repro.configs.base import ArchConfig, FreeKVConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_cost, roofline as rl
from repro.models.model import (init_params, prefill, serve_step,
                                init_decode_state)
from repro.sharding import rules
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import make_train_step

PARAM_DTYPE = jnp.bfloat16
ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def dryrun_fkv(page_size=32) -> FreeKVConfig:
    # paper's long-generation serving configuration (Sec. 5.3)
    return FreeKVConfig(method="freekv", page_size=page_size, budget=2048,
                        n_sink=512, n_window=512, tau=0.9,
                        pool_pad_pages=512)


def input_specs(cfg: ArchConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, T = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.mode in ("train", "prefill"):
        batch = {"tokens": sds((B, T), jnp.int32)}
        if cfg.frontend is not None:
            batch["frontend"] = sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                    PARAM_DTYPE)
        return batch
    return {"tokens": sds((B, 1), jnp.int32)}


def _opt_cfg(cfg: ArchConfig) -> AdamWConfig:
    # bf16 optimizer state for >50B-param archs so a single pod fits (DESIGN.md)
    big = cfg.param_counts()["total"] > 5e10
    return AdamWConfig(state_dtype="bfloat16" if big else "float32")


def _with_periods(cfg: ArchConfig, n: int) -> ArchConfig:
    return dataclasses.replace(
        cfg, n_layers=len(cfg.prelude) + len(cfg.pattern) * n, n_periods=n)


def _build(cfg: ArchConfig, shape: ShapeConfig, mesh, fkv: FreeKVConfig,
           infer_weight_layout: bool = False):
    """Returns (jitted_fn, example_args) for one (cfg, shape, mesh).

    ``infer_weight_layout``: store weights model-sharded only (no FSDP dim)
    for inference shapes when they fit — §Perf optimization 1."""
    params_shape = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), PARAM_DTYPE))
    fsdp = True
    if infer_weight_layout and shape.mode != "train":
        fsdp = rules.inference_fsdp(cfg, mesh)
    p_sh = rules.param_shardings(cfg, mesh, params_shape, fsdp_shard=fsdp)
    batch = input_specs(cfg, shape)
    if shape.mode == "train":
        opt_cfg = _opt_cfg(cfg)
        opt_shape = jax.eval_shape(lambda p: adamw_init(p, opt_cfg),
                                   params_shape)
        opt_sh = {"m": p_sh, "v": p_sh, "step": NamedSharding(mesh, P())}
        b_sh = rules.batch_shardings(cfg, mesh, batch)
        step_fn = make_train_step(cfg, opt_cfg, mesh=mesh)
        jf = jax.jit(step_fn, in_shardings=(p_sh, opt_sh, b_sh),
                     out_shardings=(p_sh, opt_sh, None),
                     donate_argnums=(0, 1))
        return jf, (params_shape, opt_shape, batch)
    if shape.mode == "prefill":
        b_sh = rules.batch_shardings(cfg, mesh, batch)
        state_shape = jax.eval_shape(
            lambda: init_decode_state(cfg, fkv, shape.global_batch,
                                      shape.seq_len + 64, PARAM_DTYPE))
        st_sh = rules.decode_state_shardings(cfg, mesh, state_shape, fkv)

        def pf(p, b):
            return prefill(cfg, fkv, p, b, max_len=shape.seq_len + 64,
                           mesh=mesh, state_dtype=PARAM_DTYPE)
        jf = jax.jit(pf, in_shardings=(p_sh, b_sh), out_shardings=(None, st_sh))
        return jf, (params_shape, batch)
    # decode
    state_shape = jax.eval_shape(
        lambda: init_decode_state(cfg, fkv, shape.global_batch,
                                  shape.seq_len + 64, PARAM_DTYPE))
    st_sh = rules.decode_state_shardings(cfg, mesh, state_shape, fkv)
    tok_sh = rules.batch_shardings(cfg, mesh, batch)

    def step(p, s, t):
        return serve_step(cfg, fkv, p, s, t["tokens"], mesh=mesh)
    jf = jax.jit(step, in_shardings=(p_sh, st_sh, tok_sh),
                 out_shardings=(None, st_sh), donate_argnums=(1,))
    return jf, (params_shape, state_shape, batch)


def _costs(compiled, n_devices):
    ca = compiled.cost_analysis() or {}
    coll = rl.collective_bytes_per_device(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll["total"]), "coll_detail": coll}


def lower_case(arch: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    fkv = dryrun_fkv()
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "n_devices": mesh.devices.size, "mode": shape.mode}

    with mesh:
        t0 = time.time()
        jf, args = _build(cfg, shape, mesh, fkv)
        lowered = jf.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        ma = compiled.memory_analysis()
        per_dev = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                   + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "per_device_total": int(per_dev),
            "fits_16GB": bool(per_dev < 16e9),
        }
        raw = _costs(compiled, mesh.devices.size)
        rec["cost_raw_xla"] = {k: raw[k] for k in ("flops", "bytes", "coll")}

        # XLA's cost model counts a while-loop body ONCE; the layer scan runs
        # n_periods times and the time scans T/chunk times. Use the
        # loop-aware HLO analyzer (launch/hlo_cost.py) instead.
        hc = hlo_cost.analyze(compiled.as_text())
        rec["cost"] = {
            "flops_per_device": hc["flops"],
            "bytes_accessed_per_device": hc["bytes"],
            "collective_bytes_per_device": hc["coll"],
        }
        rec["collectives"] = {"total": hc["coll"],
                              "per_op": hc["coll_per_op"]}
        rec["top_comps"] = [
            {"name": n, **{k: v for k, v in d.items()}}
            for n, d in hlo_cost.top_computations(hc, "flops", 6)]
        ext = hc

        mem_bytes = ext["bytes"]
        rec["cost"]["bytes_hlo_upper"] = ext["bytes"]
        if shape.mode == "decode":
            # decode HBM term: analytic (exact); the CPU-backend HLO wraps
            # every bf16 buffer in f32 round trips (EXPERIMENTS §Method-notes)
            mem_bytes = rl.analytic_decode_bytes(
                cfg, fkv, shape, dict(mesh.shape), fsdp=True)
            rec["cost"]["bytes_analytic"] = mem_bytes
        terms = rl.roofline_terms(ext["flops"], mem_bytes, ext["coll"])
        n_tokens = shape.global_batch * (shape.seq_len
                                         if shape.mode != "decode" else 1)
        mf = rl.model_flops(cfg, shape, n_tokens)
        rec["roofline"] = {
            "compute_s": terms.compute_s, "memory_s": terms.memory_s,
            "collective_s": terms.collective_s, "dominant": terms.dominant,
            "model_flops_total": mf,
            "hlo_flops_total": ext["flops"] * mesh.devices.size,
            "useful_flops_ratio": (mf / (ext["flops"] * mesh.devices.size)
                                   if ext["flops"] else 0.0),
        }
    return rec


def run(archs, shapes, meshes, out_dir=ARTIFACT_DIR, skip_existing=True):
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                path = os.path.join(out_dir, tag + ".json")
                if skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    rec = lower_case(arch, shape, mp)
                    rec["status"] = "ok"
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                    print(f"  ERROR: {e!r}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec.get("status") == "ok":
                    r = rec.get("roofline", {})
                    print(f"  ok lower={rec.get('lower_s')}s "
                          f"compile={rec.get('compile_s')}s "
                          f"mem/dev={rec['memory']['per_device_total']/1e9:.2f}GB "
                          f"dominant={r.get('dominant')} "
                          f"useful={r.get('useful_flops_ratio', 0):.3f}",
                          flush=True)
                results.append(rec)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    archs = list(ASSIGNED) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    run(archs, shapes, meshes, skip_existing=not args.force)


if __name__ == "__main__":
    main()
