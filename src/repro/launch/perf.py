import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: lower + compile named optimization variants of a
(arch x shape) pair and report the roofline-term deltas.

    PYTHONPATH=src python -m repro.launch.perf --arch granite-3-8b \
        --shape decode_32k --variants baseline opt1 opt1+opt2
"""
import argparse
import dataclasses
import json
import time

import jax

from repro.configs import get_config, SHAPES
from repro.launch import hlo_cost, roofline as rl
from repro.launch.dryrun import _build, dryrun_fkv
from repro.launch.mesh import make_production_mesh

VARIANTS = {
    # paper-faithful distributed baseline
    "baseline": dict(),
    # opt1: inference weight layout — no FSDP dim when weights fit on the
    # model axis (zero per-step weight all-gathers)
    "opt1": dict(infer_weights=True),
    # opt2: sharded speculative retrieval (shard-local select/recall/attend,
    # LSE merge) — beyond-paper
    "opt1+opt2": dict(infer_weights=True, sharded_retrieval=True),
    "opt2": dict(sharded_retrieval=True),
    # opt3: flash KV-chunk 512 -> 2048 (prefill memory-term hypothesis)
    "opt3": dict(attn_chunk=2048),
    "opt3b": dict(attn_chunk=4096),
}


def run_variant(arch, shape_name, name, multi_pod=False):
    spec = VARIANTS[name]
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    fkv = dryrun_fkv()
    if spec.get("sharded_retrieval"):
        fkv = dataclasses.replace(fkv, sharded_retrieval=True)
    if spec.get("attn_chunk"):
        from repro.models import attention as _attn
        _attn.CHUNK_OVERRIDE = spec["attn_chunk"]
    with mesh:
        t0 = time.time()
        jf, args = _build(cfg, shape, mesh, fkv,
                          infer_weight_layout=spec.get("infer_weights", False))
        compiled = jf.lower(*args).compile()
        dt = time.time() - t0
        ma = compiled.memory_analysis()
        per_dev = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                   + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        hc = hlo_cost.analyze(compiled.as_text())
    from repro.models import attention as _attn
    _attn.CHUNK_OVERRIDE = None
    mem_bytes = hc["bytes"]
    if shape.mode == "decode":   # same convention as dryrun (§Method-notes)
        mem_bytes = rl.analytic_decode_bytes(cfg, fkv, shape,
                                             dict(mesh.shape))
    terms = rl.roofline_terms(hc["flops"], mem_bytes, hc["coll"])
    return {
        "variant": name, "arch": arch, "shape": shape_name,
        "compile_s": round(dt, 1),
        "mem_gb": per_dev / 1e9,
        "compute_s": terms.compute_s, "memory_s": terms.memory_s,
        "collective_s": terms.collective_s, "dominant": terms.dominant,
        "bound_s": terms.bound_s,
        "coll_per_op": {k: v for k, v in hc["coll_per_op"].items()},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", nargs="+", default=["baseline", "opt1"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    results = []
    for v in args.variants:
        r = run_variant(args.arch, args.shape, v, args.multi_pod)
        results.append(r)
        print(f"{v:14s} bound={r['bound_s']*1e6:9.1f}us dominant={r['dominant']:10s} "
              f"compute={r['compute_s']*1e6:8.1f} memory={r['memory_s']*1e6:8.1f} "
              f"collective={r['collective_s']*1e6:8.1f} mem={r['mem_gb']:.2f}GB",
              flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
