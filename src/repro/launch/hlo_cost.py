"""Loop-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, which
under-counts everything inside the layer scan (x n_periods) and the time scans
(x T/chunk) by orders of magnitude. This module re-derives

    flops            (dot/convolution ops, 2 * result_elems * contraction)
    hbm bytes        (operands + results of scheduled top-level instructions)
    collective bytes (all-gather / all-reduce / reduce-scatter / all-to-all /
                      collective-permute, all-reduce charged 2x)

from the compiled HLO text, multiplying every instruction by the product of
trip counts of its enclosing while loops (trip count parsed from the loop
condition's comparison constant). Bytes are only charged in *scheduled*
computations (entry + loop bodies), not inside fusion subcomputations, which
mirrors what the XLA cost model does for fused ops.

It also returns a per-computation breakdown used by the §Perf iteration loop
as the "profile".
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|\S+))\s+([\w\-]+)\(")
_CALLEE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BODY_COND = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_OPERANDS = re.compile(r"%([\w.\-]+)")
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_elems_bytes(type_str):
    n_total, b_total = 0, 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        n_total += n
        b_total += n * _DTYPE_BYTES[dt]
    return n_total, b_total


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclass
class Computation:
    name: str
    params: dict = field(default_factory=dict)   # name -> type str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # symbol -> type str


def parse_hlo(text: str):
    comps = {}
    cur = None
    entry = None
    for line in text.splitlines():
        m = _COMP_HDR.match(line)
        if m:
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            # params: "a: f32[2,3], b: (s32[], f32[4])"
            ptxt = m.group(2)
            for pm in re.finditer(r"([\w.\-]+)\s*:\s*((?:\([^()]*\)|[^,()]+(?:\[[^\]]*\])?(?:\{[^}]*\})?))",
                                  ptxt):
                cur.params[pm.group(1)] = pm.group(2)
                cur.shapes[pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        im = _INSTR.match(line)
        if im:
            ins = Instr(im.group(1), im.group(2), im.group(3), line.strip())
            cur.instrs.append(ins)
            cur.shapes[ins.name] = ins.type_str
        if line.strip() == "}":
            cur = None
    return comps, entry


def _trip_count(comps, caller: Computation, while_line: str,
                cond_name: str) -> int:
    """Loop trip count. Two cases:
    (a) the bound is an inline constant in the condition computation;
    (b) (grad-of-scan) the bound is a carried tuple element: resolve the
        get-tuple-element index used by the condition's compare back through
        the while's init tuple in the caller to a constant."""
    cond = comps.get(cond_name)
    best = 1
    if cond is None:
        return best
    for ins in cond.instrs:
        for c in re.finditer(r"constant\((\d+)\)", ins.line):
            best = max(best, int(c.group(1)))
    # dataflow path: GTE indices referenced in the condition
    gte_idx = []
    for ins in cond.instrs:
        if ins.op == "get-tuple-element":
            m = re.search(r"index=(\d+)", ins.line)
            if m:
                gte_idx.append(int(m.group(1)))
    if not gte_idx:
        return best
    init_ops = _OPERANDS.findall(while_line.split("while(", 1)[1])
    init_name = init_ops[0] if init_ops else None
    tuple_line = next((i.line for i in caller.instrs
                       if i.name == init_name and i.op == "tuple"), None)
    if tuple_line is None:
        return best
    elems = _OPERANDS.findall(tuple_line.split("tuple(", 1)[1])
    const_defs = {i.name: i.line for i in caller.instrs if i.op == "constant"}
    for n in gte_idx:
        if n < len(elems) and elems[n] in const_defs:
            c = re.search(r"constant\((\d+)\)", const_defs[elems[n]])
            if c:
                best = max(best, int(c.group(1)))
    return best


def _dot_flops(comp: Computation, ins: Instr) -> float:
    res_elems, _ = shape_elems_bytes(ins.type_str)
    ops = _OPERANDS.findall(ins.line.split("(", 1)[1])
    lhs = next((o for o in ops if o in comp.shapes), None)
    contract = 1
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    if lhs is not None and cm and cm.group(1):
        dims_m = _SHAPE_RE.search(comp.shapes[lhs])
        if dims_m and dims_m.group(2):
            dims = [int(x) for x in dims_m.group(2).split(",")]
            for ci in cm.group(1).split(","):
                ci = int(ci)
                if ci < len(dims):
                    contract *= dims[ci]
    return 2.0 * res_elems * contract


def _instr_bytes(comp: Computation, ins: Instr, comps=None) -> int:
    if ins.op in ("parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "after-all"):
        return 0
    _, out_b = shape_elems_bytes(ins.type_str)
    args = ins.line.split("(", 1)[1]
    args = args.split("), ")[0]
    op_bytes = []
    for o in _OPERANDS.findall(args):
        if o in comp.shapes:
            _, b = shape_elems_bytes(comp.shapes[o])
            op_bytes.append(b)
    # slicing/update ops touch only the moved slice, not the whole buffer
    # (XLA aliases the big operand in place); charging the full operand makes
    # a paged-KV decode look like it re-reads the entire pool every step
    if ins.op == "convert":
        # dtype-only round trips are CPU-backend artifacts (no native bf16):
        # the TPU target does not materialize them — excluded from the
        # roofline's HBM-bytes term (documented in EXPERIMENTS.md)
        return 0
    if ins.op in ("gather", "dynamic-slice"):
        return 2 * out_b
    if ins.op in ("dynamic-update-slice", "scatter"):
        # operands = (big buffer, update, indices): charge 2x the update
        big = max(op_bytes) if op_bytes else out_b
        others = [b for b in op_bytes if b != big]
        upd = max(others) if others else out_b
        return 2 * upd
    if ins.op == "fusion" and comps is not None:
        # in-place-update fusions (containing DUS/scatter, possibly wrapped
        # in CPU-backend dtype converts) alias their big operand: charge the
        # delta, not the whole buffer
        cm = re.search(r"calls=%?([\w.\-]+)", ins.line)
        callee = comps.get(cm.group(1)) if cm else None
        if callee is not None and callee.instrs and any(
                i.op in ("dynamic-update-slice", "scatter")
                for i in callee.instrs):
            big = max(op_bytes) if op_bytes else 0
            return max(out_b + sum(op_bytes) - 2 * big, 0)
    return out_b + sum(op_bytes)


def analyze(text: str):
    comps, entry = parse_hlo(text)
    # build multipliers by BFS from entry
    mult = defaultdict(float)
    scheduled = defaultdict(bool)
    mult[entry] = 1.0
    scheduled[entry] = True
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            bc = _BODY_COND.search(ins.line)
            if ins.op == "while" and bc:
                cond_name, body_name = bc.group(1), bc.group(2)
                trips = _trip_count(comps, comp, ins.line, cond_name)
                mult[body_name] += mult[cname] * trips
                scheduled[body_name] |= scheduled[cname]
                for nm in (body_name, cond_name):
                    if nm not in seen:
                        seen.add(nm)
                        order.append(nm)
            else:
                for cal in _CALLEE.finditer(ins.line):
                    nm = cal.group(1)
                    mult[nm] += mult[cname]
                    # fusion/reduce callees are not scheduled (no HBM traffic)
                    if nm not in seen:
                        seen.add(nm)
                        order.append(nm)

    flops = 0.0
    hbm_bytes = 0.0
    coll_bytes = 0.0
    coll_per_op = defaultdict(float)
    per_comp = {}
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        c_fl = c_by = c_co = 0.0
        for ins in comp.instrs:
            if ins.op in ("dot", "convolution"):
                c_fl += _dot_flops(comp, ins)
            if ins.op.startswith(COLLECTIVES):
                base = ins.op
                for c in COLLECTIVES:
                    if ins.op.startswith(c):
                        base = c
                if ins.op.endswith("-done"):
                    continue
                _, b = shape_elems_bytes(ins.type_str)
                charged = 2 * b if base == "all-reduce" else b
                c_co += charged
                coll_per_op[base] += charged * m
            if scheduled.get(cname):
                c_by += _instr_bytes(comp, ins, comps)
        flops += c_fl * m
        if scheduled.get(cname):
            hbm_bytes += c_by * m
        coll_bytes += c_co * m
        if c_fl or c_by or c_co:
            per_comp[cname] = {"mult": m, "flops": c_fl * m,
                               "bytes": c_by * m if scheduled.get(cname) else 0,
                               "coll": c_co * m}
    return {"flops": flops, "bytes": hbm_bytes, "coll": coll_bytes,
            "coll_per_op": dict(coll_per_op), "per_comp": per_comp}


def top_computations(result, key="flops", n=8):
    items = sorted(result["per_comp"].items(), key=lambda kv: -kv[1][key])
    return items[:n]
