"""Serving launcher CLI: drive the continuous-batching engine (admission
queue, per-slot lifecycle, optional radix-trie prefix cache) — or the static
chunked fallback — against any arch + retrieval method, with the overlapped
double-buffered recall pipeline on by default (``--no-overlap`` for the
synchronous reference; outputs are bit-identical either way).

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b-smoke \
        --method freekv --context 512 --new-tokens 16 --batch 2 \
        --scheduler continuous --prefix-cache-tokens 4096 --tp 2

``--tp N`` serves tensor-parallel over a 1-D ('model',) mesh: the paged KV
slot pool, host pool (+ quant scales), summaries and selection state shard
per KV-head group, the whole retrieval step runs shard-local, and greedy
outputs are bit-identical to ``--tp 1`` (docs/serving.md).

Prints per-request completions plus ``EngineMetrics.summary()`` (tokens/s,
slot occupancy, TTFT, hidden vs exposed recall transfer). Observability
exporters (docs/observability.md): ``--metrics-out`` appends one JSONL
metrics-registry snapshot per run, ``--prom-out`` writes the Prometheus
text exposition, ``--trace-out`` writes a Chrome-trace/Perfetto JSON of
the request lifecycle + recall-pipeline spans. See ``docs/serving.md``
and ``docs/architecture.md``.
"""
import argparse
import json
import os

import jax

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.data.synthetic import needle_stream
from repro.models.model import init_params
from repro.obs import Observability, TraceRecorder
from repro.serving.engine import Request, ServeEngine
from repro.serving.sampling import SamplerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b-smoke")
    ap.add_argument("--method", default="freekv")
    ap.add_argument("--context", type=int, default=512)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--requests", type=int, default=0,
                    help="total requests (default: one per batch slot)")
    ap.add_argument("--budget", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--tau", type=float, default=0.8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--scheduler", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--prefill-bucket", type=int, default=64)
    ap.add_argument("--prefix-cache-tokens", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: at most N prompt tokens per "
                         "scheduler round, interleaved with decode windows "
                         "(0 = whole-shot; greedy outputs bit-identical)")
    ap.add_argument("--preempt", action="store_true",
                    help="priority preemption: swap the lowest-priority "
                         "running request's KV to host when a strictly "
                         "higher-priority request waits for a slot")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable the overlapped recall pipeline (use the "
                         "synchronous blocking-recall reference path)")
    ap.add_argument("--kv-quant", choices=("none", "int8", "int4"),
                    default="none",
                    help="quantized host KV tier: store the offloaded pool "
                         "packed with fused dequant-on-recall")
    ap.add_argument("--quant-group-size", type=int, default=0,
                    help="channels per fp32 scale group (0 = per page half)")
    ap.add_argument("--sync-interval", type=int, default=8,
                    help="decode steps dispatched per host synchronization "
                         "(host-sync-free loop; 1 = sync every step)")
    ap.add_argument("--draft-len", type=int, default=0,
                    help="speculative decoding: tokens the on-device bigram "
                         "drafter proposes per verify step (0 = off). One "
                         "batched target pass verifies the drafted block and "
                         "commits the longest greedy-consistent prefix — "
                         "outputs stay bit-identical, steps get wider. "
                         "Requires the continuous scheduler + on-device "
                         "sampling; the engine falls back to 0 otherwise.")
    ap.add_argument("--no-spec-decode", action="store_true",
                    help="force draft_len=0 regardless of --draft-len")
    ap.add_argument("--host-sampling", action="store_true",
                    help="disable on-device sampling (synchronous reference "
                         "path: one host round trip per decode step; greedy "
                         "outputs bit-identical either way)")
    ap.add_argument("--kernel-interpret",
                    choices=("auto", "interpret", "compiled"), default="auto",
                    help="Pallas kernel mode: auto = compiled on TPU, "
                         "interpret elsewhere")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel shards (KV-head-group sharding "
                         "over a 1-D mesh; bit-identical greedy outputs vs "
                         "--tp 1). On CPU, forces XLA host devices when "
                         "needed — set --tp before other jax users import.")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="append a JSONL metrics-registry snapshot "
                         "(counters/gauges/histograms) after the run")
    ap.add_argument("--prom-out", default=None, metavar="PATH",
                    help="write the Prometheus text exposition after the run")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON (request "
                         "lifecycle + recall-pipeline spans)")
    ap.add_argument("--no-obs", action="store_true",
                    help="disable per-step observability histograms/spans "
                         "(registry counters always run)")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="engine-level TTFT SLO (ms): completed requests "
                         "are tagged and summary()['slo'] reports "
                         "attainment + goodput (tokens/s from SLO-meeting "
                         "requests)")
    ap.add_argument("--slo-itl-ms", type=float, default=None,
                    help="engine-level mean inter-token-latency SLO (ms)")
    ap.add_argument("--serve-http", action="store_true",
                    help="serve the async streaming HTTP front-end instead "
                         "of running a fixed batch: POST /generate (chunked "
                         "NDJSON token stream), GET /metrics (Prometheus), "
                         "GET /stats (sliding-window time series), "
                         "GET /healthz. Ctrl-C to stop.")
    ap.add_argument("--host", default="127.0.0.1",
                    help="HTTP front-end bind address (--serve-http)")
    ap.add_argument("--port", type=int, default=8008,
                    help="HTTP front-end port (--serve-http; 0 = ephemeral)")
    args = ap.parse_args()

    if args.tp > 1 and "--xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        # must happen before jax initializes its backends
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.tp}")

    cfg = get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    fkv = FreeKVConfig(method=args.method, page_size=args.page_size,
                       budget=args.budget, n_sink=args.page_size * 2,
                       n_window=args.page_size * 2, tau=args.tau,
                       recall_overlap=not args.no_overlap,
                       kv_quant=args.kv_quant,
                       quant_group_size=args.quant_group_size,
                       sync_interval=args.sync_interval,
                       sample_on_device=not args.host_sampling,
                       prefill_chunk_tokens=args.prefill_chunk,
                       preempt=args.preempt,
                       kernel_interpret=args.kernel_interpret,
                       draft_len=0 if args.no_spec_decode else args.draft_len)
    if args.no_obs:
        obs = Observability.off()
    else:
        from repro.obs import TimeSeriesBoard
        obs = Observability(
            enabled=True,
            trace=TraceRecorder(enabled=bool(args.trace_out)),
            # the HTTP front-end serves the windowed series at /stats
            timeseries=TimeSeriesBoard() if args.serve_http else None)
    eng = ServeEngine(cfg, fkv, params,
                      max_len=args.context + args.new_tokens + args.page_size
                      + args.prefill_bucket,
                      batch_size=args.batch,
                      sampler=SamplerConfig(temperature=args.temperature),
                      scheduler=args.scheduler,
                      prefill_bucket=args.prefill_bucket,
                      prefix_cache_tokens=args.prefix_cache_tokens,
                      tp=args.tp, obs=obs,
                      slo_ttft_ms=args.slo_ttft_ms,
                      slo_itl_ms=args.slo_itl_ms)

    if args.serve_http:
        from repro.serving.frontend import (EngineService, HttpFrontend,
                                            run_http_frontend)
        svc = EngineService(eng, seed=0).start()
        fe = HttpFrontend(svc, args.host, args.port)
        print(f"serving {args.arch}/{args.method} on "
              f"http://{args.host}:{args.port} "
              "(POST /generate, GET /metrics /stats /healthz)")
        try:
            run_http_frontend(svc, args.host, args.port, frontend=fe)
        finally:
            svc.stop()
            em = eng.last_metrics
            if em is not None:
                _finish_run(args, em, obs)
        return

    n_req = args.requests or args.batch
    stream = needle_stream(cfg.vocab_size, args.context, args.page_size)
    reqs = [Request(uid=i, tokens=next(stream).tokens,
                    max_new_tokens=args.new_tokens) for i in range(n_req)]
    for out in eng.generate(reqs):
        steps = max(out.steps, 1)
        print(f"req {out.uid}: {out.tokens}")
        print(f"  prefill {out.prefill_s*1e3:.1f} ms | "
              f"decode {out.decode_s/steps*1e3:.1f} ms/step | "
              f"corr_rate {out.stats.get('correction_rate', 0):.3f}")
    em = eng.last_metrics
    if em is not None:
        _finish_run(args, em, obs)


def _finish_run(args, em, obs):
    """End-of-run reporting shared by batch mode and --serve-http."""
    print(json.dumps(em.summary(), indent=2, default=str))
    sd = em.specdec_summary()
    if sd["draft_len"] > 0:
        print(f"spec-decode (draft_len={sd['draft_len']}): accept rate "
              f"{sd['accept_rate']:.3f} | {sd['tokens_per_step']:.2f} tokens "
              f"per target step over {sd['verify_steps']} verify steps")
    slo = em.slo_summary()
    if slo["tagged"]:
        print(f"SLO (ttft<={slo['ttft_ms']}ms, itl<={slo['itl_ms']}ms): "
              f"{slo['attained']}/{slo['tagged']} attained "
              f"({slo['attainment']:.1%}) | goodput "
              f"{slo['goodput_tokens_per_s']:.1f} tok/s "
              f"(total {em.tokens_per_s:.1f} tok/s)")
    if args.metrics_out:
        em.registry.write_jsonl(args.metrics_out,
                                extra={"arch": args.arch,
                                       "method": args.method,
                                       "tp": args.tp})
        print(f"metrics snapshot appended to {args.metrics_out}")
    if args.prom_out:
        with open(args.prom_out, "w", encoding="utf-8") as f:
            f.write(em.registry.to_prometheus())
        print(f"prometheus exposition written to {args.prom_out}")
    if args.trace_out and obs.trace.enabled:
        obs.trace.write(args.trace_out)
        print(f"trace written to {args.trace_out} "
              f"({len(obs.trace.events)} events)")


if __name__ == "__main__":
    main()
