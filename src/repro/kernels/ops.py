"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU, where
the kernels lower through Mosaic. The wrappers are the only entry points the
rest of the framework uses.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.centroid_scores import centroid_scores as _centroid
from repro.kernels.flash_prefill import flash_prefill as _flash
from repro.kernels.page_scores import default_interpret as _default_interpret
from repro.kernels.page_scores import page_scores as _scores
from repro.kernels.page_summary import page_summary as _summary
from repro.kernels.paged_attention import paged_attention as _paged
from repro.kernels.recall_gather import recall_gather as _recall
from repro.kernels.recall_gather import recall_gather_quant as _recall_quant


def resolve_interpret(fkv=None, interpret=None):
    """Resolve the kernel execution mode: an explicit ``interpret`` wins,
    then ``FreeKVConfig.kernel_interpret`` ("interpret" / "compiled"), then
    the backend default ("auto": compiled on TPU, interpret elsewhere)."""
    if interpret is not None:
        return interpret
    mode = getattr(fkv, "kernel_interpret", "auto") if fkv is not None \
        else "auto"
    if mode == "auto":
        return _default_interpret()
    assert mode in ("interpret", "compiled"), mode
    return mode == "interpret"


def paged_attention(q, k_pages, v_pages, page_pos, cur_pos, *, scale,
                    softcap=None, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _paged(q, k_pages, v_pages, page_pos, cur_pos, scale=scale,
                  softcap=softcap, interpret=interpret)


def page_summary(k, *, page_size, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _summary(k, page_size=page_size, interpret=interpret)


def page_scores(q, summ, *, scale, block_pages=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    N = summ.shape[1]
    bp = block_pages
    while N % bp:
        bp //= 2
    return _scores(q, summ, scale=scale, block_pages=max(bp, 1),
                   interpret=interpret)


def centroid_scores(q, cent, count, *, scale, interpret=None):
    """Stage-1 centroid-box scoring for the centroid retriever: q vs the
    C cluster bounding boxes (C << n_pages); empty clusters -> NEG_INF."""
    interpret = _default_interpret() if interpret is None else interpret
    return _centroid(q, cent, count, scale=scale, interpret=interpret)


def recall_gather(pool, idx, *, chunk=None, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _recall(pool, idx, chunk=chunk, interpret=interpret)


def recall_values(pool, idx, *, chunk=None, interpret=None):
    """ShadowKV-style V-only recall: half the transfer, K output unused."""
    interpret = _default_interpret() if interpret is None else interpret
    _, v = _recall(pool, idx, values_only=True, chunk=chunk,
                   interpret=interpret)
    return v


def recall_gather_quant(pool, scales, idx, *, bits, out_dtype=jnp.float32,
                        chunk=None, interpret=None):
    """Fused dequant-on-recall from the packed int8/int4 host pool
    (src/repro/quant): page payload + fp32 scales stream through the same
    2-deep VMEM ring; dequant to ``out_dtype`` happens in-kernel."""
    interpret = _default_interpret() if interpret is None else interpret
    return _recall_quant(pool, scales, idx, bits=bits, out_dtype=out_dtype,
                         chunk=chunk, interpret=interpret)


def recall_values_quant(pool, scales, idx, *, bits, out_dtype=jnp.float32,
                        chunk=None, interpret=None):
    """V-only fused dequant recall (ShadowKV x quantized pool)."""
    interpret = _default_interpret() if interpret is None else interpret
    _, v = _recall_quant(pool, scales, idx, bits=bits, out_dtype=out_dtype,
                         values_only=True, chunk=chunk, interpret=interpret)
    return v


def flash_prefill(q, k, v, *, scale, causal=True, window=None, softcap=None,
                  interpret=None, blq=128, blk=128):
    interpret = _default_interpret() if interpret is None else interpret
    return _flash(q, k, v, scale=scale, causal=causal, window=window,
                  softcap=softcap, blq=blq, blk=blk, interpret=interpret)


REFS = {
    "paged_attention": ref.paged_attention_ref,
    "page_summary": ref.page_summary_ref,
    "page_scores": ref.page_scores_ref,
    "centroid_scores": ref.centroid_scores_ref,
    "recall_gather": ref.recall_gather_ref,
    "flash_prefill": ref.flash_prefill_ref,
}
