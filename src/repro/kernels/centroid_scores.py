"""Pallas kernel: query vs centroid bounding-box scoring (stage 1 of the
centroid-then-token retriever, ``core/centroid_index``).

score[g, c] = scale * sum_d max(q[g,d] * lo[c,d], q[g,d] * hi[c,d])

with (lo, hi) the hierarchical bounding box of cluster ``c`` — the
elementwise min/max over its member pages' Quest summaries — so the score is
a true upper bound on any member page's score. Empty clusters (count == 0)
score NEG_INF so they can never win a candidate slot.

The centroid count C is small (tens) by construction, so a single grid cell
per (batch, kv-head) holds the whole C axis; no page-axis tiling is needed.
Interpret-mode parity with ``ref.centroid_scores_ref`` is covered by
``tests/test_centroid_index.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, lo_ref, hi_ref, cnt_ref, o_ref, *, scale):
    q = q_ref[0, 0].astype(jnp.float32)            # (G, d)
    lo = lo_ref[0, :, 0].astype(jnp.float32)       # (C, d)
    hi = hi_ref[0, :, 0].astype(jnp.float32)
    cnt = cnt_ref[0, :, 0]                         # (C,)
    # sum_d max(q*lo, q*hi) == relu(q) @ hi^T + min(q,0) @ lo^T  (lo <= hi)
    dot = lambda a, b: jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    s = (dot(jnp.maximum(q, 0), hi) + dot(jnp.minimum(q, 0), lo)) * scale
    s = jnp.where((cnt > 0)[None, :], s, NEG_INF)
    o_ref[0, 0] = s.astype(o_ref.dtype)


def centroid_scores(q, cent, count, *, scale, interpret=None):
    """q (B, kv, G, d); cent (B, C, kv, 2, d); count (B, C, kv) int32
    -> (B, kv, G, C) f32 upper-bound scores, NEG_INF for empty clusters."""
    if interpret is None:
        from repro.kernels.page_scores import default_interpret
        interpret = default_interpret()
    B, kv, G, d = q.shape
    C = cent.shape[1]
    lo, hi = cent[..., 0, :], cent[..., 1, :]      # (B, C, kv, d)
    kern = functools.partial(_kernel, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(B, kv),
        in_specs=[
            pl.BlockSpec((1, 1, G, d), lambda b, k: (b, k, 0, 0)),
            pl.BlockSpec((1, C, 1, d), lambda b, k: (b, 0, k, 0)),
            pl.BlockSpec((1, C, 1, d), lambda b, k: (b, 0, k, 0)),
            pl.BlockSpec((1, C, 1), lambda b, k: (b, 0, k)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, C), lambda b, k: (b, k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, kv, G, C), jnp.float32),
        interpret=interpret,
    )(q, lo, hi, count)
