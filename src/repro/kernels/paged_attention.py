"""Pallas TPU kernel: decode attention over per-KV-head selected pages.

This is FreeKV's decode hot spot: one query token per request attends to the
budget-resident pages (sink + window + speculatively recalled), laid out NHD
(page-major (p, d) blocks). Flash-style online softmax over a page-grid:

  grid = (B, kv, N_pages); each step loads one (p, d) K page and V page into
  VMEM, updates running (m, l, acc) scratch for all G group queries, and the
  final step writes acc/l. Pallas pipelines the (b, kv, n) grid, so page n+1's
  HBM->VMEM DMA overlaps page n's compute — the on-chip mirror of the paper's
  double-buffered streamed recall.

Tiling: p=32 x d=128 blocks are MXU/lane aligned; G (GQA group) rides in the
sublane dimension of the q block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, pos_ref, cur_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale, softcap, n_pages):
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, d)
    k = k_ref[0, 0, 0].astype(jnp.float32)         # (p, d)
    v = v_ref[0, 0, 0].astype(jnp.float32)         # (p, d)
    pos = pos_ref[0, 0, 0]                         # (p,) int32
    cur = cur_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (G,p)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    ok = (pos >= 0) & (pos <= cur)
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.max(s, axis=1)                     # (G,)
    m_new = jnp.maximum(m_prev[:, 0], m_cur)
    alpha = jnp.exp(m_prev[:, 0] - m_new)
    pexp = jnp.exp(s - m_new[:, None])             # (G, p)
    l_new = l_prev[:, 0] * alpha + jnp.sum(pexp, axis=1)
    acc_new = acc_prev * alpha[:, None] + jax.lax.dot_general(
        pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new[:, None]
    l_ref[...] = l_new[:, None]
    acc_ref[...] = acc_new

    @pl.when(n == n_pages - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, page_pos, cur_pos, *, scale,
                    softcap=None, interpret=True):
    """q (B,kv,G,d); k/v_pages (B,kv,N,p,d); page_pos (B,kv,N,p);
    cur_pos (B,) -> (B,kv,G,d)."""
    B, kv, G, d = q.shape
    N, p = k_pages.shape[2], k_pages.shape[3]
    grid = (B, kv, N)
    kern = functools.partial(_kernel, scale=scale, softcap=softcap, n_pages=N)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, d), lambda b, k, n: (b, k, 0, 0)),
            pl.BlockSpec((1, 1, 1, p, d), lambda b, k, n: (b, k, n, 0, 0)),
            pl.BlockSpec((1, 1, 1, p, d), lambda b, k, n: (b, k, n, 0, 0)),
            pl.BlockSpec((1, 1, 1, p), lambda b, k, n: (b, k, n, 0)),
            pl.BlockSpec((1,), lambda b, k, n: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, d), lambda b, k, n: (b, k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, kv, G, d), q.dtype),
        scratch_shapes=[
            # (G,1) running max / denom + (G,d) accumulator, fp32 in VMEM
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_pages, v_pages, page_pos, cur_pos)
