"""Pallas kernel: flash attention for the 32K prefill path (GQA, causal,
optional sliding window + logit softcap).

Grid (B, H, Tq/blq, Tk/blk); K/V index maps fold the GQA group (head h reads
KV head h // G). Running (m, l, acc) scratch in VMEM; fully-masked KV blocks
are skipped with pl.when (causal upper triangle and out-of-window blocks),
which halves the causal work versus mask-only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, softcap, window, blq, blk, n_kb, causal):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = iq * blq
    k_lo = ik * blk
    # block-level skip: fully above the diagonal, or fully left of the window
    run = True
    if causal:
        run = k_lo <= q_lo + blq - 1
    if window is not None:
        run = jnp.logical_and(run, k_lo + blk - 1 > q_lo - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (blq, d)
        k = k_ref[0, 0].astype(jnp.float32)        # (blk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        tq = q_lo + jax.lax.broadcasted_iota(jnp.int32, (blq, blk), 0)
        tk = k_lo + jax.lax.broadcasted_iota(jnp.int32, (blq, blk), 1)
        ok = jnp.ones((blq, blk), bool)
        if causal:
            ok &= tk <= tq
        if window is not None:
            ok &= tk > tq - window
        s = jnp.where(ok, s, NEG_INF)
        m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
        m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev[:, 0] - m_new)
        pexp = jnp.exp(s - m_new[:, None])
        l_ref[...] = (l_prev[:, 0] * alpha + jnp.sum(pexp, axis=1))[:, None]
        acc_ref[...] = acc_prev * alpha[:, None] + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new[:, None]

    @pl.when(ik == n_kb - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_prefill(q, k, v, *, scale, causal=True, window=None, softcap=None,
                  blq=128, blk=128, interpret=True):
    """q (B, H, T, d); k/v (B, kv, T, d) -> (B, H, T, d)."""
    B, H, T, d = q.shape
    kv = k.shape[1]
    G = H // kv
    blq, blk = min(blq, T), min(blk, T)
    assert T % blq == 0 and T % blk == 0
    n_kb = T // blk
    kern = functools.partial(_kernel, scale=scale, softcap=softcap,
                             window=window, blq=blq, blk=blk, n_kb=n_kb,
                             causal=causal)
    return pl.pallas_call(
        kern,
        grid=(B, H, T // blq, n_kb),
        in_specs=[
            pl.BlockSpec((1, 1, blq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, blk, d), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, blk, d), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blq, 1), jnp.float32),
            pltpu.VMEM((blq, 1), jnp.float32),
            pltpu.VMEM((blq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
