"""Pallas kernel: Quest min-max page scoring for selection (§3.2).

score[g, n] = scale * sum_d max(q[g,d] * lo[n,d], q[g,d] * hi[n,d])

Grid tiles the page axis in blocks of 128 (lane-aligned); q's GQA group rides
the sublane dim. This runs off the critical path under speculative retrieval
but on it for corrected heads, so it is a genuine hot spot at 16K+ pages.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, lo_ref, hi_ref, o_ref, *, scale):
    q = q_ref[0, 0].astype(jnp.float32)            # (G, d)
    lo = lo_ref[0, :, 0].astype(jnp.float32)       # (NB, d)
    hi = hi_ref[0, :, 0].astype(jnp.float32)
    # sum_d max(q*lo, q*hi) == relu(q) @ hi^T + min(q,0) @ lo^T  (lo <= hi)
    dot = lambda a, b: jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    s = dot(jnp.maximum(q, 0), hi) + dot(jnp.minimum(q, 0), lo)
    o_ref[0, 0] = (s * scale).astype(o_ref.dtype)


def default_interpret() -> bool:
    """Backend-derived kernel execution mode: compiled (Mosaic) on TPU,
    interpret on every other backend — the single source of truth
    (``kernels.ops`` builds its wrappers and ``resolve_interpret`` on it)."""
    return jax.default_backend() != "tpu"


def page_scores(q, summ, *, scale, block_pages=128, interpret=None):
    """q (B, kv, G, d); summ (B, n_pages, kv, 2, d) -> (B, kv, G, n_pages) f32.

    ``interpret=None`` derives the execution mode from the backend
    (``default_interpret``) — override per call or globally via
    ``FreeKVConfig.kernel_interpret`` (see ``kernels.ops.resolve_interpret``).
    """
    if interpret is None:
        interpret = default_interpret()
    B, kv, G, d = q.shape
    N = summ.shape[1]
    NB = min(block_pages, N)
    assert N % NB == 0, (N, NB)
    lo, hi = summ[..., 0, :], summ[..., 1, :]      # (B, N, kv, d)
    kern = functools.partial(_kernel, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(B, kv, N // NB),
        in_specs=[
            pl.BlockSpec((1, 1, G, d), lambda b, k, j: (b, k, 0, 0)),
            pl.BlockSpec((1, NB, 1, d), lambda b, k, j: (b, j, k, 0)),
            pl.BlockSpec((1, NB, 1, d), lambda b, k, j: (b, j, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, NB), lambda b, k, j: (b, k, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, kv, G, N), jnp.float32),
        interpret=interpret,
    )(q, lo, hi)
