"""Pallas kernel: fused min/max page summaries over post-RoPE keys.

Runs at page-offload time (off the critical path): one grid step reduces one
(p, d) key page to its (2, d) bounding box (Quest-style summary, §3.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(k_ref, o_ref):
    k = k_ref[0, :, 0, :]                        # (p, d)
    o_ref[0, 0, 0, 0] = jnp.min(k, axis=0)
    o_ref[0, 0, 0, 1] = jnp.max(k, axis=0)


def page_summary(k, *, page_size, interpret=True):
    """k (B, T, kv, d) with T = n_pages * p -> (B, n_pages, kv, 2, d)."""
    B, T, kv, d = k.shape
    p = page_size
    assert T % p == 0
    N = T // p
    return pl.pallas_call(
        _kernel,
        grid=(B, N, kv),
        in_specs=[pl.BlockSpec((1, p, 1, d), lambda b, n, h: (b, n, h, 0))],
        out_specs=pl.BlockSpec((1, 1, 1, 2, d),
                               lambda b, n, h: (b, n, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N, kv, 2, d), k.dtype),
        interpret=interpret,
    )(k)
