"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def page_summary_ref(k_pages):
    """k_pages (B, n_pages, p, kv, d) -> (B, n_pages, kv, 2, d) min/max."""
    lo = k_pages.min(axis=2)
    hi = k_pages.max(axis=2)
    return jnp.stack([lo, hi], axis=3)


def page_scores_ref(q, summ, scale):
    """q (B, kv, G, d); summ (B, n_pages, kv, 2, d) -> (B, kv, G, n_pages).

    Quest scoring: sum_d max(q*min, q*max) == max of the two inner products
    taken coordinate-wise BEFORE the sum; note this is sum(max(q*lo, q*hi)),
    not max(q@lo, q@hi)."""
    lo = summ[..., 0, :].astype(jnp.float32)      # (B,n,kv,d)
    hi = summ[..., 1, :].astype(jnp.float32)
    qf = q.astype(jnp.float32)
    e_lo = qf[:, :, :, None, :] * lo.transpose(0, 2, 1, 3)[:, :, None]
    e_hi = qf[:, :, :, None, :] * hi.transpose(0, 2, 1, 3)[:, :, None]
    return jnp.maximum(e_lo, e_hi).sum(-1) * scale


def centroid_scores_ref(q, cent, count, scale):
    """q (B, kv, G, d); cent (B, C, kv, 2, d); count (B, C, kv)
    -> (B, kv, G, C). Quest scoring against cluster bounding boxes;
    empty clusters (count == 0) score NEG_INF."""
    s = page_scores_ref(q, cent, scale)           # (B,kv,G,C)
    ok = count.transpose(0, 2, 1)[:, :, None, :] > 0
    return jnp.where(ok, s, -1e30)


def paged_attention_ref(q, k_pages, v_pages, page_pos, cur_pos, scale,
                        softcap=None):
    """Decode attention over per-KV-head page sets.

    q        (B, kv, G, d)
    k/v_pages(B, kv, N, p, d)
    page_pos (B, kv, N, p) int32, -1 = masked
    cur_pos  (B,) int32
    -> (B, kv, G, d)
    """
    B, kv, N, p, d = k_pages.shape
    k = k_pages.reshape(B, kv, N * p, d)
    v = v_pages.reshape(B, kv, N * p, d)
    pos = page_pos.reshape(B, kv, N * p)
    s = jnp.einsum("bkgd,bkld->bkgl", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    ok = (pos >= 0) & (pos <= cur_pos[:, None, None])
    s = jnp.where(ok[:, :, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgl,bkld->bkgd", w, v.astype(jnp.float32)).astype(q.dtype)


def recall_gather_ref(pool, idx):
    """pool (B, n_pages, kv, 2, p, d) HND; idx (B, kv, n_sel)
    -> k, v (B, kv, n_sel, p, d)."""
    B, n_pages, kv, _, p, d = pool.shape
    safe = jnp.clip(idx, 0, n_pages - 1)
    bI = jnp.arange(B)[:, None, None]
    kI = jnp.arange(kv)[None, :, None]
    blk = pool[bI, safe, kI]
    blk = jnp.where((idx >= 0)[..., None, None, None], blk, 0)
    return blk[..., 0, :, :], blk[..., 1, :, :]


def flash_prefill_ref(q, k, v, scale, causal=True, window=None):
    """q (B, H, T, d); k/v (B, kv, T, d) -> (B, H, T, d)."""
    B, H, T, d = q.shape
    kv = k.shape[1]
    G = H // kv
    qg = q.reshape(B, kv, G, T, d).astype(jnp.float32)
    s = jnp.einsum("bkgtd,bksd->bkgts", qg, k.astype(jnp.float32)) * scale
    ti = jnp.arange(T)
    ok = jnp.ones((T, T), bool)
    if causal:
        ok &= ti[None, :] <= ti[:, None]
    if window is not None:
        ok &= ti[None, :] > ti[:, None] - window
    s = jnp.where(ok, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bksd->bkgtd", w, v.astype(jnp.float32))
    return o.reshape(B, H, T, d).astype(q.dtype)
