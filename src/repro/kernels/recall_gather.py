"""Pallas kernel: double-buffered streamed recall (§4.2, TPU adaptation).

Gathers the selected KV pages out of the HND pool into NHD device buffers.
The page index feeding each grid step's BlockSpec comes from a SCALAR-PREFETCH
operand (the selected page ids), so the pipeline's DMA engine fetches page
n+1's (2, p, d) HND block from (host-mapped) HBM while page n's layout
conversion/store executes — Pallas' automatic grid pipelining IS the paper's
two staging buffers (double buffering), expressed TPU-natively.

The 16 KiB contiguous (2*p*d, bf16) transfer unit is the paper's maximal-unit
argument verbatim: the HND pool keeps each (kv-head, page) block contiguous.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, pool_ref, k_ref, v_ref):
    b, h, n = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    valid = idx_ref[b, h, n] >= 0
    blk = pool_ref[0, 0, 0]                       # (2, p, d) HND block
    zero = jnp.zeros_like(blk[0])
    k_ref[0, 0, 0] = jnp.where(valid, blk[0], zero)   # NHD (p, d) halves
    v_ref[0, 0, 0] = jnp.where(valid, blk[1], zero)


def recall_gather(pool, idx, *, interpret=True):
    """pool (B, n_pages, kv, 2, p, d) HND; idx (B, kv, n_sel) int32 (-1 pad)
    -> (k, v) each (B, kv, n_sel, p, d)."""
    B, n_pages, kv, _, p, d = pool.shape
    n_sel = idx.shape[2]

    def pool_map(b, h, n, idx_ref):
        page = jnp.clip(idx_ref[b, h, n], 0, n_pages - 1)
        return (b, page, h, 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, kv, n_sel),
        in_specs=[pl.BlockSpec((1, 1, 1, 2, p, d), pool_map)],
        out_specs=[
            pl.BlockSpec((1, 1, 1, p, d), lambda b, h, n, idx_ref: (b, h, n, 0, 0)),
            pl.BlockSpec((1, 1, 1, p, d), lambda b, h, n, idx_ref: (b, h, n, 0, 0)),
        ],
    )
    out_shape = [jax.ShapeDtypeStruct((B, kv, n_sel, p, d), pool.dtype),
                 jax.ShapeDtypeStruct((B, kv, n_sel, p, d), pool.dtype)]
    k, v = pl.pallas_call(
        _kernel, grid_spec=grid_spec, out_shape=out_shape, interpret=interpret,
    )(idx, pool)
    return k, v
