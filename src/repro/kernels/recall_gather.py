"""Pallas kernel: chunked double-buffered streamed recall (§4.2, TPU).

Gathers the selected KV pages out of the HND pool into NHD device buffers
with an explicit two-deep VMEM ring: while chunk *c*'s pages drain from the
ring slot into the outputs (layout conversion + store), chunk *c+1*'s DMAs
stream into the alternate slot. This is the paper's double buffering
expressed with manual ``pltpu.make_async_copy`` descriptors — one DMA per
selected page, because selected pages are scattered in the pool; each DMA
moves the maximal contiguous unit, the ``(2, p, d)`` HND K+V block
(16 KiB at p=32, d=128, bf16). The page ids arrive as a SCALAR-PREFETCH
operand so the copy source addresses are computable before the body runs.

The pool stays in ``pltpu.ANY`` memory space ((host-mapped) HBM — see
``core/offload.py``); the *staging* footprint is the ring alone (2 chunks of
pages), independent of the selection budget. The per-(b, h) output blocks
are ``(n_sel, p, d)`` and do scale with the budget — at production shapes
(n_sel=32, p=32, d=128, bf16) that is 256 KiB per output, well under VMEM.

Invalid (``-1``-padded) lanes issue no DMA at all — the masked split the
recall executor plans (top-up vs staged vs reused) is a physical traffic
split, not just accounting. ``values_only=True`` transfers just the V half
of each block (ShadowKV-style recall, half the bytes); the K output is then
all zeros.

Contract (shared with ``core/recall.recall_pages`` and
``kernels/ref.recall_gather_ref``): ``(pool, idx) -> (k, v)``, invalid pages
(``idx < 0``) produce zeros. Interpret-mode parity on CPU is covered by
``tests/test_recall_pipeline.py``; orchestration of *which* pages transfer
on vs off the decode critical path lives in ``core/recall_pipeline.py``.

``recall_gather_quant`` is the quantized-pool variant (``src/repro/quant``):
the packed int8/int4 page and its fp32 scales ride the same ring as two DMAs
per lane, and dequantization to the output dtype is fused into the drain —
the transfer moves 2-4x fewer bytes and the fp page never exists outside
VMEM. Parity vs the jnp dequant reference: ``tests/test_quant.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, pool_ref, k_ref, v_ref, scratch, sems, *,
            n_sel, n_pages, chunk, n_chunks, values_only):
    b, h = pl.program_id(0), pl.program_id(1)

    def lane_valid(i):
        # invalid (-1 padded) and tail lanes issue NO DMA at all — the
        # transfer truly skips them, matching the telemetry's block counts
        return (i < n_sel) & (idx_ref[b, h, jnp.minimum(i, n_sel - 1)] >= 0)

    def page_of(i):
        return jnp.clip(idx_ref[b, h, jnp.minimum(i, n_sel - 1)],
                        0, n_pages - 1)

    def dma(slot, j, i):
        src = pool_ref.at[b, page_of(i), h]
        if values_only:
            src = src.at[1]                    # V half of the (2, p, d) block
        return pltpu.make_async_copy(src, scratch.at[slot, j],
                                     sems.at[slot, j])

    def start_chunk(slot, c):
        for j in range(chunk):                 # one DMA per scattered page
            i = c * chunk + j

            @pl.when(lane_valid(i))
            def _():
                dma(slot, j, i).start()

    start_chunk(0, 0)                          # warm-up: fill ring slot 0

    def body(c, _):
        slot = jax.lax.rem(c, 2)
        nxt = jax.lax.rem(c + 1, 2)

        @pl.when(c + 1 < n_chunks)             # stream chunk c+1 into the
        def _():                               # alternate ring slot
            start_chunk(nxt, c + 1)

        for j in range(chunk):                 # drain chunk c
            i = c * chunk + j
            valid = lane_valid(i)

            @pl.when(valid)                    # same predicate as the start
            def _():
                dma(slot, j, i).wait()

            @pl.when(i < n_sel)
            def _():
                blk = scratch[slot, j]
                if values_only:
                    zero = jnp.zeros_like(blk)
                    k_ref[0, 0, i] = zero
                    v_ref[0, 0, i] = jnp.where(valid, blk, zero)
                else:
                    zero = jnp.zeros_like(blk[0])
                    k_ref[0, 0, i] = jnp.where(valid, blk[0], zero)
                    v_ref[0, 0, i] = jnp.where(valid, blk[1], zero)
        return 0

    jax.lax.fori_loop(0, n_chunks, body, 0)


def _quant_kernel(idx_ref, pool_ref, scale_ref, k_ref, v_ref,
                  scratch, sscratch, sems, ssems, *,
                  n_sel, n_pages, chunk, n_chunks, values_only, bits,
                  out_dtype):
    """Quantized-pool variant: DMA the packed int page AND its fp32 scales
    through the same 2-deep VMEM ring, dequantize on drain (fused — the fp
    page never exists in host or HBM, only in VMEM on its way to the output
    buffer). Dequant math matches ``repro.quant.quantizers.dequant_block``
    exactly: int -> f32 * scale -> out_dtype."""
    from repro.quant import quantizers as qz

    b, h = pl.program_id(0), pl.program_id(1)

    def lane_valid(i):
        return (i < n_sel) & (idx_ref[b, h, jnp.minimum(i, n_sel - 1)] >= 0)

    def page_of(i):
        return jnp.clip(idx_ref[b, h, jnp.minimum(i, n_sel - 1)],
                        0, n_pages - 1)

    def dmas(slot, j, i):
        src = pool_ref.at[b, page_of(i), h]
        ssrc = scale_ref.at[b, page_of(i), h]
        if values_only:
            src = src.at[1]                    # V half of the packed block
            ssrc = ssrc.at[1]
        return (pltpu.make_async_copy(src, scratch.at[slot, j],
                                      sems.at[slot, j]),
                pltpu.make_async_copy(ssrc, sscratch.at[slot, j],
                                      ssems.at[slot, j]))

    def start_chunk(slot, c):
        for j in range(chunk):                 # page + scale DMA per lane
            i = c * chunk + j

            @pl.when(lane_valid(i))
            def _():
                for cp in dmas(slot, j, i):
                    cp.start()

    start_chunk(0, 0)

    def body(c, _):
        slot = jax.lax.rem(c, 2)
        nxt = jax.lax.rem(c + 1, 2)

        @pl.when(c + 1 < n_chunks)
        def _():
            start_chunk(nxt, c + 1)

        for j in range(chunk):
            i = c * chunk + j
            valid = lane_valid(i)

            @pl.when(valid)
            def _():
                for cp in dmas(slot, j, i):
                    cp.wait()

            @pl.when(i < n_sel)
            def _():
                deq = qz.dequant_block(scratch[slot, j], sscratch[slot, j],
                                       bits, out_dtype)
                zero = jnp.zeros_like(deq[..., 0, :, :] if not values_only
                                      else deq)
                if values_only:
                    k_ref[0, 0, i] = zero
                    v_ref[0, 0, i] = jnp.where(valid, deq, zero)
                else:
                    k_ref[0, 0, i] = jnp.where(valid, deq[0], zero)
                    v_ref[0, 0, i] = jnp.where(valid, deq[1], zero)
        return 0

    jax.lax.fori_loop(0, n_chunks, body, 0)


def recall_gather_quant(pool, scales, idx, *, bits, values_only=False,
                        out_dtype=jnp.float32, chunk=None, interpret=True):
    """Fused dequant-on-recall gather from the packed host pool.

    pool (B, n_pages, kv, 2, p, d_packed) int8 (packed int4 when bits=4);
    scales (B, n_pages, kv, 2, n_groups) float32; idx (B, kv, n_sel) int32
    (-1 pad) -> (k, v) each (B, kv, n_sel, p, d) in ``out_dtype``. Matches
    ``repro.quant.quantizers.dequant_recall_pages`` bit-for-bit."""
    B, n_pages, kv, _, p, dp = pool.shape
    d = dp * (8 // bits)
    n_g = scales.shape[-1]
    n_sel = idx.shape[2]
    chunk = max(1, min(chunk or 8, n_sel))
    n_chunks = -(-n_sel // chunk)

    ring = ((2, chunk, p, dp) if values_only else (2, chunk, 2, p, dp))
    sring = ((2, chunk, n_g) if values_only else (2, chunk, 2, n_g))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, kv),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=[
            pl.BlockSpec((1, 1, n_sel, p, d), lambda b, h, idx_ref: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, n_sel, p, d), lambda b, h, idx_ref: (b, h, 0, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM(ring, pool.dtype),
                        pltpu.VMEM(sring, scales.dtype),
                        pltpu.SemaphoreType.DMA((2, chunk)),
                        pltpu.SemaphoreType.DMA((2, chunk))],
    )
    out_shape = [jax.ShapeDtypeStruct((B, kv, n_sel, p, d), out_dtype),
                 jax.ShapeDtypeStruct((B, kv, n_sel, p, d), out_dtype)]
    kernel = functools.partial(
        _quant_kernel, n_sel=n_sel, n_pages=n_pages, chunk=chunk,
        n_chunks=n_chunks, values_only=values_only, bits=bits,
        out_dtype=out_dtype)
    k, v = pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shape, interpret=interpret,
    )(idx, pool, scales)
    return k, v


def recall_gather(pool, idx, *, values_only=False, chunk=None, interpret=True):
    """pool (B, n_pages, kv, 2, p, d) HND; idx (B, kv, n_sel) int32 (-1 pad)
    -> (k, v) each (B, kv, n_sel, p, d)."""
    B, n_pages, kv, _, p, d = pool.shape
    n_sel = idx.shape[2]
    chunk = max(1, min(chunk or 8, n_sel))
    n_chunks = -(-n_sel // chunk)

    ring = ((2, chunk, p, d) if values_only else (2, chunk, 2, p, d))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, kv),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=[
            pl.BlockSpec((1, 1, n_sel, p, d), lambda b, h, idx_ref: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, n_sel, p, d), lambda b, h, idx_ref: (b, h, 0, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM(ring, pool.dtype),
                        pltpu.SemaphoreType.DMA((2, chunk))],
    )
    out_shape = [jax.ShapeDtypeStruct((B, kv, n_sel, p, d), pool.dtype),
                 jax.ShapeDtypeStruct((B, kv, n_sel, p, d), pool.dtype)]
    kernel = functools.partial(
        _kernel, n_sel=n_sel, n_pages=n_pages, chunk=chunk,
        n_chunks=n_chunks, values_only=values_only)
    k, v = pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shape, interpret=interpret,
    )(idx, pool)
    return k, v
