"""Synthetic data pipelines.

1. ``lm_batches`` — a structured synthetic LM stream (Zipf unigrams + copy /
   periodic motifs) so small models have learnable signal within a few hundred
   steps. Deterministic given seed; sharding-friendly (pure numpy host-side).
2. ``needle_stream`` — long contexts with a "needle" motif planted at a known
   page; used by the retrieval-accuracy benchmarks: a good KV-retrieval method
   must select the needle's page when the query motif re-appears.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    motif_len: int = 8
    n_motifs: int = 64
    zipf_a: float = 1.3


class SyntheticLM:
    """Mixture of Zipf tokens and repeated motifs (copy structure)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        self.motifs = rng.integers(0, v, size=(cfg.n_motifs, cfg.motif_len))

    def _zipf(self, rng, n):
        v = self.cfg.vocab_size
        z = rng.zipf(self.cfg.zipf_a, size=n)
        return (z - 1) % v

    def sample_row(self, rng) -> np.ndarray:
        cfg = self.cfg
        out = []
        while sum(map(len, out)) < cfg.seq_len:
            if rng.random() < 0.5:
                out.append(self.motifs[rng.integers(cfg.n_motifs)])
            else:
                out.append(self._zipf(rng, cfg.motif_len))
        return np.concatenate(out)[: cfg.seq_len]

    def batches(self) -> Iterator[np.ndarray]:
        rng = np.random.default_rng(self.cfg.seed + 1)
        while True:
            yield np.stack([self.sample_row(rng)
                            for _ in range(self.cfg.batch_size)]).astype(np.int32)


def lm_batches(vocab_size, seq_len, batch_size, seed=0) -> Iterator[np.ndarray]:
    return SyntheticLM(DataConfig(vocab_size, seq_len, batch_size, seed)).batches()


# ---------------------------------------------------------------------------
# needle-retrieval stream (accuracy-proxy benchmark)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class NeedleSample:
    tokens: np.ndarray      # (T,) context ending with the needle's query motif
    needle_page: int        # page index (page_size supplied) holding the needle
    answer: int             # token immediately following the needle motif


def needle_stream(vocab_size, seq_len, page_size, seed=0,
                  motif_len=8) -> Iterator[NeedleSample]:
    rng = np.random.default_rng(seed)
    while True:
        toks = (rng.zipf(1.3, size=seq_len) - 1) % vocab_size
        motif = rng.integers(0, vocab_size, size=motif_len)
        answer = int(rng.integers(0, vocab_size))
        # plant needle away from sink/window edges
        lo, hi = 2 * page_size, seq_len - 4 * page_size - motif_len
        pos = int(rng.integers(lo, hi))
        toks[pos: pos + motif_len] = motif
        toks[pos + motif_len] = answer
        # query: repeat the motif at the very end (model must look the needle up)
        toks[seq_len - motif_len:] = motif
        yield NeedleSample(tokens=toks.astype(np.int32),
                           needle_page=pos // page_size, answer=answer)
