"""Sliding-window time-series aggregators: rotation, percentiles, schema."""
import json

import numpy as np
import pytest

from repro.obs import (Observability, TimeSeriesBoard,
                       validate_timeseries_snapshot)
from repro.obs.timeseries import (TIMESERIES_SCHEMA_VERSION, WindowRate,
                                  WindowStat, _percentile_sorted)


# ---------------------------------------------------------------------------
# WindowStat: eviction + exact rolling percentiles vs numpy
# ---------------------------------------------------------------------------
def test_window_stat_rotation_evicts_old_samples():
    ws = WindowStat("x", window_s=10.0)
    for t in range(20):                       # one sample per "second"
        ws.observe(float(t), t=float(t))
    vals = ws.values(now=19.0)
    # cutoff = 19 - 10 = 9: samples at t in [9, 19] survive
    assert vals == [float(t) for t in range(9, 20)]
    assert ws.summary(now=19.0)["count"] == 11
    # advancing the clock with no new samples keeps evicting
    assert ws.summary(now=40.0)["count"] == 0
    assert ws.summary(now=40.0)["p99"] == 0.0


@pytest.mark.parametrize("seed", range(4))
def test_window_stat_percentiles_match_numpy_on_sliding_slices(seed):
    """Rolling p50/p90/p99 equal np.percentile over the same time slice,
    checked at several 'now' points as the window slides over the data."""
    rng = np.random.default_rng(seed)
    W = 5.0
    ts = np.sort(rng.uniform(0.0, 30.0, 400))
    vs = rng.lognormal(mean=-3.0, sigma=1.0, size=400)
    ws = WindowStat("lat", window_s=W)
    # feed in time order (the scheduler's clock is monotone) and evaluate
    # at checkpoints as the window slides over the stream
    idx = 0
    for now in (6.0, 12.5, 20.0, 30.0):
        while idx < len(ts) and ts[idx] <= now:
            ws.observe(vs[idx], t=ts[idx])
            idx += 1
        in_win = vs[(ts >= now - W) & (ts <= now)]
        got = ws.summary(now=now)
        assert got["count"] == len(in_win)
        for q, key in ((50, "p50"), (90, "p90"), (99, "p99")):
            np.testing.assert_allclose(
                got[key], np.percentile(in_win, q, method="linear"),
                rtol=1e-12)
        np.testing.assert_allclose(got["mean"], in_win.mean(), rtol=1e-12)
        np.testing.assert_allclose(got["min"], in_win.min())
        np.testing.assert_allclose(got["max"], in_win.max())


def test_percentile_sorted_edge_cases():
    assert _percentile_sorted([], 0.5) == 0.0
    assert _percentile_sorted([3.0], 0.99) == 3.0
    assert _percentile_sorted([1.0, 2.0], 0.5) == 1.5
    vals = sorted([5.0, 1.0, 9.0, 3.0])
    assert _percentile_sorted(vals, 0.0) == 1.0
    assert _percentile_sorted(vals, 1.0) == 9.0


def test_window_stat_ring_bound_caps_memory():
    ws = WindowStat("x", window_s=1e9, max_samples=16)
    for t in range(100):
        ws.observe(float(t), t=float(t))
    assert ws.summary(now=100.0)["count"] == 16   # ring bound, not window
    assert ws.values(now=100.0) == [float(t) for t in range(84, 100)]


# ---------------------------------------------------------------------------
# WindowRate: rolling rate + cumulative totals
# ---------------------------------------------------------------------------
def test_window_rate_rolls_and_totals_accumulate():
    wr = WindowRate("tokens", window_s=10.0)
    for t in range(30):
        wr.event(weight=2.0, t=float(t))
    s = wr.summary(now=29.0)
    assert s["events"] == 11 and s["weight"] == 22.0
    assert s["events_per_s"] == pytest.approx(1.1)
    assert s["weight_per_s"] == pytest.approx(2.2)
    assert s["total_events"] == 30 and s["total_weight"] == 60.0
    # fully rotated out: window empties, totals persist
    s2 = wr.summary(now=100.0)
    assert s2["events"] == 0 and s2["total_events"] == 30


# ---------------------------------------------------------------------------
# TimeSeriesBoard: snapshot schema + validator
# ---------------------------------------------------------------------------
def _manual_clock():
    state = {"t": 0.0}

    def clock():
        return state["t"]

    return state, clock


def test_board_snapshot_schema_valid_and_json_stable():
    state, clock = _manual_clock()
    board = TimeSeriesBoard(window_s=5.0, clock=clock)
    for i in range(50):
        state["t"] = i * 0.1
        board.observe("ttft_s", 0.01 * (i % 7))
        board.observe("itl_s", 0.002 * (i % 3 + 1))
        board.event("tokens", 1.0)
        if i % 10 == 0:
            board.event("completions", 1.0)
    snap = board.snapshot()
    assert validate_timeseries_snapshot(snap) == []
    assert snap["schema_version"] == TIMESERIES_SCHEMA_VERSION
    assert set(snap["stats"]) == {"ttft_s", "itl_s"}
    assert set(snap["rates"]) == {"tokens", "completions"}
    assert snap["rates"]["tokens"]["total_events"] == 50
    # round-trips through JSON (the /stats payload)
    assert validate_timeseries_snapshot(
        json.loads(board.snapshot_line(extra={"k": 1}))) == []


def test_board_snapshot_window_rotation_live():
    state, clock = _manual_clock()
    board = TimeSeriesBoard(window_s=2.0, clock=clock)
    for i in range(10):
        state["t"] = float(i)
        board.observe("itl_s", float(i))
    state["t"] = 9.0
    s = board.snapshot()["stats"]["itl_s"]
    assert s["count"] == 3 and s["min"] == 7.0 and s["max"] == 9.0


def test_validator_flags_malformed_snapshots():
    assert validate_timeseries_snapshot("nope")
    assert any("schema_version" in e
               for e in validate_timeseries_snapshot({}))
    state, clock = _manual_clock()
    board = TimeSeriesBoard(clock=clock)
    board.observe("x", 1.0)
    snap = board.snapshot()
    snap["stats"]["x"]["p50"] = 99.0          # breaks p50 <= p90
    assert any("monotone" in e for e in validate_timeseries_snapshot(snap))
    snap2 = board.snapshot()
    snap2["stats"]["x"]["mean"] = float("nan")
    assert any("non-finite" in e for e in validate_timeseries_snapshot(snap2))
    board.event("r", 1.0)
    snap3 = board.snapshot()
    snap3["rates"]["r"]["total_events"] = 0
    snap3["rates"]["r"]["events"] = 5
    assert any("exceed" in e for e in validate_timeseries_snapshot(snap3))


def test_observability_full_attaches_board():
    obs = Observability.full()
    assert obs.timeseries is not None
    assert Observability.off().timeseries is None
    obs.timeseries.observe("ttft_s", 0.1)
    assert validate_timeseries_snapshot(obs.timeseries.snapshot()) == []


def test_board_thread_safety_under_concurrent_feed_and_snapshot():
    import threading
    board = TimeSeriesBoard(window_s=60.0)
    stop = threading.Event()
    errs = []

    def feeder():
        i = 0
        while not stop.is_set():
            board.observe("itl_s", 0.001 * (i % 5))
            board.event("tokens", 1.0)
            i += 1

    def snapper():
        try:
            while not stop.is_set():
                errors = validate_timeseries_snapshot(board.snapshot())
                assert errors == [], errors
        except Exception as e:                   # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=feeder) for _ in range(2)] + \
        [threading.Thread(target=snapper) for _ in range(2)]
    for t in threads:
        t.start()
    import time as _time
    _time.sleep(0.3)
    stop.set()
    for t in threads:
        t.join()
    assert errs == []
