"""Async streaming front-end: EngineService + HTTP server over ServeEngine.

Fake-backend tests pin the service-mode scheduler semantics (dynamic
admission, per-token event streaming, cancellation releasing slots with
surviving requests bit-identical); real-engine tests drive the stdlib
asyncio HTTP server end-to-end (chunked NDJSON streaming, concurrent
clients, live /metrics + /stats + /healthz, disconnect-cancels-request).
"""
import json
import os
import socket
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.obs import Observability, validate_timeseries_snapshot
from repro.serving.frontend import (EngineService, http_generate,
                                    http_get_json, http_get_text,
                                    serve_http_background)
from repro.serving.sampling import SamplerConfig
from repro.serving.scheduler import CANCELLED, ContinuousScheduler

from test_preemption import FakeBackend, FakePool, FakeReq


# ---------------------------------------------------------------------------
# fake engine facade: ContinuousScheduler service mode without a model
# ---------------------------------------------------------------------------
class FakeEngine:
    def __init__(self, num_slots=2, **kw):
        self.backend = FakeBackend(**kw)
        self.pool = FakePool(num_slots)
        self.last_metrics = None

    def serve_service(self, service, seed=0):
        done, em = ContinuousScheduler(self.backend, self.pool).run(
            [], seed=seed, service=service)
        self.last_metrics = em
        return done


class Collector:
    """Per-request event sink; callbacks arrive on the scheduler thread."""

    def __init__(self, cancel_after=None, service=None, uid=None):
        self.events = []
        self.finish = None
        self.done = threading.Event()
        self._cancel_after = cancel_after
        self._service = service
        self._uid = uid

    def __call__(self, kind, payload):
        self.events.append((kind, payload))
        if kind == "finish":
            self.finish = payload
            self.done.set()
        elif kind == "error":
            self.finish = payload
            self.done.set()
        elif self._cancel_after is not None and kind == "token" \
                and payload["index"] + 1 == self._cancel_after:
            self._service.cancel(self._uid)

    @property
    def tokens(self):
        return [p["token"] for k, p in self.events if k == "token"]

    @property
    def indexes(self):
        return [p["index"] for k, p in self.events if k == "token"]


def _direct_tokens(reqs, num_slots, seed=0):
    done, _ = ContinuousScheduler(FakeBackend(), FakePool(num_slots)).run(
        reqs, seed=seed)
    return {tr.req.uid: tr.tokens for tr in done}


def _reqs(spec):
    rng = np.random.default_rng(0)
    return [FakeReq(uid=u, tokens=rng.integers(0, 5000, 8).astype(np.int32),
                    max_new_tokens=n) for u, n in spec]


# ---------------------------------------------------------------------------
# EngineService semantics (fake backend)
# ---------------------------------------------------------------------------
def test_service_streams_bit_identical_to_direct_run():
    """Tokens streamed through the service equal a direct scheduler run of
    the same traffic (same uids + seed -> same per-request PRNG streams),
    with in-order indexes 0..n-1 per request."""
    spec = [(0, 5), (1, 9), (2, 3), (3, 7)]
    eng = FakeEngine(num_slots=2)
    svc = EngineService(eng, seed=11).start()
    cols = {}
    for uid, n in spec:
        cols[uid] = Collector()
        svc.submit(np.arange(8, dtype=np.int32) + uid, n, cols[uid], uid=uid)
    completions = svc.stop()
    direct = _direct_tokens(_reqs(spec), num_slots=2, seed=11)
    for uid, n in spec:
        assert cols[uid].done.is_set()
        assert cols[uid].indexes == list(range(n))
        assert cols[uid].tokens == direct[uid]
        assert cols[uid].finish["tokens"] == direct[uid]
        assert cols[uid].finish["cancelled"] is False
        assert cols[uid].finish["ttft_s"] is not None
    assert sorted(tr.req.uid for tr in completions) == [0, 1, 2, 3]
    assert eng.last_metrics.cancellations == 0


def test_service_dynamic_admission_mid_run():
    """Requests submitted while the scheduler is already decoding are
    admitted and complete (the live-serving loop condition)."""
    eng = FakeEngine(num_slots=1)
    svc = EngineService(eng, seed=3).start()
    first = Collector()
    svc.submit(np.arange(8, dtype=np.int32), 200, first, uid=0)
    while len(first.tokens) < 3:        # scheduler demonstrably running
        time.sleep(0.001)
    late = Collector()
    svc.submit(np.arange(8, dtype=np.int32), 4, late, uid=1)
    svc.stop()
    assert first.done.is_set() and len(first.tokens) == 200
    assert late.done.is_set() and len(late.tokens) == 4
    assert eng.pool.free_count == eng.pool.num_slots


def test_service_cancellation_frees_slot_and_preserves_survivors():
    """Cancelling one request mid-decode releases its slot (survivors'
    streams are bit-identical to an uncancelled run), records CANCELLED,
    and excludes the partial from completed/SLO accounting."""
    eng = FakeEngine(num_slots=2)
    svc = EngineService(eng, seed=7).start()
    victim = Collector(cancel_after=3, service=svc, uid=1)
    others = {0: Collector(), 2: Collector()}
    svc.submit(np.arange(8, dtype=np.int32), 40, others[0], uid=0)
    svc.submit(np.arange(8, dtype=np.int32) + 1, 400, victim, uid=1)
    svc.submit(np.arange(8, dtype=np.int32) + 2, 6, others[2], uid=2)
    svc.stop()
    em = eng.last_metrics

    assert victim.done.is_set()
    assert victim.finish["cancelled"] is True
    assert victim.finish["state"] == CANCELLED
    assert 3 <= len(victim.tokens) < 400       # cut off mid-stream
    # the freed slot admitted uid 2, and every slot returned to the pool
    assert others[2].done.is_set() and len(others[2].tokens) == 6
    assert eng.pool.free_count == eng.pool.num_slots
    assert all(o is None for o in eng.pool.owner)
    # survivors bit-identical to the same traffic without the cancel
    direct = _direct_tokens(_reqs([(0, 40), (1, 400), (2, 6)]),
                            num_slots=2, seed=7)
    assert others[0].tokens == direct[0]
    assert others[2].tokens == direct[2]
    assert victim.tokens == direct[1][:len(victim.tokens)]
    # accounting: CANCELLED is terminal, outside completed/latency/SLO
    assert em.cancellations == 1
    s = em.summary()
    assert s["completed"] == 2 and s["cancelled"] == 1
    assert s["latency"]["ttft_s"]["count"] == 2


def test_service_cancel_queued_request_never_starts():
    eng = FakeEngine(num_slots=1)
    svc = EngineService(eng, seed=5).start()
    running = Collector()
    queued = Collector()
    svc.submit(np.arange(8, dtype=np.int32), 300, running, uid=0)
    while len(running.tokens) < 2:
        time.sleep(0.001)
    svc.submit(np.arange(8, dtype=np.int32), 5, queued, uid=1)
    svc.cancel(1)
    svc.stop()
    assert queued.done.is_set()
    assert queued.finish["cancelled"] is True and queued.tokens == []
    assert running.done.is_set() and len(running.tokens) == 300
    assert eng.last_metrics.cancellations == 1


def test_service_submit_validation():
    eng = FakeEngine(num_slots=1)
    svc = EngineService(eng).start()
    c = Collector()
    svc.submit([1, 2, 3], 2, c, uid=9)
    with pytest.raises(ValueError, match="duplicate uid"):
        svc.submit([1, 2, 3], 2, Collector(), uid=9)
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit([1, 2, 3], 2, Collector())
    svc.stop()
    assert c.done.is_set()


# ---------------------------------------------------------------------------
# HTTP front-end over the real engine
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def http_setup():
    cfg = get_config("smollm-360m-smoke")
    from repro.models.model import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))
    fkv = FreeKVConfig(method="freekv", page_size=8, budget=64, n_sink=8,
                       n_window=8, tau=0.8)
    from repro.serving.engine import ServeEngine
    eng = ServeEngine(cfg, fkv, params, max_len=256, batch_size=2,
                      sampler=SamplerConfig(temperature=0.7),
                      obs=Observability.full(),
                      slo_ttft_ms=120_000.0, slo_itl_ms=120_000.0)
    return cfg, eng


def _http_prompt(cfg, n=48, seed=1):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, n).astype(np.int32).tolist()


def test_http_stream_bit_identical_and_concurrent(http_setup):
    """Concurrent streaming clients each get an ordered start->token*->done
    NDJSON stream whose tokens equal a direct engine.generate run of the
    same (uid, prompt, seed) — the frontend adds no nondeterminism — and
    /healthz + /metrics + /stats answer while requests are in flight."""
    cfg, eng = http_setup
    svc = EngineService(eng, seed=0).start()
    fe, stop, th = serve_http_background(svc)
    results, errors = {}, []

    def client(uid):
        try:
            evs = list(http_generate("127.0.0.1", fe.port, {
                "uid": uid, "tokens": _http_prompt(cfg, 48 + 8 * uid,
                                                   seed=uid),
                "max_new_tokens": 8, "slo_ttft_ms": 120000}))
            results[uid] = evs
        except Exception as e:                   # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=client, args=(u,)) for u in (0, 1, 2)]
    for t in threads:
        t.start()
    deadline = time.time() + 120
    while svc.em is None and time.time() < deadline:
        time.sleep(0.01)                # scheduler attaches its registry
    # live endpoints while the engine decodes
    st, hz = http_get_json("127.0.0.1", fe.port, "/healthz")
    assert st == 200 and hz["ok"] is True
    st, prom = http_get_text("127.0.0.1", fe.port, "/metrics")
    assert st == 200 and "# TYPE" in prom
    st, stats = http_get_json("127.0.0.1", fe.port, "/stats")
    assert st == 200 and validate_timeseries_snapshot(stats) == []
    for t in threads:
        t.join()
    assert errors == []
    stop.set()
    th.join()
    svc.stop()

    em = eng.last_metrics
    assert em.registry.counter("requests_completed_total").value == 3
    # SLO section: all three tagged generously -> full attainment
    slo = em.summary()["slo"]
    assert slo["tagged"] == 3 and slo["attainment"] == 1.0
    assert slo["goodput_tokens_per_s"] > 0

    # event-stream shape + per-token timestamps
    for uid, evs in results.items():
        kinds = [e["event"] for e in evs]
        assert kinds[0] == "start" and kinds[-1] == "done"
        toks = [e for e in evs if e["event"] == "token"]
        assert [e["index"] for e in toks] == list(range(8))
        assert all("t" in e and "t_server" in e for e in toks)
        assert evs[-1]["tokens"] == [e["token"] for e in toks]

    # bit-identity: direct run, same uids/prompts/seed, no frontend
    from repro.serving.engine import Request
    reqs = [Request(uid=u, tokens=np.asarray(_http_prompt(cfg, 48 + 8 * u,
                                                          seed=u), np.int32),
                    max_new_tokens=8) for u in (0, 1, 2)]
    direct = {c.uid: c.tokens for c in eng.generate(reqs, seed=0)}
    for uid, evs in results.items():
        assert evs[-1]["tokens"] == direct[uid], \
            f"uid {uid}: frontend stream != direct engine run"


def test_http_disconnect_cancels_request(http_setup):
    """A client that drops its socket mid-stream cancels the request: the
    scheduler records CANCELLED, frees the slot, and a concurrent survivor
    completes with tokens identical to an undisturbed run."""
    cfg, eng = http_setup
    svc = EngineService(eng, seed=0).start()
    fe, stop, th = serve_http_background(svc)

    prompt = _http_prompt(cfg, 64, seed=9)
    body = json.dumps({"uid": 100, "tokens": prompt,
                       "max_new_tokens": 160, "stream": True}).encode()
    s = socket.create_connection(("127.0.0.1", fe.port), timeout=60)
    s.sendall(b"POST /generate HTTP/1.1\r\nHost: t\r\n"
              b"Content-Type: application/json\r\n"
              b"Content-Length: " + str(len(body)).encode() + b"\r\n\r\n"
              + body)
    buf = b""
    while buf.count(b'"event": "token"') < 2:     # a few tokens flowed
        chunk = s.recv(4096)
        assert chunk, "server closed stream early"
        buf += chunk
    s.close()                                     # client walks away

    # survivor admitted while the cancel propagates
    evs = list(http_generate("127.0.0.1", fe.port, {
        "uid": 101, "tokens": _http_prompt(cfg, 48, seed=2),
        "max_new_tokens": 6}))
    assert evs[-1]["event"] == "done"

    deadline = time.time() + 30
    while svc.em.cancellations < 1 and time.time() < deadline:
        time.sleep(0.01)
    stop.set()
    th.join()
    completions = svc.stop()
    em = eng.last_metrics
    assert em.cancellations == 1
    by_uid = {c.uid: c for c in completions}
    assert by_uid[100].metrics.cancelled is True
    assert 2 <= len(by_uid[100].tokens) < 160
    assert by_uid[101].metrics.cancelled is False

    # survivor bit-identical to an undisturbed run
    from repro.serving.engine import Request
    direct = eng.generate([Request(
        uid=101, tokens=np.asarray(_http_prompt(cfg, 48, seed=2), np.int32),
        max_new_tokens=6)], seed=0)
    assert evs[-1]["tokens"] == direct[0].tokens


def test_check_obs_validates_stats_file_and_live_url(http_setup, tmp_path):
    """tools/check_obs.py --stats / --url: the /stats snapshot file and a
    live front-end both validate; a corrupted snapshot is rejected."""
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "check_obs", os.path.join(os.path.dirname(__file__), "..",
                                  "tools", "check_obs.py"))
    check_obs = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(check_obs)

    cfg, eng = http_setup
    svc = EngineService(eng, seed=0).start()
    fe, stop, th = serve_http_background(svc)
    try:
        evs = list(http_generate("127.0.0.1", fe.port, {
            "tokens": _http_prompt(cfg, 48, seed=4), "max_new_tokens": 4}))
        assert evs[-1]["event"] == "done"
        assert check_obs.check_url(f"http://127.0.0.1:{fe.port}") == []
        _, stats = http_get_json("127.0.0.1", fe.port, "/stats")
    finally:
        stop.set()
        th.join()
        svc.stop()
    good = tmp_path / "stats.json"
    good.write_text(json.dumps(stats))
    assert check_obs.check_stats(str(good)) == []
    stats["stats"]["ttft_s"]["p50"] = float("inf")   # json parses Infinity
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(stats))
    assert check_obs.check_stats(str(bad))
    assert check_obs.check_url(f"http://127.0.0.1:{fe.port}")  # server gone


def test_http_bad_requests(http_setup):
    cfg, eng = http_setup
    svc = EngineService(eng, seed=0).start()
    fe, stop, th = serve_http_background(svc)
    import http.client
    conn = http.client.HTTPConnection("127.0.0.1", fe.port, timeout=30)
    conn.request("POST", "/generate", body=json.dumps({"tokens": []}),
                 headers={"Content-Type": "application/json"})
    assert conn.getresponse().status == 400
    conn.close()
    conn = http.client.HTTPConnection("127.0.0.1", fe.port, timeout=30)
    conn.request("POST", "/generate", body=json.dumps(
        {"tokens": [1] * 64, "max_new_tokens": 10_000}))
    assert conn.getresponse().status == 400       # exceeds engine max_len
    conn.close()
    st, _ = http_get_json("127.0.0.1", fe.port, "/nope")
    assert st == 404
    stop.set()
    th.join()
    svc.stop()
