"""Per-architecture smoke tests: REDUCED variant of each assigned arch family
(<=3 layers, d_model<=256, <=4 experts) running one forward/train step and a
prefill+decode step on CPU, asserting shapes + finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, ASSIGNED, PAPER_MODELS
from repro.configs.base import FreeKVConfig
from repro.models.model import forward_train, init_params, prefill, serve_step

KEY = jax.random.PRNGKey(0)
FKV = FreeKVConfig(method="freekv", page_size=8, budget=64, n_sink=8,
                   n_window=8, tau=0.8)


def _batch(cfg, B=2, T=64):
    b = {"tokens": jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)}
    if cfg.frontend:
        b["frontend"] = 0.1 * jax.random.normal(
            KEY, (B, cfg.n_frontend_tokens, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", list(ASSIGNED) + list(PAPER_MODELS))
def test_smoke_train_and_decode(arch):
    cfg = get_config(arch + "-smoke")
    assert cfg.d_model <= 512 and cfg.n_layers <= 3
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    loss, metrics = jax.jit(lambda p, b: forward_train(cfg, p, b))(params,
                                                                   batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), arch
    assert jnp.isfinite(metrics["ce"])

    logits, st = jax.jit(
        lambda p, b: prefill(cfg, FKV, p, b, max_len=96,
                             state_dtype=jnp.float32))(params, batch)
    assert logits.shape == (2, cfg.padded_vocab())
    assert jnp.isfinite(logits).all(), arch
    tok = jnp.argmax(logits, -1)[:, None]
    logits2, st = jax.jit(
        lambda p, s, t: serve_step(cfg, FKV, p, s, t))(params, st, tok)
    assert logits2.shape == (2, cfg.padded_vocab())
    assert jnp.isfinite(logits2).all(), arch
    n_front = cfg.n_frontend_tokens if (cfg.frontend and
                                        not cfg.is_encoder_decoder) else 0
    assert int(st["pos"][0]) == 64 + n_front + 1


@pytest.mark.parametrize("arch", ["granite-3-8b", "deepseek-moe-16b",
                                  "jamba-1.5-large-398b"])
def test_smoke_grad_finite(arch):
    cfg = get_config(arch + "-smoke")
    params = init_params(cfg, KEY)
    batch = _batch(cfg, T=32)

    def loss_fn(p):
        return forward_train(cfg, p, batch)[0]

    g = jax.jit(jax.grad(loss_fn))(params)
    for leaf in jax.tree.leaves(g):
        assert jnp.isfinite(leaf).all(), arch


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned dimensions."""
    expect = {
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
    }
    for arch, (L, d, h, kvh, dff, v) in expect.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (L, d, h, kvh, dff, v), arch
    # MoE extras
    dm = get_config("deepseek-moe-16b")
    assert (dm.n_experts, dm.moe_top_k, dm.n_shared_experts) == (64, 6, 2)
    l4 = get_config("llama4-scout-17b-a16e")
    assert (l4.n_experts, l4.moe_top_k) == (16, 1)
    jb = get_config("jamba-1.5-large-398b")
    assert (jb.n_experts, jb.moe_top_k) == (16, 2)
    # jamba 1:7 attention interleave
    mixers = [m for m, _ in jb.pattern]
    assert mixers.count("attn") == 1 and len(mixers) == 8
