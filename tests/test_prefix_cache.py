"""Radix-trie prefix cache: insert/match/evict properties + engine integration."""
import numpy as np

from repro.serving.prefix_cache import RadixPrefixCache


def _payload(tokens, n_arrays=2, width=3):
    """Deterministic per-token payload so slices are checkable: array i holds
    value (token * 10 + i) replicated across the feature axis."""
    t = np.asarray(tokens, np.int32)
    return [np.repeat((t * 10 + i)[:, None], width, axis=1).astype(np.float32)
            for i in range(n_arrays)]


def _check(payload, tokens):
    ref = _payload(tokens)
    assert len(payload) == len(ref)
    for a, b in zip(payload, ref):
        np.testing.assert_array_equal(a, b)


def test_insert_then_exact_match():
    c = RadixPrefixCache(1 << 20)
    seq = (5, 6, 7, 8)
    c.insert(seq, _payload(seq))
    n, payload = c.match(seq)
    assert n == 4
    _check(payload, seq)
    assert c.total_tokens == 4


def test_partial_segment_match():
    """A match may stop mid-segment (partial-page prefix match): the node is
    sliced, not split, and the payload covers exactly the matched span."""
    c = RadixPrefixCache(1 << 20)
    seq = (1, 2, 3, 4, 5, 6, 7, 8)
    c.insert(seq, _payload(seq))
    n, payload = c.match((1, 2, 3, 99))
    assert n == 3
    _check(payload, (1, 2, 3))
    # no structural change from matching
    assert c.total_tokens == 8


def test_shared_prefix_dedup_and_split():
    c = RadixPrefixCache(1 << 20)
    a = (1, 2, 3, 4, 5, 6)
    b = (1, 2, 3, 9, 9, 9)
    c.insert(a, _payload(a))
    c.insert(b, _payload(b))
    # shared prefix (1,2,3) stored once: 6 + 3 new tokens, not 12
    assert c.total_tokens == 9
    for seq in (a, b):
        n, payload = c.match(seq)
        assert n == 6
        _check(payload, seq)


def test_match_across_split_nodes_concatenates_payload():
    c = RadixPrefixCache(1 << 20)
    a = (1, 2, 3, 4)
    b = (1, 2, 5, 6)
    c.insert(a, _payload(a))
    c.insert(b, _payload(b))           # splits (1,2,3,4) into (1,2)+(3,4)
    n, payload = c.match((1, 2, 3, 4, 7))
    assert n == 4
    _check(payload, a)


def test_zero_capacity_disables():
    c = RadixPrefixCache(0)
    assert c.insert((1, 2, 3), _payload((1, 2, 3))) == 0
    n, payload = c.match((1, 2, 3))
    assert n == 0 and payload is None


def test_lru_eviction_under_capacity():
    c = RadixPrefixCache(8)
    a = (1, 2, 3, 4)
    b = (5, 6, 7, 8)
    c.insert(a, _payload(a))
    c.insert(b, _payload(b))
    assert c.total_tokens == 8
    c.match(a)                          # a is now most recently used
    d = (9, 10, 11, 12)
    c.insert(d, _payload(d))            # over capacity -> evict LRU leaf (b)
    assert c.total_tokens == 8
    assert c.evictions == 1
    assert c.match(b)[0] == 0           # b evicted
    assert c.match(a)[0] == 4           # a retained
    assert c.match(d)[0] == 4


def test_eviction_prefers_leaves():
    """Evicting a leaf must not take a shared ancestor with it."""
    c = RadixPrefixCache(7)
    a = (1, 2, 3, 4, 5)
    b = (1, 2, 3, 8, 9)                 # shares (1,2,3) -> 5 + 2 = 7 tokens
    c.insert(a, _payload(a))
    c.insert(b, _payload(b))
    assert c.total_tokens == 7
    c.match(b)
    e = (7, 7)
    c.insert(e, _payload(e))            # evicts the LRU leaf (a's tail)
    assert c.total_tokens <= 7
    n, payload = c.match(b)             # b's full path still intact
    assert n == 5
    _check(payload, b)


def test_accounting_stats():
    c = RadixPrefixCache(1 << 20)
    seq = tuple(range(16))
    c.insert(seq, _payload(seq))
    c.match(seq)
    c.match((99,))
    s = c.stats()
    assert s["hits"] == 1 and s["misses"] == 1
    assert s["hit_tokens"] == 16
    assert s["cached_tokens"] == 16
    assert s["nbytes"] == sum(a.nbytes for a in _payload(seq))
    c.clear()
    assert c.total_tokens == 0
    assert c.hits == 0 and c.misses == 0 and c.hit_tokens == 0
    assert c.match(seq)[0] == 0
