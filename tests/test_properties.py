"""Hypothesis property tests for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.core import selection
from repro.core.retrieval import _window_floor
from repro.core.correction import query_similarity
from repro.training.optimizer import AdamWConfig, lr_at

CFG = get_config("granite-3-8b-smoke")
SETTINGS = settings(max_examples=40, deadline=None)


# ---------------------------------------------------------------------------
# the three-region partition (sink / selected pages / window) is exact
# ---------------------------------------------------------------------------
@given(length=st.integers(min_value=1, max_value=2000),
       p=st.sampled_from([4, 8, 16, 32]),
       sink_pages=st.integers(min_value=0, max_value=4),
       win_pages=st.integers(min_value=1, max_value=6))
@SETTINGS
def test_region_partition_exact(length, p, sink_pages, win_pages):
    fkv = FreeKVConfig(method="freekv", page_size=p, budget=10 ** 6,
                       n_sink=sink_pages * p, n_window=win_pages * p)
    L = jnp.array([length])
    wf = int(_window_floor(fkv, L)[0])
    n_pages = -(-length // p) + 2
    sel_mask = np.asarray(
        selection.selectable_mask(CFG, fkv, n_pages, L))[0]
    covered = np.zeros(length, dtype=int)
    covered[: min(fkv.n_sink, length)] += 1                  # sink region
    covered[min(wf, length): length] += 1                    # window region
    for pg in range(n_pages):                                # selected pages
        if sel_mask[pg]:
            lo, hi = pg * p, min((pg + 1) * p, length)
            # selection region masked to [n_sink, window_floor)
            lo2, hi2 = max(lo, fkv.n_sink), min(hi, wf)
            if hi2 > lo2:
                covered[lo2:hi2] += 1
    # window ring holds the last n_window + p tokens; everything in
    # [window_floor, length) must be within it
    assert wf >= length - fkv.n_window - p
    assert (covered == 1).all(), (length, p, sink_pages, win_pages, covered)


# ---------------------------------------------------------------------------
# Quest min-max score is an upper bound on any key inside the page box
# ---------------------------------------------------------------------------
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
@SETTINGS
def test_quest_score_upper_bound(seed):
    key = jax.random.PRNGKey(seed)
    d, p = 16, 8
    q = jax.random.normal(key, (1, 2, d))                # (B,H,d), kv=2,G=1
    ks = jax.random.normal(jax.random.fold_in(key, 1), (1, p, 2, d))
    summ = jnp.stack([ks.min(1), ks.max(1)], axis=2)[:, None]  # (1,1,kv,2,d)
    s = selection.page_scores_minmax(q, summ, scale=1.0)       # (1,H,1)
    true = jnp.einsum("bhd,bpkd->bhkp", q,
                      ks)                                       # h==kv here G=1
    for h in range(2):
        assert float(s[0, h, 0]) >= float(true[0, h, h].max()) - 1e-4


@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       n_sel=st.integers(min_value=1, max_value=8))
@SETTINGS
def test_selection_valid_distinct(seed, n_sel):
    key = jax.random.PRNGKey(seed)
    fkv = FreeKVConfig(method="freekv", page_size=8, budget=10 ** 4,
                       n_sink=8, n_window=8)
    B, H, d, n_pages = 1, CFG.n_heads, CFG.d_head, 12
    q = jax.random.normal(key, (B, H, d))
    summ = jax.random.normal(jax.random.fold_in(key, 1),
                             (B, n_pages, CFG.n_kv_heads, 2, d))
    length = jnp.array([12 * 8])
    idx, _ = selection.select_pages(CFG, fkv, q, summ, length, n_sel)
    idx = np.asarray(idx)
    valid = np.asarray(selection.selectable_mask(CFG, fkv, n_pages, length))[0]
    for b in range(B):
        for k in range(CFG.n_kv_heads):
            sel = idx[b, k][idx[b, k] >= 0]
            assert len(set(sel.tolist())) == len(sel)      # distinct
            assert all(valid[s] for s in sel)              # in-range


# ---------------------------------------------------------------------------
# correction similarity
# ---------------------------------------------------------------------------
@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       scale=st.floats(min_value=0.1, max_value=10))
@SETTINGS
def test_cosine_similarity_properties(seed, scale):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (2, 4, 16))
    s_same = query_similarity(q, q * scale)        # scale-invariant
    np.testing.assert_allclose(np.asarray(s_same), 1.0, atol=1e-5)
    s_neg = query_similarity(q, -q)
    np.testing.assert_allclose(np.asarray(s_neg), -1.0, atol=1e-5)
    qa = jax.random.normal(jax.random.fold_in(key, 1), q.shape)
    s = np.asarray(query_similarity(q, qa))
    assert (s >= -1 - 1e-5).all() and (s <= 1 + 1e-5).all()


# ---------------------------------------------------------------------------
# optimizer / schedule
# ---------------------------------------------------------------------------
@given(step=st.integers(min_value=0, max_value=20000))
@SETTINGS
def test_lr_schedule_bounds(step):
    cfg = AdamWConfig(lr=1e-3, warmup_steps=100, total_steps=10000,
                      min_lr_ratio=0.1)
    lr = float(lr_at(cfg, step))
    assert 0.0 <= lr <= cfg.lr + 1e-9
    if step >= cfg.warmup_steps:
        assert lr >= cfg.lr * cfg.min_lr_ratio - 1e-9


def test_adamw_minimizes_quadratic():
    from repro.training.optimizer import adamw_init, adamw_update
    cfg = AdamWConfig(lr=0.05, warmup_steps=0, total_steps=200,
                      weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(params, cfg)
    for _ in range(150):
        g = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(g, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------
@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       temp=st.floats(min_value=0.1, max_value=2.0),
       top_p=st.floats(min_value=0.1, max_value=1.0))
@SETTINGS
def test_sampling_in_vocab(seed, temp, top_p):
    from repro.serving.sampling import SamplerConfig, sample
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (3, 50))
    toks = sample(logits, SamplerConfig(temperature=temp, top_p=top_p), key)
    assert ((toks >= 0) & (toks < 50)).all()
    greedy = sample(logits, SamplerConfig(temperature=0.0), key)
    assert (greedy == jnp.argmax(logits, -1)).all()
