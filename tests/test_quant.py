"""Quantized host KV tier (src/repro/quant): quantizer round-trip bounds,
int4 pack/unpack exactness, fused dequant kernel parity vs the jnp reference,
``kv_quant="none"`` bit-identity through engine slot turnover, and the
accuracy / byte-accounting invariants of the quantized recall path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.core import paging
from repro.core.retrieval import make_retriever
from repro.quant import (accounting, dequant_block, dequant_recall_pages,
                         dequant_recall_values, pack_int4, quantize_block,
                         unpack_int4)

KEY = jax.random.PRNGKey(0)

FKV_BASE = dict(method="freekv", page_size=8, budget=48, n_sink=8, n_window=8,
                tau=0.8, svd_rank=32)


# ---------------------------------------------------------------------------
# property tests: pack/unpack exactness + round-trip error bounds
# (hypothesis-driven when installed — CI — seeded sweep otherwise)
# ---------------------------------------------------------------------------
def _check_pack_unpack(seed, d, lead):
    """pack_int4 ∘ unpack_int4 is the identity on the full int4 range."""
    rng = np.random.default_rng(seed)
    q = rng.integers(-8, 8, size=(lead, d), dtype=np.int8)
    out = np.asarray(unpack_int4(pack_int4(jnp.asarray(q))))
    np.testing.assert_array_equal(out, q)


def _check_roundtrip_bound(seed, bits, group, scale_pow):
    """Symmetric absmax round-trip error is <= scale/2 per element, for any
    data magnitude, both bit widths, and per-page or grouped scales."""
    rng = np.random.default_rng(seed)
    p, d = 8, 32
    x = (10.0 ** scale_pow) * rng.standard_normal((2, 2, p, d))
    x = jnp.asarray(x, jnp.float32)
    q, s = quantize_block(x, bits, group)
    deq = np.asarray(dequant_block(q, s, bits))
    g = group or d
    n_g = d // g
    err = np.abs(deq - np.asarray(x)).reshape(2, 2, p, n_g, g)
    bound = np.asarray(s)[:, :, None, :, None] * 0.5001 + 1e-30
    assert (err <= bound).all()


try:
    from hypothesis import given, settings, strategies as st

    SETTINGS = settings(max_examples=25, deadline=None)

    @given(seed=st.integers(0, 2 ** 31 - 1),
           d=st.sampled_from([2, 8, 32, 64]),
           lead=st.integers(1, 5))
    @SETTINGS
    def test_int4_pack_unpack_exact(seed, d, lead):
        _check_pack_unpack(seed, d, lead)

    @given(seed=st.integers(0, 2 ** 31 - 1),
           bits=st.sampled_from([8, 4]),
           group=st.sampled_from([0, 8, 16]),
           scale_pow=st.integers(-3, 3))
    @SETTINGS
    def test_roundtrip_error_bound(seed, bits, group, scale_pow):
        _check_roundtrip_bound(seed, bits, group, scale_pow)

except ImportError:                       # container without hypothesis

    @pytest.mark.parametrize("seed", range(8))
    @pytest.mark.parametrize("d,lead", [(2, 1), (8, 3), (32, 5), (64, 2)])
    def test_int4_pack_unpack_exact(seed, d, lead):
        _check_pack_unpack(seed, d, lead)

    @pytest.mark.parametrize("seed", range(4))
    @pytest.mark.parametrize("bits", [8, 4])
    @pytest.mark.parametrize("group", [0, 8, 16])
    @pytest.mark.parametrize("scale_pow", [-3, 0, 3])
    def test_roundtrip_error_bound(seed, bits, group, scale_pow):
        _check_roundtrip_bound(seed, bits, group, scale_pow)


def test_quantize_zero_page_exact():
    """All-zero pages (pool padding) survive the round trip exactly."""
    x = jnp.zeros((3, 2, 8, 16), jnp.float32)
    q, s = quantize_block(x, 4, 0)
    np.testing.assert_array_equal(np.asarray(dequant_block(q, s, 4)), 0.0)


# ---------------------------------------------------------------------------
# fused dequant recall kernel parity vs the jnp reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits,group", [(8, 0), (8, 16), (4, 0), (4, 8)])
@pytest.mark.parametrize("n_sel,chunk", [(5, 2), (6, 6), (1, 8)])
def test_quant_kernel_parity(bits, group, n_sel, chunk):
    """recall_gather_quant (2-deep VMEM ring, page+scale DMA, in-kernel
    dequant) matches dequant_recall_pages bit-for-bit in interpret mode,
    including invalid (-1) lanes and non-divisible chunk tails."""
    from repro.kernels import ops
    B, n_pages, kv, p, d = 2, 12, 3, 8, 32
    pool_f = jax.random.normal(KEY, (B, n_pages, kv, 2, p, d))
    pool_q, scales = quantize_block(pool_f, bits, group)
    idx = jax.random.randint(jax.random.fold_in(KEY, 7 * bits + n_sel),
                             (B, kv, n_sel), -2, n_pages).astype(jnp.int32)
    k1, v1 = ops.recall_gather_quant(pool_q, scales, idx, bits=bits,
                                     chunk=chunk)
    k2, v2 = dequant_recall_pages(pool_q, scales, idx, bits)
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    vo = ops.recall_values_quant(pool_q, scales, idx, bits=bits, chunk=chunk)
    np.testing.assert_array_equal(
        np.asarray(vo),
        np.asarray(dequant_recall_values(pool_q, scales, idx, bits)))


def test_invalid_lanes_are_zero():
    pool_f = jax.random.normal(KEY, (1, 4, 2, 2, 8, 16))
    pool_q, scales = quantize_block(pool_f, 8, 0)
    idx = jnp.full((1, 2, 3), -1, jnp.int32)
    k, v = dequant_recall_pages(pool_q, scales, idx, 8)
    np.testing.assert_array_equal(np.asarray(k), 0.0)
    np.testing.assert_array_equal(np.asarray(v), 0.0)


# ---------------------------------------------------------------------------
# paging: quantize-at-offload keeps decode-time pages == prefill pages
# ---------------------------------------------------------------------------
def test_append_token_offloads_quantized_page(smoke_cfg):
    """A page completed during decode is quantized exactly like a prefill
    page of the same content (one quantization, at offload time)."""
    cfg = smoke_cfg
    fkv = FreeKVConfig(kv_quant="int8", **FKV_BASE)
    kv, d = cfg.n_kv_heads, cfg.d_head
    p = fkv.page_size
    st = paging.init_kv_state(cfg, fkv, 1, 64, jnp.float32)
    assert st["pool"].dtype == jnp.int8 and "pool_scale" in st
    toks = jax.random.normal(KEY, (2 * p, kv, d))
    for t in range(2 * p):
        st = paging.append_token(st, toks[None, t], toks[None, t])
    # pages 0 and 1 hold tokens [0, p) and [p, 2p)
    hnd = paging.nhd_pages_to_hnd(
        toks[None].reshape(1, 2, p, kv, d), toks[None].reshape(1, 2, p, kv, d))
    qref, sref = quantize_block(hnd, 8, fkv.quant_group_size)
    np.testing.assert_array_equal(np.asarray(st["pool"][:, :2]),
                                  np.asarray(qref))
    np.testing.assert_array_equal(np.asarray(st["pool_scale"][:, :2]),
                                  np.asarray(sref))


def test_none_state_has_no_quant_leaves(smoke_cfg):
    st = paging.init_kv_state(smoke_cfg, FreeKVConfig(**FKV_BASE), 1, 64,
                              jnp.float32)
    assert "pool_scale" not in st and st["pool"].dtype == jnp.float32
    assert paging.quant_info(st) is None


# ---------------------------------------------------------------------------
# retrievers: quantized recall stays close; pipeline invariant survives quant
# ---------------------------------------------------------------------------
def _setup(cfg, fkv, B=2, T=96, max_len=160):
    kv, d, H = cfg.n_kv_heads, cfg.d_head, cfg.n_heads
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, kv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, T, kv, d))
    q_last = jax.random.normal(jax.random.fold_in(KEY, 3), (B, H, d))
    r = make_retriever(cfg, fkv)
    return r, r.prefill(r.init_state(B, max_len, jnp.float32), k, v, q_last)


def _steps(cfg, r, st, n=6):
    outs = []
    for t in range(n):
        kq = jax.random.fold_in(KEY, 100 + t)
        q = jax.random.normal(kq, (2, cfg.n_heads, cfg.d_head))
        kn = jax.random.normal(jax.random.fold_in(kq, 1),
                               (2, cfg.n_kv_heads, cfg.d_head))
        vn = jax.random.normal(jax.random.fold_in(kq, 2),
                               (2, cfg.n_kv_heads, cfg.d_head))
        o, st, info = r.decode(st, q, kn, vn)
        outs.append(np.asarray(o))
    return outs, st, info


@pytest.mark.parametrize("method", ["freekv", "arkvale", "quest", "shadowkv"])
def test_quant_decode_close_to_fp(smoke_cfg, method):
    """int8 recall stays within ~2% of the fp path for every retriever that
    reads the pool; the transfer accounting (block counts) is unchanged —
    quantization shrinks bytes/block, never the schedule."""
    cfg = smoke_cfg
    outs = {}
    infos = {}
    for mode in ("none", "int8"):
        fkv = FreeKVConfig(kv_quant=mode, **{**FKV_BASE, "method": method})
        r, st = _setup(cfg, fkv)
        outs[mode], _, infos[mode] = _steps(cfg, r, st)
    for a, b in zip(outs["none"], outs["int8"]):
        rel = np.linalg.norm(a - b) / max(np.linalg.norm(a), 1e-6)
        assert rel < 0.02, rel
    np.testing.assert_array_equal(np.asarray(infos["none"]["sync_pages"]),
                                  np.asarray(infos["int8"]["sync_pages"]))


def test_pipeline_bit_identical_under_quant(smoke_cfg):
    """The PR-2 invariant extends to the quantized tier: pool pages are still
    written once and dequant is deterministic, so overlapped vs synchronous
    recall stays bit-identical at int8/int4 too."""
    cfg = smoke_cfg
    for mode in ("int8", "int4"):
        outs = {}
        for overlap in (False, True):
            fkv = FreeKVConfig(kv_quant=mode, recall_overlap=overlap,
                               **FKV_BASE)
            r, st = _setup(cfg, fkv)
            outs[overlap], _, _ = _steps(cfg, r, st)
        for a, b in zip(outs[True], outs[False]):
            np.testing.assert_array_equal(a, b)


def test_quant_kernel_path_matches_jnp_path(smoke_cfg):
    """use_kernels=True routes recall through the fused dequant kernel; the
    recalled pages are bit-identical to the jnp dequant gather."""
    cfg = smoke_cfg
    outs = {}
    for uk in (False, True):
        fkv = FreeKVConfig(kv_quant="int8", use_kernels=uk,
                           recall_chunk_pages=2, **FKV_BASE)
        r, st = _setup(cfg, fkv)
        outs[uk], _, _ = _steps(cfg, r, st, n=2)
    for a, b in zip(outs[False], outs[True]):
        np.testing.assert_allclose(a, b, atol=1e-5)


# ---------------------------------------------------------------------------
# engine: kv_quant="none" bit-identity through slot turnover + accounting
# ---------------------------------------------------------------------------
def _generate(fkv, prompts, cfg, params):
    from repro.serving.engine import Request, ServeEngine
    from repro.serving.sampling import SamplerConfig
    eng = ServeEngine(cfg, fkv, params, max_len=160, batch_size=2,
                      sampler=SamplerConfig(temperature=0.0))
    reqs = [Request(uid=i, tokens=p, max_new_tokens=4 + 3 * (i % 2))
            for i, p in enumerate(prompts)]          # staggered -> turnover
    outs = eng.generate(reqs)
    return [o.tokens for o in outs], eng


def test_engine_none_bit_identity_and_quant_accounting():
    """Greedy outputs with kv_quant="none" are bit-identical pipeline on/off
    through continuous-batching slot turnover (the quant plumbing adds no
    leaves and changes no graph), and the quantized modes report shrunken
    blocks / pool bytes through EngineMetrics.summary()["kv_quant"]."""
    from repro.models.model import init_params
    cfg = get_config("smollm-360m-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
               for _ in range(4)]
    toks = {}
    engines = {}
    for mode in ("none", "int8"):
        for overlap in (False, True):
            fkv = FreeKVConfig(kv_quant=mode, recall_overlap=overlap,
                               **FKV_BASE)
            toks[(mode, overlap)], engines[mode] = _generate(
                fkv, prompts, cfg, params)
        assert toks[(mode, True)] == toks[(mode, False)]

    em_none = engines["none"].last_metrics
    em_q = engines["int8"].last_metrics
    sq = em_q.summary()["kv_quant"]
    sn = em_none.summary()["kv_quant"]
    # dense accounting unchanged when off
    assert sn["mode"] == "none" and sn["bytes_saved"] == 0.0
    assert sn["page_block_bytes"] == sn["dense_block_bytes"]
    # quantized: packed block strictly smaller, savings and dequant overhead
    # proportional to moved blocks, pool physically compressed
    assert sq["page_block_bytes"] < sq["dense_block_bytes"]
    assert sq["moved_page_blocks"] > 0
    assert sq["bytes_saved"] == pytest.approx(
        sq["moved_page_blocks"]
        * (sq["dense_block_bytes"] - sq["page_block_bytes"]))
    assert sq["dequant_overhead_s"] > 0
    assert sq["pool_bytes_physical"] < sq["pool_bytes_dense"]
    assert sq["pool_compression"] > 3.0          # int8 vs fp32 state dtype
    # slot-pool accounting agrees with the offload walk
    pool = engines["int8"]._pool
    assert pool.pool_bytes() == pytest.approx(sq["pool_bytes_physical"])
    detail = pool.pool_bytes_detail()
    assert detail["physical"] == pool.pool_bytes()
    assert detail["scales"] > 0 and detail["ratio"] > 3.0


def test_block_bytes_accounting():
    """The packed transfer unit: payload + fp32 scales, and the advertised
    >=1.9x (int8) / >=3.5x (int4) reductions vs the fp16 dense block."""
    for p, d, g in [(32, 128, 0), (32, 128, 32), (16, 64, 16)]:
        dense = accounting.page_block_bytes_dense(
            FreeKVConfig(page_size=p), d, itemsize=2)
        assert dense == 2 * p * d * 2
        f8 = FreeKVConfig(page_size=p, kv_quant="int8", quant_group_size=g)
        f4 = FreeKVConfig(page_size=p, kv_quant="int4", quant_group_size=g)
        b8 = accounting.page_block_bytes(f8, d, itemsize=2)
        b4 = accounting.page_block_bytes(f4, d, itemsize=2)
        n_g = d // (g or d)
        assert b8 == 2 * p * d + 2 * n_g * 4
        assert b4 == p * d + 2 * n_g * 4
        assert dense / b8 >= 1.9 and dense / b4 >= 3.5
