import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FreeKVConfig

# Modules kept whole on one shard: their session-scoped fixture (a multi-
# device subprocess driver) would otherwise re-run once per shard.
_ATOMIC_MODULES = ("test_centroid_index.py", "test_preemption.py",
                   "test_sharded_serving.py", "test_spec_decode.py")


def pytest_collection_modifyitems(config, items):
    """Deterministic test sharding for the CI matrix — no plugin needed.

    ``PYTEST_SHARD_COUNT=N PYTEST_SHARD_ID=i`` keeps every N-th collected
    item (round-robin, so heavy parametrized groups spread evenly), except
    for _ATOMIC_MODULES which are pinned whole — one module per shard by its
    position in the (sorted) tuple, so the heavy subprocess drivers land on
    DIFFERENT shards instead of hashing onto the same one.
    Unset / count<=1 runs everything (local default)."""
    count = int(os.environ.get("PYTEST_SHARD_COUNT", "0") or 0)
    if count <= 1:
        return
    shard = int(os.environ.get("PYTEST_SHARD_ID", "0")) % count
    keep, drop = [], []
    idx = 0
    for item in items:
        fname = os.path.basename(str(item.fspath))
        if fname in _ATOMIC_MODULES:
            key = _ATOMIC_MODULES.index(fname)    # stable across machines
        else:
            key = idx
            idx += 1
        (keep if key % count == shard else drop).append(item)
    if drop:
        config.hook.pytest_deselected(items=drop)
        items[:] = keep


@pytest.fixture(scope="session")
def small_fkv():
    return FreeKVConfig(method="freekv", page_size=8, budget=64, n_sink=8,
                        n_window=8, tau=0.8)


@pytest.fixture(scope="session")
def smoke_cfg():
    return get_config("granite-3-8b-smoke")


def rand_kv(key, B, T, kv, d, dtype=jnp.float32):
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, kv, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, kv, d), dtype)
    return k, v
