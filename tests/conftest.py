import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FreeKVConfig


@pytest.fixture(scope="session")
def small_fkv():
    return FreeKVConfig(method="freekv", page_size=8, budget=64, n_sink=8,
                        n_window=8, tau=0.8)


@pytest.fixture(scope="session")
def smoke_cfg():
    return get_config("granite-3-8b-smoke")


def rand_kv(key, B, T, kv, d, dtype=jnp.float32):
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, kv, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, kv, d), dtype)
    return k, v
