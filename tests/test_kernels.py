"""Per-kernel shape/dtype sweeps, assert_allclose vs the ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dt):
    return dict(atol=5e-2, rtol=5e-2) if dt == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,kv,G,N,p,d", [
    (1, 1, 1, 2, 8, 128), (2, 3, 4, 6, 32, 128), (1, 2, 8, 4, 16, 64),
    (3, 4, 2, 5, 32, 256),
])
def test_paged_attention_sweep(B, kv, G, N, p, d, dtype):
    q = jax.random.normal(KEY, (B, kv, G, d), dtype)
    kp = jax.random.normal(jax.random.fold_in(KEY, 1), (B, kv, N, p, d), dtype)
    vp = jax.random.normal(jax.random.fold_in(KEY, 2), (B, kv, N, p, d), dtype)
    pos = jax.random.randint(jax.random.fold_in(KEY, 3), (B, kv, N, p), -1,
                             N * p)
    cur = jnp.full((B,), N * p, jnp.int32)
    scale = 1.0 / d ** 0.5
    o = ops.paged_attention(q, kp, vp, pos, cur, scale=scale)
    oref = ref.paged_attention_ref(q, kp, vp, pos, cur, scale)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), **_tol(dtype))


def test_paged_attention_softcap():
    B, kv, G, N, p, d = 2, 2, 2, 4, 16, 128
    q = jax.random.normal(KEY, (B, kv, G, d))
    kp = jax.random.normal(jax.random.fold_in(KEY, 1), (B, kv, N, p, d))
    vp = jax.random.normal(jax.random.fold_in(KEY, 2), (B, kv, N, p, d))
    pos = jax.random.randint(jax.random.fold_in(KEY, 3), (B, kv, N, p), -1, 60)
    cur = jnp.full((B,), 64, jnp.int32)
    o = ops.paged_attention(q, kp, vp, pos, cur, scale=0.1, softcap=20.0)
    oref = ref.paged_attention_ref(q, kp, vp, pos, cur, 0.1, softcap=20.0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), atol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,T,kv,d,p", [
    (1, 64, 1, 128, 8), (2, 128, 3, 128, 32), (2, 96, 2, 64, 16),
])
def test_page_summary_sweep(B, T, kv, d, p, dtype):
    k = jax.random.normal(KEY, (B, T, kv, d), dtype)
    s = ops.page_summary(k, page_size=p)
    sref = ref.page_summary_ref(k.reshape(B, T // p, p, kv, d))
    np.testing.assert_allclose(np.asarray(s, np.float32),
                               np.asarray(sref, np.float32), atol=0)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,kv,G,d,N", [
    (1, 1, 1, 128, 4), (2, 3, 4, 128, 8), (2, 2, 5, 64, 256),
])
def test_page_scores_sweep(B, kv, G, d, N, dtype):
    q = jax.random.normal(KEY, (B, kv, G, d), dtype)
    raw = jax.random.normal(jax.random.fold_in(KEY, 1), (B, N, kv, 2, d), dtype)
    summ = jnp.stack([jnp.minimum(raw[..., 0, :], raw[..., 1, :]),
                      jnp.maximum(raw[..., 0, :], raw[..., 1, :])], axis=3)
    s = ops.page_scores(q, summ, scale=0.088)
    sref = ref.page_scores_ref(q, summ, 0.088)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sref, np.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,n_pages,kv,p,d,n_sel", [
    (1, 4, 1, 8, 128, 2), (2, 16, 3, 32, 128, 5), (2, 8, 2, 16, 64, 8),
])
def test_recall_gather_sweep(B, n_pages, kv, p, d, n_sel, dtype):
    pool = jax.random.normal(KEY, (B, n_pages, kv, 2, p, d), dtype)
    idx = jax.random.randint(jax.random.fold_in(KEY, 1), (B, kv, n_sel), -1,
                             n_pages)
    k, v = ops.recall_gather(pool, idx)
    kr, vr = ref.recall_gather_ref(pool, idx)
    np.testing.assert_allclose(np.asarray(k, np.float32),
                               np.asarray(kr, np.float32), atol=0)
    np.testing.assert_allclose(np.asarray(v, np.float32),
                               np.asarray(vr, np.float32), atol=0)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,H,kv,T,d,blk", [
    (1, 2, 1, 128, 128, 64), (2, 6, 3, 256, 64, 128), (1, 4, 4, 128, 128, 128),
])
def test_flash_prefill_sweep(B, H, kv, T, d, blk, dtype):
    q = jax.random.normal(KEY, (B, H, T, d), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, kv, T, d), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, kv, T, d), dtype)
    scale = 1.0 / d ** 0.5
    o = ops.flash_prefill(q, k, v, scale=scale, blq=blk, blk=blk)
    oref = ref.flash_prefill_ref(q, k, v, scale)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), **_tol(dtype))


def test_flash_prefill_window():
    B, H, kv, T, d = 1, 2, 2, 256, 64
    q = jax.random.normal(KEY, (B, H, T, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, kv, T, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, kv, T, d))
    o = ops.flash_prefill(q, k, v, scale=0.125, window=64, blq=64, blk=64)
    oref = ref.flash_prefill_ref(q, k, v, 0.125, window=64)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), atol=2e-5)
