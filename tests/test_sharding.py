"""Sharding rules (AbstractMesh — no devices needed) + 1-device pjit
integration + loop-aware HLO analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config, ASSIGNED
from repro.configs.base import FreeKVConfig, SHAPES
from repro.models.model import init_decode_state, init_params
from repro.sharding import rules

def _abstract_mesh(shape, names):
    try:
        return AbstractMesh(shape, names)
    except TypeError:   # jax <= 0.4.x: single shape_tuple of (name, size) pairs
        return AbstractMesh(tuple(zip(names, shape)))


MESHES = [_abstract_mesh((16, 16), ("data", "model")),
          _abstract_mesh((2, 16, 16), ("pod", "data", "model"))]
FKV = FreeKVConfig(method="freekv", page_size=32, budget=2048, n_sink=512,
                   n_window=512, pool_pad_pages=512)


def _check_divisible(mesh, spec, shape, where):
    for dim, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        assert shape[dim] % n == 0, (where, shape, spec)


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("mesh", MESHES, ids=["single", "multi"])
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), jnp.bfloat16))

    def f(path, leaf):
        spec = rules.param_spec(mesh, rules._path_str(path), leaf)
        _check_divisible(mesh, spec, leaf.shape, rules._path_str(path))
    jax.tree_util.tree_map_with_path(f, shapes)


@pytest.mark.parametrize("arch", ["granite-3-8b", "deepseek-moe-16b",
                                  "jamba-1.5-large-398b", "whisper-tiny",
                                  "xlstm-350m"])
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_decode_state_specs_divisible(arch, shape_name):
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    mesh = MESHES[0]
    st = jax.eval_shape(lambda: init_decode_state(
        cfg, FKV, shp.global_batch, shp.seq_len + 64, jnp.bfloat16))

    def f(path, leaf):
        spec = rules.decode_state_spec(cfg, mesh, rules._path_str(path), leaf)
        _check_divisible(mesh, spec, leaf.shape, rules._path_str(path))
    jax.tree_util.tree_map_with_path(f, st)


def test_pjit_one_device_end_to_end(small_fkv):
    """The full sharded pipeline on the real 1-device mesh: values must match
    the unsharded path exactly (mesh plumbing is semantically a no-op)."""
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import forward_train, prefill, serve_step
    cfg = get_config("deepseek-moe-16b-smoke")   # exercises MoE shard_map
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                          cfg.vocab_size)}
    mesh = make_host_mesh(1)
    loss_plain, _ = jax.jit(lambda p, b: forward_train(cfg, p, b))(params, batch)
    with mesh:
        loss_mesh, _ = jax.jit(
            lambda p, b: forward_train(cfg, p, b, mesh=mesh))(params, batch)
        logits, st = jax.jit(lambda p, b: prefill(
            cfg, small_fkv, p, b, max_len=96, mesh=mesh,
            state_dtype=jnp.float32))(params, batch)
        tok = jnp.argmax(logits, -1)[:, None]
        logits2, st = jax.jit(lambda p, s, t: serve_step(
            cfg, small_fkv, p, s, t, mesh=mesh))(params, st, tok)
    np.testing.assert_allclose(float(loss_plain), float(loss_mesh), rtol=2e-4)
    assert jnp.isfinite(logits2).all()


def test_hlo_cost_analyzer_loops():
    from repro.launch import hlo_cost
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()
    comp = jax.jit(f).lower(jnp.ones((64, 128)), jnp.ones((128, 128))).compile()
    r = hlo_cost.analyze(comp.as_text())
    expected = 7 * 2 * 64 * 128 * 128
    assert abs(r["flops"] - expected) / expected < 0.01
    # grad-of-scan: fwd 7 dots + bwd 14 dots
    comp2 = jax.jit(jax.grad(f, argnums=1)).lower(
        jnp.ones((64, 128)), jnp.ones((128, 128))).compile()
    r2 = hlo_cost.analyze(comp2.as_text())
    assert abs(r2["flops"] - 3 * expected) / (3 * expected) < 0.05


def test_collective_parse():
    from repro.launch import roofline as rl
    hlo = """
  %ag = bf16[128,256] all-gather(%x), replica_groups={}
  %ar = f32[64] all-reduce(%y), to_apply=%sum
  %a2a.1 = f32[32,32] all-to-all(%z)
"""
    c = rl.collective_bytes_per_device(hlo)
    assert c["per_op"]["all-gather"] == 128 * 256 * 2
    assert c["per_op"]["all-reduce"] == 2 * 64 * 4
    assert c["per_op"]["all-to-all"] == 32 * 32 * 4
