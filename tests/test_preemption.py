"""Priority preemption + chunked prefill: randomized scheduler suite.

Three layers of coverage:

* a **randomized state-machine suite** (hypothesis-driven when installed —
  CI — seeded sweep otherwise) drives ``ContinuousScheduler`` with a fake
  backend/pool over random admit/chunk/preempt/resume/finish interleavings
  and checks the structural invariants: no slot double-occupancy, every
  preempted request resumes and finishes, preempt/resume and swap byte
  counters conserve, token counts conserve, and the produced token streams
  are BIT-IDENTICAL to a never-preempt never-chunk run of the same traffic
  (per-request PRNG streams make this a structural property);
* **SlotPool swap exactness**: ``swap_out`` -> ``swap_in`` round-trips every
  decode-state leaf bit-for-bit at its stored dtype — the packed int8/int4
  pool payload and fp32 scales move as stored, never dequantized;
* **real-engine bit-identity**: greedy outputs with preemption firing (and
  with chunked prefill + preemption together) equal the uninterrupted run,
  for kv_quant none and int8 — plus a 2-forced-device subprocess driver
  repeating the check under tp=2 (pinned whole to one CI shard, see
  conftest._ATOMIC_MODULES).
"""
import json
import os
import subprocess
import sys
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.serving.kv_slots import SlotPool
from repro.serving.scheduler import SWAPPED, ContinuousScheduler

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# fake backend/pool: the scheduler's protocol, no model
# ---------------------------------------------------------------------------
@dataclass
class FakeReq:
    uid: int
    tokens: np.ndarray
    max_new_tokens: int
    priority: int = 0
    eos_token: Optional[int] = None


def _tok(key_row, count: int) -> int:
    """Deterministic token from (per-request key, position) ONLY — the
    same contract the real on-device sampler provides (fold_in(rkey, i)),
    so placement/co-scheduling/preemption cannot change the stream."""
    return int((int(key_row[0]) * 2654435761 + int(key_row[1])
                + count * 97) % 9973)


class FakeJob:
    """Chunked-prefill job protocol: .advance/.done/.result/.pos/.seq."""

    def __init__(self, backend, req):
        self.backend, self.req = backend, req
        self.seq = tuple(int(t) for t in req.tokens)
        self.pos = 0
        self.chunks = 0
        self.result = None

    @property
    def remaining(self):
        return len(self.seq) - self.pos

    @property
    def done(self):
        return self.result is not None

    def advance(self, budget: int) -> int:
        assert not self.done and budget > 0
        n = min(int(budget), self.remaining)
        self.pos += n
        self.chunks += 1
        if self.pos == len(self.seq):
            self.result = (None, self.backend.make_state(self.req.uid),
                           0, len(self.seq))
        return n


class FakePool:
    """Slot bookkeeping with the SlotPool surface the scheduler touches.
    ``alloc`` asserts no double-occupancy — the invariant the randomized
    suite exercises under preemption churn."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self.state = {"slots": [None] * num_slots}
        self.owner: List[Optional[int]] = [None] * num_slots
        self._free = list(range(num_slots - 1, -1, -1))
        self.allocs = 0
        self.swaps = 0

    @property
    def free_count(self):
        return len(self._free)

    def alloc(self, uid: int) -> int:
        slot = self._free.pop()
        assert self.owner[slot] is None, \
            f"slot {slot} double-allocated: owned by {self.owner[slot]}"
        self.owner[slot] = uid
        self.allocs += 1
        return slot

    def free(self, slot: int):
        assert self.owner[slot] is not None, f"slot {slot} already free"
        self.owner[slot] = None
        self._free.append(slot)

    def flush_resets(self):
        pass

    def insert(self, src, slot: int):
        self.state["slots"][slot] = src

    def swap_out(self, slot: int):
        host = self.state["slots"][slot]
        assert host is not None, f"slot {slot} swapped out empty"
        self.state["slots"][slot] = None
        self.swaps += 1
        return host

    def swap_in(self, host, slot: int):
        assert host is not None
        self.state["slots"][slot] = host


@dataclass
class FakeBackend:
    """Sync-path scheduler protocol; tokens depend only on (uid, count)."""
    prefill_chunk_tokens: int = 0
    preempt: bool = False
    page_block_bytes: int = 1024
    states: dict = field(default_factory=dict)

    def make_state(self, uid: int):
        # distinct nbytes per request so swap byte accounting is testable
        st = {"uid": np.full((1,), uid, np.int64),
              "payload": np.zeros((uid % 3 + 1, 4), np.float32)}
        self.states[uid] = st
        return st

    def prefill_one(self, req):
        return None, self.make_state(req.uid), 0, len(req.tokens)

    def start_prefill_job(self, req):
        return FakeJob(self, req)

    def sample_slot(self, logits, rkey, count):
        return np.asarray([_tok(np.asarray(rkey), int(count))])

    def sample_lanes(self, logits, keys, counts):
        k = np.asarray(keys)
        c = np.asarray(counts)
        return np.asarray([_tok(k[i], int(c[i])) for i in range(len(c))])

    def step(self, state, tokens):
        # verify every occupied slot still holds ITS request's state — a
        # wrong swap restore would decode over someone else's KV
        for s, st in enumerate(state["slots"]):
            if st is not None:
                assert st is self.states[int(st["uid"][0])]
        return None, state, {}


def _traffic(rng, n_req, max_prio):
    return [FakeReq(uid=i,
                    tokens=rng.integers(0, 5000, rng.integers(1, 20))
                    .astype(np.int32),
                    max_new_tokens=int(rng.integers(0, 9)),
                    priority=int(rng.integers(0, max_prio + 1)))
            for i in range(n_req)]


def _run(reqs, num_slots, chunk, preempt, seed=7):
    backend = FakeBackend(prefill_chunk_tokens=chunk, preempt=preempt)
    pool = FakePool(num_slots)
    done, em = ContinuousScheduler(backend, pool).run(
        [FakeReq(r.uid, r.tokens, r.max_new_tokens, r.priority, r.eos_token)
         for r in reqs], seed=seed)
    return done, em, pool


def _check_scenario(seed, n_req, num_slots, chunk, max_prio, preempt):
    rng = np.random.default_rng(seed)
    reqs = _traffic(rng, n_req, max_prio)
    done, em, pool = _run(reqs, num_slots, chunk, preempt)
    base, em0, _ = _run(reqs, num_slots, chunk=0, preempt=False)

    # every request finishes, in submission order, with its full budget
    assert [tr.req.uid for tr in done] == [r.uid for r in reqs]
    for tr, r in zip(done, reqs):
        assert tr.state == "done" and tr.state != SWAPPED
        assert len(tr.tokens) == r.max_new_tokens
        assert tr.host_state is None          # nothing left parked on host
    # bit-identity vs the never-chunk never-preempt run of the same traffic
    assert [tr.tokens for tr in done] == [tr.tokens for tr in base]
    # token conservation: decode steps account for every token after the
    # prefill-sampled first one, invariant to chunking and preemption
    admitted = [r for r in reqs if r.max_new_tokens > 0]
    assert sum(len(tr.tokens) for tr in done) == \
        sum(r.max_new_tokens for r in reqs)
    assert em.active_slot_steps == em0.active_slot_steps == \
        sum(r.max_new_tokens - 1 for r in admitted)
    # pool drained: all slots free, no owners
    assert pool.free_count == pool.num_slots
    assert all(o is None for o in pool.owner)
    # preempt/resume and swap byte counters conserve
    assert em.preemptions == em.resumes == pool.swaps
    assert em.swap_out_bytes == em.swap_in_bytes
    assert sum(tr.metrics.preemptions for tr in done) == em.preemptions
    if not preempt or max_prio == 0:
        assert em.preemptions == 0
    # chunked prefill accounting: every admitted prompt token chunked once
    if chunk > 0:
        assert em.prefill_chunk_tokens == sum(len(r.tokens)
                                              for r in admitted)
        if admitted:
            assert em.prefill_chunks >= len(admitted)


try:
    from hypothesis import given, settings, strategies as st

    @given(seed=st.integers(0, 2 ** 31 - 1),
           n_req=st.integers(1, 8),
           num_slots=st.integers(1, 4),
           chunk=st.integers(0, 6),
           max_prio=st.integers(0, 2),
           preempt=st.booleans())
    @settings(max_examples=60, deadline=None)
    def test_scheduler_state_machine(seed, n_req, num_slots, chunk,
                                     max_prio, preempt):
        _check_scenario(seed, n_req, num_slots, chunk, max_prio, preempt)

except ImportError:                                   # pragma: no cover
    @pytest.mark.parametrize("seed", range(12))
    @pytest.mark.parametrize("num_slots,chunk,max_prio,preempt", [
        (1, 0, 2, True), (2, 3, 2, True), (3, 1, 1, True),
        (2, 0, 0, True), (4, 6, 2, True), (2, 4, 0, False),
    ])
    def test_scheduler_state_machine(seed, num_slots, chunk, max_prio,
                                     preempt):
        _check_scenario(seed, n_req=2 + seed % 7, num_slots=num_slots,
                        chunk=chunk, max_prio=max_prio, preempt=preempt)


def test_priority_preempts_lowest_and_resumes():
    """Directed scenario: a late high-priority request steals the slot of
    the LOWEST-priority running request, which resumes and finishes with an
    unchanged token stream; equal priorities never preempt."""
    reqs = [FakeReq(0, np.arange(6, dtype=np.int32), 6, priority=0),
            FakeReq(1, np.arange(8, dtype=np.int32), 6, priority=1),
            FakeReq(2, np.arange(4, dtype=np.int32), 3, priority=2)]
    done, em, _ = _run(reqs, num_slots=2, chunk=0, preempt=True)
    assert em.preemptions == 1
    by_uid = {tr.req.uid: tr for tr in done}
    assert by_uid[0].metrics.preemptions == 1      # lowest priority evicted
    assert by_uid[1].metrics.preemptions == 0
    assert by_uid[2].metrics.preemptions == 0
    # the high-priority request finishes before its victim
    assert by_uid[2].metrics.finish_step <= by_uid[0].metrics.finish_step
    base, _, _ = _run(reqs, num_slots=2, chunk=0, preempt=False)
    assert [tr.tokens for tr in done] == [tr.tokens for tr in base]

    same = [FakeReq(i, np.arange(4, dtype=np.int32), 4, priority=1)
            for i in range(3)]
    _, em2, _ = _run(same, num_slots=2, chunk=0, preempt=True)
    assert em2.preemptions == 0                    # strict inequality only


# ---------------------------------------------------------------------------
# SlotPool swap round trip: bit-exact at the stored (packed) width
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def smoke():
    from repro.models.model import init_params
    cfg = get_config("smollm-360m-smoke")
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _engine(cfg, params, **kw):
    from repro.serving.engine import ServeEngine
    from repro.serving.sampling import SamplerConfig
    fkv = FreeKVConfig(method="freekv", page_size=8, budget=64, n_sink=8,
                       n_window=8, tau=0.8, **kw)
    return ServeEngine(cfg, fkv, params, max_len=256, batch_size=2,
                       sampler=SamplerConfig(temperature=0.0),
                       prefill_bucket=8)


@pytest.mark.parametrize("kv_quant", ["none", "int8", "int4"])
def test_slot_swap_roundtrip_exact(smoke, kv_quant):
    """swap_out -> swap_in reproduces every leaf bit-for-bit at its stored
    dtype — the quantized pool payload moves packed, never dequantized —
    even into a DIFFERENT physical slot."""
    from repro.serving.engine import Request
    cfg, params = smoke
    eng = _engine(cfg, params, kv_quant=kv_quant)
    pool = eng.make_slot_pool(2)
    rng = np.random.default_rng(3)
    req = Request(uid=9, tokens=rng.integers(0, cfg.vocab_size, 48)
                  .astype(np.int32), max_new_tokens=4)
    _, state1, _, _ = eng.prefill_one(req)
    pool.insert(state1, 0)
    before = jax.tree.map(np.asarray, pool.extract(0))
    host = pool.swap_out(0)
    for leaf, ref in zip(jax.tree.leaves(host), jax.tree.leaves(before)):
        assert isinstance(leaf, np.ndarray)
        assert leaf.dtype == ref.dtype          # packed width preserved
    if kv_quant != "none":                      # pool payload stored packed
        assert any(l.dtype == np.int8 for l in jax.tree.leaves(host))
    pool.swap_in(host, 1)
    after = jax.tree.map(np.asarray, pool.extract(1))
    jax.tree.map(np.testing.assert_array_equal, before, after)


# ---------------------------------------------------------------------------
# real engine: preemption fires and greedy outputs are unchanged
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def preempt_runs(smoke):
    """Run the mixed-priority traffic once per config; tests assert views."""
    from repro.serving.engine import Request
    cfg, params = smoke
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (40, 64, 24)]

    def gen(**kw):
        eng = _engine(cfg, params, **kw)
        reqs = [Request(uid=i, tokens=p, max_new_tokens=10,
                        priority=(1 if i == 2 else 0))
                for i, p in enumerate(prompts)]
        outs = {o.uid: o.tokens for o in eng.generate(reqs)}
        return outs, eng.last_metrics

    runs = {}
    for quant in ("none", "int8"):
        runs[f"base/{quant}"] = gen(kv_quant=quant)
        runs[f"pre/{quant}"] = gen(kv_quant=quant, preempt=True)
    runs["both/none"] = gen(preempt=True, prefill_chunk_tokens=8)
    return runs


@pytest.mark.parametrize("quant", ["none", "int8"])
def test_preemption_bit_identical_real_engine(preempt_runs, quant):
    base, _ = preempt_runs[f"base/{quant}"]
    pre, em = preempt_runs[f"pre/{quant}"]
    assert pre == base, "preemption changed greedy outputs"
    assert em.preemptions >= 1 and em.resumes == em.preemptions
    assert em.swap_out_bytes == em.swap_in_bytes > 0
    pm = {m.uid: m for m in em.requests}
    assert pm[0].preemptions + pm[1].preemptions == em.preemptions
    assert pm[2].preemptions == 0               # high priority never evicted


def test_preemption_with_chunked_prefill_real_engine(preempt_runs):
    base, _ = preempt_runs["base/none"]
    both, em = preempt_runs["both/none"]
    assert both == base
    assert em.preemptions >= 1 and em.prefill_chunks > 0
    s = em.summary()["scheduling"]
    assert s["preemptions"] == em.preemptions
    assert s["swap_out_bytes"] == em.swap_out_bytes
    assert s["token_gap_s"]["count"] > 0


# ---------------------------------------------------------------------------
# tp=2: same invariants under KV-head-group sharding (subprocess driver)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="session")
def tp_preempt_report(tmp_path_factory):
    out = tmp_path_factory.mktemp("tp_preempt") / "report.json"
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    subprocess.run([sys.executable, os.path.abspath(__file__), str(out)],
                   check=True, timeout=1500, env=env, cwd=REPO)
    return json.loads(out.read_text())


def test_tp2_preemption_bit_identical(tp_preempt_report):
    r = tp_preempt_report["preempt"]
    assert r["tp2_preemptions"] >= 1
    assert r["tp2_tokens"] == r["tp1_tokens"] == r["base_tokens"]
    # the swap moves the same global state regardless of sharding
    assert r["tp2_swap_bytes"] == r["tp1_swap_bytes"] > 0


def test_tp2_swap_roundtrip_quantized(tp_preempt_report):
    r = tp_preempt_report["swap_roundtrip_int8"]
    assert r["bit_equal"] is True
    assert r["has_packed_leaf"] is True


def _driver(out_path):
    from repro.models.model import init_params
    from repro.serving.engine import Request, ServeEngine
    from repro.serving.sampling import SamplerConfig
    assert len(jax.devices()) >= 2, jax.devices()
    cfg = get_config("granite-3-8b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (40, 72, 56, 32)]

    def engine(tp, **kw):
        fkv = FreeKVConfig(method="freekv", page_size=8, budget=48, n_sink=8,
                           n_window=8, tau=0.8, **kw)
        return ServeEngine(cfg, fkv, params, max_len=160, batch_size=2,
                           sampler=SamplerConfig(temperature=0.0),
                           prefill_bucket=24, tp=tp)

    def gen(eng):
        reqs = [Request(uid=i, tokens=p, max_new_tokens=6,
                        priority=(1 if i == 3 else 0))
                for i, p in enumerate(prompts)]
        return [c.tokens for c in eng.generate(reqs)]

    report = {}
    base = gen(engine(1))
    e1 = engine(1, preempt=True)
    t1 = gen(e1)
    e2 = engine(2, preempt=True)
    t2 = gen(e2)
    report["preempt"] = {
        "base_tokens": base, "tp1_tokens": t1, "tp2_tokens": t2,
        "tp1_preemptions": e1.last_metrics.preemptions,
        "tp2_preemptions": e2.last_metrics.preemptions,
        "tp1_swap_bytes": e1.last_metrics.swap_out_bytes,
        "tp2_swap_bytes": e2.last_metrics.swap_out_bytes,
    }

    # int8 pool swap round trip under a 2-shard pool
    eq = engine(2, kv_quant="int8")
    pool = eq.make_slot_pool(2)
    _, state1, _, _ = eq.prefill_one(
        Request(uid=5, tokens=prompts[1], max_new_tokens=4))
    pool.insert(state1, 0)
    before = jax.tree.map(np.asarray, pool.extract(0))
    host = pool.swap_out(0)
    pool.swap_in(host, 1)
    after = jax.tree.map(np.asarray, pool.extract(1))
    flat_b, flat_a = jax.tree.leaves(before), jax.tree.leaves(after)
    report["swap_roundtrip_int8"] = {
        "bit_equal": bool(all(np.array_equal(a, b)
                              for a, b in zip(flat_b, flat_a))),
        "has_packed_leaf": bool(any(np.asarray(l).dtype == np.int8
                                    for l in jax.tree.leaves(host))),
    }

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, default=str)
    print(f"wrote {out_path}")


if __name__ == "__main__":
    _driver(sys.argv[1])
