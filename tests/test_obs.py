"""Observability plane: registry/histogram correctness, exporter schema
stability, trace well-formedness, and the zero-interference contract
(obs on vs off: bit-identical tokens, zero added host syncs/bytes).

Serving-stack fixtures reuse the tiny smoke arch; the engine runs are the
slowest part so they are shared per-module via fixtures.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.models.model import init_params
from repro.obs import (COUNT_BUCKETS, LATENCY_BUCKETS, RATE_BUCKETS,
                       Observability, TraceRecorder, validate_chrome_trace,
                       validate_snapshot)
from repro.obs.registry import (MetricsRegistry, SNAPSHOT_SCHEMA_VERSION,
                                exponential_buckets, linear_buckets)
from repro.obs.trace import (SPAN_DECODE_STEP, SPAN_DECODE_WINDOW,
                             SPAN_RECALL_STAGED, SPAN_RECALL_TOPUP,
                             SPAN_REQUEST_DECODE, SPAN_REQUEST_PREFILL,
                             SPAN_REQUEST_QUEUED, annotate)
from repro.serving.engine import Request, ServeEngine
from repro.serving.metrics import EngineMetrics
from repro.serving.sampling import SamplerConfig


# ---------------------------------------------------------------------------
# registry primitives
# ---------------------------------------------------------------------------
def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "help")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    assert reg.counter("c_total") is c          # get-or-create is idempotent
    g = reg.gauge("g")
    g.set(7)
    g.inc(-2)
    assert g.value == 5


def test_histogram_bucket_assignment():
    h = MetricsRegistry().histogram("h", [1.0, 2.0, 4.0])
    for v in (0.5, 1.0, 1.5, 3.0, 100.0):
        h.observe(v)
    # le-semantics: 0.5,1.0 -> bucket0; 1.5 -> bucket1; 3.0 -> bucket2;
    # 100 -> overflow
    assert h.counts == [2, 1, 1, 1]
    assert h.count == 5
    assert h.sum == pytest.approx(106.0)
    assert h.min == 0.5 and h.max == 100.0


def test_histogram_percentiles_against_numpy():
    rng = np.random.default_rng(0)
    xs = rng.exponential(0.01, size=5000)
    h = MetricsRegistry().histogram("lat", LATENCY_BUCKETS)
    for x in xs:
        h.observe(x)
    for q in (0.50, 0.90, 0.99):
        est = h.percentile(q)
        exact = float(np.quantile(xs, q))
        # bucketed estimate must land within one bucket boundary (2x) of
        # the exact quantile
        assert exact / 2 <= est <= exact * 2, (q, est, exact)
    # percentiles are clamped to the observed max (no bucket-edge overshoot)
    assert h.percentile(0.999) <= h.max


def test_histogram_summary_and_empty():
    h = MetricsRegistry().histogram("x", [1.0, 2.0])
    s = h.summary()
    assert s["count"] == 0 and s["p50"] == 0.0
    h.observe(1.5)
    s = h.summary()
    assert s["count"] == 1
    assert s["mean"] == pytest.approx(1.5)
    assert 1.0 <= s["p50"] <= 2.0                # inside containing bucket


def test_bucket_helpers():
    assert linear_buckets(0.0, 1.0, 5) == [0.0, 1.0, 2.0, 3.0, 4.0]
    e = exponential_buckets(1.0, 2.0, 4)
    assert e == [1.0, 2.0, 4.0, 8.0]
    for buckets in (LATENCY_BUCKETS, RATE_BUCKETS, COUNT_BUCKETS):
        assert buckets == sorted(buckets)


def test_snapshot_schema_and_validator():
    reg = MetricsRegistry()
    reg.counter("a_total").inc(3)
    reg.gauge("b").set(1.5)
    reg.histogram("c", [1.0, 2.0]).observe(0.5)
    snap = reg.snapshot()
    assert snap["schema_version"] == SNAPSHOT_SCHEMA_VERSION
    assert validate_snapshot(snap) == []
    # round-trips through JSON unchanged
    assert validate_snapshot(json.loads(json.dumps(snap))) == []
    # validator actually catches corruption
    bad = json.loads(json.dumps(snap))
    bad["histograms"]["c"]["bucket_counts"].append(9)
    assert validate_snapshot(bad)
    assert validate_snapshot({"schema_version": 999}) != []


def test_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests").inc(2)
    reg.histogram("lat_seconds", [0.1, 1.0], "latency").observe(0.05)
    reg.histogram("lat_seconds", [0.1, 1.0]).observe(5.0)
    text = reg.to_prometheus()
    assert "# TYPE req_total counter" in text
    assert "req_total 2" in text
    assert "# TYPE lat_seconds histogram" in text
    # cumulative buckets + +Inf terminal
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="+Inf"} 2' in text
    assert "lat_seconds_count 2" in text


def test_write_jsonl_appends(tmp_path):
    reg = MetricsRegistry()
    reg.counter("n_total").inc()
    path = tmp_path / "m.jsonl"
    reg.write_jsonl(str(path), extra={"run": 1})
    reg.counter("n_total").inc()
    reg.write_jsonl(str(path), extra={"run": 2})
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert len(lines) == 2
    assert [ln["extra"]["run"] for ln in lines] == [1, 2]
    assert lines[1]["counters"]["n_total"] == 2
    assert all(validate_snapshot(ln) == [] for ln in lines)


# ---------------------------------------------------------------------------
# trace recorder
# ---------------------------------------------------------------------------
def test_trace_recorder_events_and_validation():
    tr = TraceRecorder(enabled=True)
    tr.complete("engine/decode_step", 1.0, 0.002, args={"steps": 1})
    tr.instant("recall/reuse", 1.001)
    tr.counter("speculation", 1.0, {"hit_rate": 0.5})
    doc = tr.chrome_trace()
    assert validate_chrome_trace(doc) == []
    evs = doc["traceEvents"]
    x = next(e for e in evs if e["ph"] == "X")
    assert x["ts"] == pytest.approx(1.0e6)      # seconds -> microseconds
    assert x["dur"] == pytest.approx(2000.0)
    # disabled recorder drops everything
    off = TraceRecorder(enabled=False)
    off.complete("x", 0.0, 1.0)
    assert off.events == []


def test_trace_validator_catches_malformed():
    assert validate_chrome_trace({"no": "events"})
    assert validate_chrome_trace({"traceEvents": [{"ph": "X"}]})
    bad_dur = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 0, "dur": -5}]}
    assert validate_chrome_trace(bad_dur)


def test_annotate_composes_with_jit():
    import jax.numpy as jnp

    @jax.jit
    def f(x):
        with annotate("attn/compute"):
            return x * 2
    assert float(f(jnp.float32(1.0))) == 2.0


# ---------------------------------------------------------------------------
# engine integration: zero interference + exporter contents
# ---------------------------------------------------------------------------
ARCH = "smollm-360m-smoke"


def _run_engine(obs, new_tokens=6, requests=3, context=64):
    cfg = get_config(ARCH)
    params = init_params(cfg, jax.random.PRNGKey(0))
    fkv = FreeKVConfig(method="freekv", page_size=8, budget=48, n_sink=8,
                       n_window=8, tau=0.8, sync_interval=4)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        context).astype(np.int32),
                    max_new_tokens=new_tokens)
            for i in range(requests)]
    eng = ServeEngine(cfg, fkv, params, max_len=context + new_tokens + 8,
                      batch_size=2, sampler=SamplerConfig(temperature=0.0),
                      scheduler="continuous", obs=obs)
    outs = eng.generate(reqs)
    return [c.tokens for c in outs], eng


@pytest.fixture(scope="module")
def obs_on_off_runs():
    tok_off, eng_off = _run_engine(Observability.off())
    tok_on, eng_on = _run_engine(
        Observability(enabled=True, trace=TraceRecorder(enabled=True)))
    return tok_off, eng_off, tok_on, eng_on


def test_obs_zero_interference(obs_on_off_runs):
    tok_off, eng_off, tok_on, eng_on = obs_on_off_runs
    assert tok_on == tok_off                     # bit-identical greedy tokens
    off, on = eng_off.last_metrics, eng_on.last_metrics
    assert on.host_syncs == off.host_syncs       # zero added syncs
    assert on.nonsync_host_bytes == 0.0          # nothing moved between syncs
    assert on.sync_bytes_to_host == off.sync_bytes_to_host
    # counter totals identical: they run with obs on or off
    assert on.steps == off.steps
    assert on.sel_pages == off.sel_pages
    assert on.spec_hit_pages == off.spec_hit_pages


def test_speculation_telemetry_sane(obs_on_off_runs):
    _, _, _, eng_on = obs_on_off_runs
    em = eng_on.last_metrics
    s = em.summary()["speculation"]
    assert s["sel_pages"] > 0
    assert 0 <= s["spec_hit_pages"] <= s["sel_pages"]
    assert s["churn_pages"] == pytest.approx(s["sel_pages"]
                                             - s["spec_hit_pages"])
    assert 0.0 <= s["hit_rate_mean"] <= 1.0
    assert 0.0 <= s["correction_rate_mean"] <= 1.0
    # speculative hits == resident-buffer reuse hits (same mask, by
    # construction: match_resident against the previous selection)
    assert em.spec_hit_pages == pytest.approx(em.reused_pages)
    # per-step histograms populated, values inside the rate range
    assert s["hit_rate"]["count"] > 0
    assert 0.0 <= s["hit_rate"]["min"] <= s["hit_rate"]["max"] <= 1.0


def test_obs_off_skips_histograms(obs_on_off_runs):
    _, eng_off, _, eng_on = obs_on_off_runs
    off = eng_off.last_metrics.summary()
    on = eng_on.last_metrics.summary()
    assert off["speculation"]["hit_rate"]["count"] == 0
    assert on["speculation"]["hit_rate"]["count"] > 0
    assert off["latency"]["decode_step_s"]["count"] == 0
    assert on["latency"]["decode_step_s"]["count"] > 0
    # request-latency histograms record regardless (finish-time accounting)
    assert on["latency"]["ttft_s"]["count"] == on["completed"]


def test_engine_snapshot_valid_and_exports(obs_on_off_runs, tmp_path):
    _, _, _, eng_on = obs_on_off_runs
    reg = eng_on.last_metrics.registry
    assert validate_snapshot(reg.snapshot()) == []
    text = reg.to_prometheus()
    assert "engine_steps_total" in text
    assert "spec_hit_rate_bucket" in text
    path = tmp_path / "m.jsonl"
    reg.write_jsonl(str(path))
    assert validate_snapshot(json.loads(path.read_text())) == []


def test_engine_trace_perfetto_wellformed(obs_on_off_runs, tmp_path):
    _, _, _, eng_on = obs_on_off_runs
    tr = eng_on.obs.trace
    doc = tr.chrome_trace()
    assert validate_chrome_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"]}
    for required in (SPAN_REQUEST_QUEUED, SPAN_REQUEST_PREFILL,
                     SPAN_REQUEST_DECODE, SPAN_DECODE_WINDOW,
                     SPAN_DECODE_STEP, SPAN_RECALL_TOPUP):
        assert required in names, required
    # staged DMA spans appear when the overlapped pipeline moved bytes
    if eng_on.last_metrics.async_pages > 0:
        assert SPAN_RECALL_STAGED in names
    # decode-step spans nest inside their window on the engine track
    wins = [e for e in doc["traceEvents"]
            if e["name"] == SPAN_DECODE_WINDOW and e["ph"] == "X"]
    steps = [e for e in doc["traceEvents"]
             if e["name"] == SPAN_DECODE_STEP and e["ph"] == "X"]
    assert wins and steps
    lo = min(w["ts"] for w in wins)
    hi = max(w["ts"] + w["dur"] for w in wins)
    assert all(lo <= s["ts"] <= hi + 1 for s in steps)
    out = tmp_path / "t.json"
    tr.write(str(out))
    assert validate_chrome_trace(json.loads(out.read_text())) == []


def test_engine_metrics_summary_dedup():
    em = EngineMetrics(num_slots=2)
    s = em.summary()
    # satellite: the duplicated top-level byte counters are gone — the
    # recall_overlap section is the single source of truth
    assert "recall_bytes_sync" not in s
    assert "recall_bytes_async" not in s
    assert "exposed_bytes" in s["recall_overlap"]
    assert "hidden_bytes" in s["recall_overlap"]
    # legacy attribute style still works (registry-backed properties)
    em.steps += 3
    em.sync_pages += 1.5
    assert em.steps == 3 and isinstance(em.steps, int)
    assert em.sync_pages == 1.5
