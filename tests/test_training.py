"""Training substrate: loss goes down, checkpoint roundtrip, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import lm_batches, needle_stream
from repro.training import checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train, make_train_step


def test_train_loss_decreases(tmp_path):
    cfg = get_config("smollm-360m-smoke")
    opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60)
    params, opt_state = init_train(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt))
    data = lm_batches(cfg.vocab_size, 128, 8, seed=0)
    losses = []
    for i in range(40):
        params, opt_state, m = step(params, opt_state,
                                    {"tokens": jnp.asarray(next(data))})
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])
    # checkpoint roundtrip (params + opt state)
    ck = os.path.join(tmp_path, "state.npz")
    checkpoint.save(ck, {"params": params, "opt": opt_state})
    restored = checkpoint.restore(ck, {"params": params, "opt": opt_state})
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(
            {"params": params, "opt": opt_state})):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_determinism():
    a = [next(lm_batches(100, 32, 2, seed=7)) for _ in [0]][0]
    b = [next(lm_batches(100, 32, 2, seed=7)) for _ in [0]][0]
    np.testing.assert_array_equal(a, b)
    c = next(lm_batches(100, 32, 2, seed=8))
    assert not np.array_equal(a, c)


def test_needle_stream_properties():
    it = needle_stream(500, 512, page_size=32, seed=3)
    for _ in range(5):
        s = next(it)
        assert s.tokens.shape == (512,)
        motif = s.tokens[-8:]
        pos = s.needle_page * 32
        found = False
        for off in range(32):
            if pos + off + 8 <= 512 and np.array_equal(
                    s.tokens[pos + off: pos + off + 8], motif):
                found = True
                assert s.tokens[pos + off + 8] == s.answer
                break
        assert found
