"""Sharded speculative retrieval (beyond-paper §Perf): at model-parallel=1 the
shard-local path must equal the plain FreeKV path exactly, including across
page-offload boundaries."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.core.retrieval import make_retriever
from repro.launch.mesh import make_host_mesh


def test_sharded_equals_plain_mp1():
    cfg = get_config("granite-3-8b-smoke")
    B, T, H, kv, d = 2, 96, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    key = jax.random.PRNGKey(0)
    ks = jax.random.normal(key, (B, T, kv, d), jnp.float32)
    vs = jax.random.normal(jax.random.fold_in(key, 1), (B, T, kv, d), jnp.float32)
    qlast = jax.random.normal(jax.random.fold_in(key, 2), (B, H, d))
    mesh = make_host_mesh(1)
    outs = {}
    with mesh:
        for shard in (False, True):
            fkv = FreeKVConfig(method="freekv", page_size=8, budget=48,
                               n_sink=8, n_window=8, tau=0.8,
                               sharded_retrieval=shard)
            r = make_retriever(cfg, fkv, mesh=mesh if shard else None)
            st = r.init_state(B, T + 64, jnp.float32)
            st = r.prefill(st, ks, vs, qlast)
            os_ = []
            for t in range(10):  # crosses a page boundary
                kq = jax.random.fold_in(key, 50 + t)
                q = jax.random.normal(kq, (B, H, d))
                kn = jax.random.normal(jax.random.fold_in(kq, 1), (B, kv, d))
                vn = jax.random.normal(jax.random.fold_in(kq, 2), (B, kv, d))
                o, st, info = r.decode(st, q, kn, vn)
                os_.append(np.asarray(o))
            outs[shard] = (np.stack(os_), np.asarray(st["pool"]),
                           np.asarray(st["sel_idx"]))
    np.testing.assert_allclose(outs[True][0], outs[False][0], atol=1e-5)
    np.testing.assert_array_equal(outs[True][1], outs[False][1])  # pool bit-exact
    np.testing.assert_array_equal(outs[True][2], outs[False][2])  # same selection
