"""Centroid-then-token retriever (core/centroid_index, method="centroid").

Property coverage (ISSUE 9):
  (a) whenever the candidate set (union of winning clusters' pages) covers
      the exact top-k, the centroid selection equals the exact selection —
      and with correction on the final attention output is bit-identical to
      ``freekv`` (checked per step on seeded drift traffic, plus the
      all-corrected regime where coverage is irrelevant);
  (b) the incrementally maintained index equals a full rebuild from the
      (summaries, mean snapshot) after ANY seeded sequence of
      append / offload / swap_out / swap_in events — bit-equality of
      ``cent`` / ``cent_assign`` / ``cent_count``;
  (c) tp=2 centroid selection equals tp=1 (subprocess driver with two
      forced host devices, pattern of test_sharded_serving.py), and the
      mp=1 TP wrapper is semantically invisible in-process.

Plus: kernel interpret-mode parity vs the jnp oracle, the
``retriever=`` config alias, and the sharding specs of the index leaves.

This module is pinned atomically to one CI shard (tests/conftest.py).
"""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.core import centroid_index, paging, selection

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fkv(**kw):
    base = dict(method="centroid", page_size=8, budget=64, n_sink=8,
                n_window=8, tau=0.8, centroid_count=4,
                centroid_refresh_interval=3)
    base.update(kw)
    return FreeKVConfig(**base)


def _prefill(r, cfg, key, B=2, T=160, max_len=512):
    H, kv, d = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    k = jax.random.normal(key, (B, T, kv, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 1), (B, T, kv, d),
                          jnp.float32)
    q0 = jax.random.normal(jax.random.fold_in(key, 2), (B, H, d), jnp.float32)
    return r.prefill(r.init_state(B, max_len, jnp.float32), k, v, q0), q0


def _step_inputs(cfg, key, t, B=2):
    H, kv, d = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    kq = jax.random.fold_in(key, 100 + t)
    dq = jax.random.normal(kq, (B, H, d), jnp.float32)
    kn = jax.random.normal(jax.random.fold_in(kq, 1), (B, kv, d), jnp.float32)
    vn = jax.random.normal(jax.random.fold_in(kq, 2), (B, kv, d), jnp.float32)
    return dq, kn, vn


# ---------------------------------------------------------------------------
# config plumbing
# ---------------------------------------------------------------------------
def test_retriever_alias_sets_method():
    assert FreeKVConfig(retriever="centroid").method == "centroid"
    # when both are given, the serving-facing alias wins
    assert FreeKVConfig(method="freekv", retriever="centroid").method \
        == "centroid"
    assert FreeKVConfig(method="quest").method == "quest"


def test_make_retriever_dispatch():
    from repro.core.retrieval import CentroidRetriever, make_retriever
    cfg = get_config("granite-3-8b-smoke")
    r = make_retriever(cfg, _fkv())
    assert isinstance(r, CentroidRetriever)
    assert "centroid" in __import__("repro.core.retrieval",
                                    fromlist=["METHODS"]).METHODS


# ---------------------------------------------------------------------------
# kernel parity (interpret mode vs jnp oracle)
# ---------------------------------------------------------------------------
def test_centroid_scores_kernel_parity():
    from repro.kernels import ops, ref
    cfg = get_config("granite-3-8b-smoke")
    H, kv, d = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    B, C, G = 2, 6, H // kv
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (B, kv, G, d), jnp.float32)
    lo = jax.random.normal(jax.random.fold_in(key, 1), (B, C, kv, d))
    hi = lo + jnp.abs(jax.random.normal(jax.random.fold_in(key, 2),
                                        (B, C, kv, d)))
    cent = jnp.stack([lo, hi], axis=3)
    cnt = jax.random.randint(jax.random.fold_in(key, 3), (B, C, kv), 0, 3)
    got = ops.centroid_scores(q, cent, cnt, scale=0.125, interpret=True)
    want = ref.centroid_scores_ref(q, cent, cnt, 0.125)
    assert got.shape == (B, kv, G, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # empty clusters can never win a candidate slot
    empty = np.asarray(cnt.transpose(0, 2, 1)) == 0
    assert (np.asarray(got).transpose(0, 1, 3, 2)[empty] < -1e29).all()


# ---------------------------------------------------------------------------
# (a) coverage => exact selection => bit-identical output
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1])
def test_coverage_implies_exact_and_bit_identical(seed):
    """Seeded drift traffic (heads escape correction): at every step the
    candidate set covers the exact top-k, the centroid selection equals the
    exact selection (non-softmax pooling), and the decode output is
    bit-identical to freekv."""
    from repro.core.retrieval import make_retriever
    cfg = get_config("granite-3-8b-smoke")
    fkv = _fkv(group_pool="mean_qk")
    fkv_ex = dataclasses.replace(fkv, method="freekv")
    key = jax.random.PRNGKey(seed)
    r = make_retriever(cfg, fkv)
    r2 = make_retriever(cfg, fkv_ex)
    sa, q = _prefill(r, cfg, key)
    sb, _ = _prefill(r2, cfg, key)
    B = 2
    n_uncorr = 0
    for t in range(24):
        # slow drift -> high qprev similarity -> uncorrected heads exercise
        # the speculative centroid path
        q = q + 0.05 * jax.random.normal(jax.random.fold_in(key, 10 + t),
                                         q.shape)
        _, kn, vn = _step_inputs(cfg, key, t)
        # coverage + selection-equality probe on the post-append state
        probe = r._post_append(paging.append_token(dict(sa), kn, vn))
        n_sel = probe["sel_idx"].shape[2]
        exact_idx, _ = selection.select_pages(
            cfg, fkv, q, probe["summ"], probe["length"], n_sel)
        cent_idx, cand = centroid_index.centroid_select(
            cfg, fkv, q, probe, n_sel)
        e, c = np.asarray(exact_idx), np.asarray(cand)
        for b in range(B):
            for h in range(cfg.n_kv_heads):
                want = set(e[b, h][e[b, h] >= 0].tolist())
                have = set(c[b, h][c[b, h] >= 0].tolist())
                assert want <= have, (t, b, h, want - have)
        np.testing.assert_array_equal(np.asarray(cent_idx), e)
        oa, sa, ia = r.decode(sa, q, kn, vn)
        ob, sb, _ = r2.decode(sb, q, kn, vn)
        np.testing.assert_array_equal(np.asarray(oa), np.asarray(ob))
        np.testing.assert_array_equal(np.asarray(sa["sel_idx"]),
                                      np.asarray(sb["sel_idx"]))
        n_uncorr += int((~np.asarray(ia["corrected"])).sum())
    assert n_uncorr > 0, "drift traffic never escaped correction"


@pytest.mark.parametrize("overlap", [True, False], ids=["overlap", "sync"])
@pytest.mark.parametrize("quant", ["none", "int8"])
def test_bit_identical_vs_freekv_corrected(overlap, quant):
    """All-corrected regime (random queries, cold-ish tau): correction
    routes every head to the exact scan, so the output is bit-identical to
    freekv regardless of cluster quality — mis-clustered heads are
    corrected, not lost."""
    from repro.core.retrieval import make_retriever
    cfg = get_config("granite-3-8b-smoke")
    fkv = _fkv(recall_overlap=overlap, kv_quant=quant)
    fkv_ex = dataclasses.replace(fkv, method="freekv")
    key = jax.random.PRNGKey(7)
    r = make_retriever(cfg, fkv)
    r2 = make_retriever(cfg, fkv_ex)
    sa, _ = _prefill(r, cfg, key)
    sb, _ = _prefill(r2, cfg, key)
    ncorr = 0
    for t in range(12):
        q, kn, vn = _step_inputs(cfg, key, t)
        oa, sa, ia = r.decode(sa, q, kn, vn)
        ob, sb, _ = r2.decode(sb, q, kn, vn)
        np.testing.assert_array_equal(np.asarray(oa), np.asarray(ob))
        ncorr += int(np.asarray(ia["corrected"]).sum())
    assert ncorr > 0


# ---------------------------------------------------------------------------
# (b) incremental == rebuild after any append/offload/swap sequence
# ---------------------------------------------------------------------------
def _assert_rebuild_equal(state, page_size, ctx=""):
    rb = centroid_index.rebuild(state, page_size)
    for k in ("cent", "cent_assign", "cent_count"):
        np.testing.assert_array_equal(np.asarray(rb[k]), np.asarray(state[k]),
                                      err_msg=f"{k} diverged {ctx}")


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("quant", ["none", "int8"])
def test_incremental_matches_rebuild(seed, quant):
    """Randomized op sequences: decode-append runs (crossing page-completion
    and re-center boundaries at unaligned phases), interleaved with full
    swap_out -> host numpy -> swap_in round-trips. After every op the
    incrementally maintained index leaves are bit-equal to ``rebuild``."""
    from repro.core.offload import swap_state_to_host
    from repro.core.retrieval import make_retriever
    cfg = get_config("granite-3-8b-smoke")
    fkv = _fkv(kv_quant=quant)
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    r = make_retriever(cfg, fkv)
    # unaligned prefill length: partially filled last page stays un-indexed
    T = int(rng.integers(100, 200))
    st, _ = _prefill(r, cfg, key, T=T)
    _assert_rebuild_equal(st, fkv.page_size, "after prefill")
    t = 0
    for op in range(8):
        if rng.random() < 0.3:
            # preemption swap: full host round-trip of every leaf
            host = swap_state_to_host(st)
            st = jax.tree.map(jnp.asarray, host)
            _assert_rebuild_equal(st, fkv.page_size, f"after swap #{op}")
        else:
            for _ in range(int(rng.integers(1, 12))):
                q, kn, vn = _step_inputs(cfg, key, t)
                t += 1
                _, st, _ = r.decode(st, q, kn, vn)
            _assert_rebuild_equal(st, fkv.page_size,
                                  f"after append run #{op} (t={t})")
    assert int(st["cent_count"].sum()) > 0


def test_slot_splice_preserves_index():
    """Continuous-batching slot surgery (insert/extract) moves the index
    leaves with the rest of the state; a spliced-out lane still satisfies
    the rebuild invariant."""
    from repro.core.retrieval import make_retriever
    cfg = get_config("granite-3-8b-smoke")
    fkv = _fkv()
    key = jax.random.PRNGKey(11)
    r = make_retriever(cfg, fkv)
    st, _ = _prefill(r, cfg, key, B=2)
    for t in range(5):
        q, kn, vn = _step_inputs(cfg, key, t)
        _, st, _ = r.decode(st, q, kn, vn)
    lane = jax.tree.map(lambda x: paging.slot_read_leaf(x, 1), st)
    _assert_rebuild_equal(lane, fkv.page_size, "extracted lane")


# ---------------------------------------------------------------------------
# (c) tensor parallelism
# ---------------------------------------------------------------------------
def test_tp_wrapper_mp1_bit_identical():
    """A 1-shard TP wrapper around the centroid retriever is semantically
    invisible (and jits with the cand_pages counter psum)."""
    from repro.core.retrieval import make_retriever
    from repro.core.sharded_retrieval import TPGroupShardedRetriever
    from repro.launch.mesh import make_tp_mesh
    cfg = get_config("granite-3-8b-smoke")
    fkv = _fkv()
    mesh = make_tp_mesh(1)
    r_tp = make_retriever(cfg, dataclasses.replace(fkv, tp_serving=True),
                          mesh=mesh)
    assert isinstance(r_tp, TPGroupShardedRetriever)
    r_pl = make_retriever(cfg, fkv)
    key = jax.random.PRNGKey(0)
    st_tp, _ = _prefill(r_tp, cfg, key, T=64, max_len=160)
    st_pl, _ = _prefill(r_pl, cfg, key, T=64, max_len=160)

    def _jit_decode(r):
        def f(s, q, kn, vn):
            o, st, info = r.decode(s, q, kn, vn)
            return o, st, {k: v for k, v in info.items()
                           if not isinstance(v, str)}
        return jax.jit(f)

    dec_tp, dec_pl = _jit_decode(r_tp), _jit_decode(r_pl)
    for t in range(10):
        q, kn, vn = _step_inputs(cfg, key, t)
        o_tp, st_tp, i_tp = dec_tp(st_tp, q, kn, vn)
        o_pl, st_pl, i_pl = dec_pl(st_pl, q, kn, vn)
        np.testing.assert_array_equal(np.asarray(o_tp), np.asarray(o_pl))
        np.testing.assert_array_equal(np.asarray(st_tp["cent_assign"]),
                                      np.asarray(st_pl["cent_assign"]))
        np.testing.assert_array_equal(np.asarray(i_tp["cand_pages"]),
                                      np.asarray(i_pl["cand_pages"]))


def test_tp_state_specs_shard_centroid_leaves():
    """The index leaves shard over the KV-head dim (axis 2, like summ)."""
    from jax.sharding import PartitionSpec as P
    from repro.core.sharded_retrieval import tp_state_specs
    from repro.core.retrieval import make_retriever
    from repro.launch.mesh import make_tp_mesh
    cfg = get_config("granite-3-8b-smoke")
    fkv = _fkv()
    mesh = make_tp_mesh(1)
    r = make_retriever(cfg, fkv)
    st = jax.eval_shape(lambda: r.init_state(2, 96, jnp.float32))
    specs = tp_state_specs(cfg, mesh, st)
    assert specs["cent"] == P(None, None, "model", None, None)
    assert specs["cent_mean"] == P(None, None, "model", None)
    assert specs["cent_assign"] == P(None, None, "model")
    assert specs["cent_count"] == P(None, None, "model")


@pytest.fixture(scope="session")
def tp2_report(tmp_path_factory):
    out = tmp_path_factory.mktemp("tp_centroid") / "report.json"
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    subprocess.run([sys.executable, os.path.abspath(__file__), str(out)],
                   check=True, timeout=1500, env=env, cwd=REPO)
    return json.loads(out.read_text())


@pytest.mark.parametrize("overlap", [True, False], ids=["overlap", "sync"])
@pytest.mark.parametrize("quant", ["none", "int8"])
def test_tp2_centroid_equals_tp1(tp2_report, overlap, quant):
    r = tp2_report[f"overlap={overlap}/quant={quant}"]
    assert r["bit_identical"] is True, "tp=2 centroid output diverged"
    assert r["sel_idx_equal"] is True
    assert r["cand_pages_equal"] is True
    assert r["rebuild_ok"] is True


def _driver(out_path):
    """tp=2 vs tp=1 centroid retriever on 2 forced host devices."""
    from repro.core.retrieval import make_retriever
    from repro.launch.mesh import make_tp_mesh
    assert len(jax.devices()) >= 2, jax.devices()
    cfg = get_config("granite-3-8b-smoke")
    mesh = make_tp_mesh(2)
    key = jax.random.PRNGKey(5)
    report = {}
    for overlap in (True, False):
        for quant in ("none", "int8"):
            fkv = _fkv(recall_overlap=overlap, kv_quant=quant)
            r2 = make_retriever(
                cfg, dataclasses.replace(fkv, tp_serving=True), mesh=mesh)
            r1 = make_retriever(cfg, fkv)
            s2, q = _prefill(r2, cfg, key, T=64, max_len=160)
            s1, _ = _prefill(r1, cfg, key, T=64, max_len=160)

            def dec(r):
                def f(s, q, kn, vn):
                    o, st, info = r.decode(s, q, kn, vn)
                    return o, st, {k: v for k, v in info.items()
                                   if not isinstance(v, str)}
                return jax.jit(f)

            d2, d1 = dec(r2), dec(r1)
            bit = sel_eq = cand_eq = True
            for t in range(10):
                q = q + 0.05 * jax.random.normal(
                    jax.random.fold_in(key, 10 + t), q.shape)
                _, kn, vn = _step_inputs(cfg, key, t)
                o2, s2, i2 = d2(s2, q, kn, vn)
                o1, s1, i1 = d1(s1, q, kn, vn)
                bit &= bool((np.asarray(o2) == np.asarray(o1)).all())
                sel_eq &= bool((np.asarray(s2["sel_idx"])
                                == np.asarray(s1["sel_idx"])).all())
                cand_eq &= bool((np.asarray(i2["cand_pages"])
                                 == np.asarray(i1["cand_pages"])).all())
            rb = centroid_index.rebuild(
                jax.tree.map(np.asarray, s2), fkv.page_size)
            rebuild_ok = all(
                bool((np.asarray(rb[k]) == np.asarray(s2[k])).all())
                for k in ("cent", "cent_assign", "cent_count"))
            report[f"overlap={overlap}/quant={quant}"] = {
                "bit_identical": bit, "sel_idx_equal": sel_eq,
                "cand_pages_equal": cand_eq, "rebuild_ok": rebuild_ok}
    with open(out_path, "w") as f:
        json.dump(report, f)


if __name__ == "__main__":
    _driver(sys.argv[1])
