"""Overlapped double-buffered recall pipeline (core/recall_pipeline) +
chunked recall kernel: bit-identity vs the synchronous path, correction
top-up semantics, and ring-buffer reuse across continuous-batching slot
turnover."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.core import recall
from repro.core.recall_pipeline import (RecallExecutor, RecallFlightTracker,
                                        match_resident)
from repro.core.retrieval import make_retriever

KEY = jax.random.PRNGKey(0)

FKV_BASE = dict(page_size=8, budget=48, n_sink=8, n_window=8, tau=0.8,
                svd_rank=32)


def _setup(cfg, fkv, B=2, T=96, max_len=160):
    kv, d, H = cfg.n_kv_heads, cfg.d_head, cfg.n_heads
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, kv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, T, kv, d))
    q_last = jax.random.normal(jax.random.fold_in(KEY, 3), (B, H, d))
    r = make_retriever(cfg, fkv)
    st = r.init_state(B, max_len, jnp.float32)
    return r, r.prefill(st, k, v, q_last)


def _query_schedule(cfg, B, steps):
    """Mix of fresh (correcting) and near-identical (reusing) queries."""
    H, d = cfg.n_heads, cfg.d_head
    qs, qprev = [], None
    for t in range(steps):
        kq = jax.random.fold_in(KEY, 100 + t)
        if t % 3 == 2 and qprev is not None:      # near-identical -> reuse
            q = qprev + 1e-3 * jax.random.normal(kq, (B, H, d))
        else:                                     # jump -> correction
            q = jax.random.normal(kq, (B, H, d))
        qprev = q
        kn = jax.random.normal(jax.random.fold_in(kq, 1),
                               (B, cfg.n_kv_heads, d))
        vn = jax.random.normal(jax.random.fold_in(kq, 2),
                               (B, cfg.n_kv_heads, d))
        qs.append((q, kn, vn))
    return qs


# ---------------------------------------------------------------------------
# bit-identity: pipeline on/off
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", ["freekv", "shadowkv"])
def test_pipeline_bit_identical(smoke_cfg, method):
    """THE pipeline invariant: greedy attention outputs are bit-identical
    with overlapped recall on or off — only the transfer schedule moves."""
    cfg = smoke_cfg
    outs = {}
    for overlap in (False, True):
        fkv = FreeKVConfig(method=method, recall_overlap=overlap, **FKV_BASE)
        r, st = _setup(cfg, fkv)
        os_ = []
        for q, kn, vn in _query_schedule(cfg, 2, 10):
            o, st, _ = r.decode(st, q, kn, vn)
            os_.append(np.asarray(o))
        outs[overlap] = os_
    for a, b in zip(outs[True], outs[False]):
        np.testing.assert_array_equal(a, b)


def test_pipeline_reduces_blocking_traffic(smoke_cfg):
    """Under high query similarity, most selected pages are already resident
    in the double buffer: the pipeline's critical-path (sync) transfer must
    be strictly below the synchronous path's, with the difference covered by
    buffer reuse + staged (overlapped) pages."""
    cfg = smoke_cfg
    tot = {}
    for overlap in (False, True):
        fkv = FreeKVConfig(method="freekv", recall_overlap=overlap, **FKV_BASE)
        r, st = _setup(cfg, fkv)
        agg = {"sync_pages": 0, "async_pages": 0, "reused_pages": 0}
        for q, kn, vn in _query_schedule(cfg, 2, 10):
            _, st, info = r.decode(st, q, kn, vn)
            for k in agg:
                agg[k] += int(np.asarray(info[k]).sum())
        tot[overlap] = agg
    assert tot[True]["sync_pages"] < tot[False]["sync_pages"]
    assert tot[True]["reused_pages"] > 0


def test_correction_topup_only_for_corrected_heads(smoke_cfg):
    """A query jump corrects every head -> non-resident fresh pages transfer
    on the critical path (top-up); a near-identical query corrects nothing
    -> the step's blocking transfer is zero (all reuse/staged)."""
    cfg = smoke_cfg
    fkv = FreeKVConfig(method="freekv", recall_overlap=True, **FKV_BASE)
    r, st = _setup(cfg, fkv)
    q, kn, vn = _query_schedule(cfg, 2, 1)[0]
    _, st, info = r.decode(st, q, kn, vn)     # cold qprev -> all corrected
    assert bool(np.asarray(info["corrected"]).all())
    # identical query: similarity 1 -> no corrected heads -> no blocking I/O
    _, st, info2 = r.decode(st, q, kn, vn)
    assert not bool(np.asarray(info2["corrected"]).any())
    assert int(np.asarray(info2["sync_pages"]).sum()) == 0


def test_executor_merge_matches_synchronous_semantics(smoke_cfg):
    """merged == where(corr, fresh, stale) and staged == fresh, bit-exactly,
    for an arbitrary correction mask."""
    cfg = smoke_cfg
    B, kv, n_pages, n_sel, p, d = 2, cfg.n_kv_heads, 10, 4, 8, cfg.d_head
    key = jax.random.fold_in(KEY, 42)
    pool = jax.random.normal(key, (B, n_pages, kv, 2, p, d))
    prev_idx = jax.random.randint(jax.random.fold_in(key, 1),
                                  (B, kv, n_sel), -1, n_pages).astype(jnp.int32)
    new_idx = jax.random.randint(jax.random.fold_in(key, 2),
                                 (B, kv, n_sel), -1, n_pages).astype(jnp.int32)
    prev_k, prev_v = recall.recall_pages(pool, prev_idx)
    need = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.5, (B, kv))
    ex = RecallExecutor()
    pr = ex.step(pool, new_idx, prev_idx, prev_k, prev_v, need)
    fresh_k, fresh_v = recall.recall_pages(pool, new_idx)
    m = need[:, :, None, None, None]
    np.testing.assert_array_equal(np.asarray(pr.staged_k), np.asarray(fresh_k))
    np.testing.assert_array_equal(np.asarray(pr.staged_v), np.asarray(fresh_v))
    np.testing.assert_array_equal(
        np.asarray(pr.use_k), np.asarray(jnp.where(m, fresh_k, prev_k)))
    np.testing.assert_array_equal(
        np.asarray(pr.use_v), np.asarray(jnp.where(m, fresh_v, prev_v)))
    # every fresh valid page is accounted exactly once: reuse, top-up or stage
    hit, _ = match_resident(new_idx, prev_idx)
    total = int((new_idx >= 0).sum())
    booked = int(np.asarray(pr.topup_blocks).sum()
                 + np.asarray(pr.staged_blocks).sum()
                 + np.asarray(hit & (new_idx >= 0)).sum())
    assert booked == total


# ---------------------------------------------------------------------------
# chunked double-buffered kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_sel,chunk", [(5, 2), (7, 3), (6, 6), (1, 8)])
def test_chunked_kernel_parity(n_sel, chunk):
    """The 2-deep VMEM-ring kernel honors the (pool, idx) -> (k, v) contract
    for any chunking, including non-divisible tails, in interpret mode."""
    from repro.kernels import ops
    B, n_pages, kv, p, d = 2, 12, 3, 8, 16
    pool = jax.random.normal(KEY, (B, n_pages, kv, 2, p, d))
    idx = jax.random.randint(jax.random.fold_in(KEY, n_sel),
                             (B, kv, n_sel), -2, n_pages).astype(jnp.int32)
    k, v = ops.recall_gather(pool, idx, chunk=chunk)
    kr, vr = recall.recall_pages(pool, idx)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(kr))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))
    vo = ops.recall_values(pool, idx, chunk=chunk)
    np.testing.assert_array_equal(
        np.asarray(vo), np.asarray(recall.recall_values_only(pool, idx)))


def test_kernel_pipeline_matches_jnp_pipeline(smoke_cfg):
    """use_kernels routes the executor through the chunked Pallas kernel;
    outputs must match the jnp gather bit-for-bit (pure data movement)."""
    cfg = smoke_cfg
    outs = {}
    for use_k in (False, True):
        fkv = FreeKVConfig(method="freekv", recall_overlap=True,
                           use_kernels=use_k, recall_chunk_pages=2, **FKV_BASE)
        r, st = _setup(cfg, fkv)
        q, kn, vn = _query_schedule(cfg, 2, 1)[0]
        o, st, _ = r.decode(st, q, kn, vn)
        outs[use_k] = np.asarray(o)
    np.testing.assert_allclose(outs[True], outs[False], atol=1e-5)


# ---------------------------------------------------------------------------
# engine: ring-buffer reuse across continuous-batching slot turnover
# ---------------------------------------------------------------------------
def _engine(cfg, params, fkv, batch_size=2):
    from repro.serving.engine import ServeEngine
    from repro.serving.sampling import SamplerConfig
    return ServeEngine(cfg, fkv, params, max_len=160, batch_size=batch_size,
                       sampler=SamplerConfig(temperature=0.0))


def test_engine_turnover_bit_identical_and_tracks_in_flight():
    """Continuous batching with slot turnover (more requests than slots):
    greedy outputs are bit-identical with the pipeline on/off, the per-slot
    double buffers survive slot splices, and buffers abandoned at turnover
    are accounted as dropped in-flight transfer."""
    cfg = get_config("smollm-360m-smoke")
    from repro.models.model import init_params
    from repro.serving.engine import Request
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
               for _ in range(4)]
    toks = {}
    ems = {}
    trackers = {}
    for overlap in (False, True):
        fkv = FreeKVConfig(method="freekv", recall_overlap=overlap,
                           **FKV_BASE)
        eng = _engine(cfg, params, fkv)
        reqs = [Request(uid=i, tokens=p, max_new_tokens=4 + 3 * (i % 2))
                for i, p in enumerate(prompts)]     # staggered -> turnover
        outs = eng.generate(reqs)
        toks[overlap] = [o.tokens for o in outs]
        ems[overlap] = eng.last_metrics
        trackers[overlap] = eng.recall_tracker
    assert toks[True] == toks[False]
    em, tr = ems[True], trackers[True]
    # the scheduler fed the engine-owned tracker every step (live wiring:
    # random prompts guarantee corrections, hence nonzero blocking top-up)
    assert em.sync_pages > 0
    assert tr.topup_pages == em.sync_pages
    assert tr.staged_pages == em.async_pages
    assert tr.reused_pages == em.reused_pages
    # 4 finishes over 2 slots: each turnover abandons whatever that slot
    # staged on its final step; nothing stays in flight after the run
    # drains, and drops can never exceed what was staged
    assert em.dropped_pages == tr.dropped_pages <= tr.staged_pages
    assert all(tr.in_flight(s) is None for s in (0, 1))
    # synchronous mode must expose at least as many blocking bytes
    assert (ems[False].exposed_transfer_bytes
            >= ems[True].exposed_transfer_bytes)


def test_flight_tracker_accounting():
    tr = RecallFlightTracker()
    tr.note_step(0, staged=10, topup=2, reused=1)
    tr.note_step(1, staged=4, topup=0, reused=0)
    tr.note_step(0, staged=6, topup=1, reused=2)   # slot 0's 10 consumed
    tr.invalidate(0)                               # slot 0 turns over: 6 lost
    tr.invalidate(0)                               # idempotent
    assert tr.dropped_pages == 6
    assert tr.in_flight(1) == 4
    s = tr.summary()
    assert s["staged_pages"] == 20 and s["topup_pages"] == 3
    assert s["reused_pages"] == 3
    assert 0.0 < s["hidden_fraction"] < 1.0
