"""Core FreeKV invariants + baseline retriever behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.core.retrieval import make_retriever, METHODS

KEY = jax.random.PRNGKey(0)


def _setup(cfg, fkv, B=2, T=96, max_len=128, dtype=jnp.float32):
    kv, d, H = cfg.n_kv_heads, cfg.d_head, cfg.n_heads
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, kv, d), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, T, kv, d), dtype)
    q_last = jax.random.normal(jax.random.fold_in(KEY, 3), (B, H, d), dtype)
    r = make_retriever(cfg, fkv)
    st = r.init_state(B, max_len, dtype)
    st = r.prefill(st, k, v, q_last)
    return r, st, (k, v, q_last)


def _decode_inputs(cfg, B, t):
    kq = jax.random.fold_in(KEY, 100 + t)
    q = jax.random.normal(kq, (B, cfg.n_heads, cfg.d_head))
    kn = jax.random.normal(jax.random.fold_in(kq, 1), (B, cfg.n_kv_heads, cfg.d_head))
    vn = jax.random.normal(jax.random.fold_in(kq, 2), (B, cfg.n_kv_heads, cfg.d_head))
    return q, kn, vn


def test_freekv_full_budget_exact(smoke_cfg):
    """With budget >= context, FreeKV attention == exact full attention.

    This is THE correctness invariant: the sink/window/selected regions
    partition the context exactly (no double counting, no gaps)."""
    cfg = smoke_cfg
    T = 96
    fkv = FreeKVConfig(method="freekv", page_size=8, budget=T + 64, n_sink=16,
                       n_window=16, tau=0.8)
    r, st, _ = _setup(cfg, fkv, T=T)
    rf, stf, _ = _setup(cfg, FreeKVConfig(method="full"), T=T)
    for t in range(20):
        q, kn, vn = _decode_inputs(cfg, 2, t)
        o, st, _ = r.decode(st, q, kn, vn)
        of, stf, _ = rf.decode(stf, q, kn, vn)
        np.testing.assert_allclose(np.asarray(o), np.asarray(of), atol=2e-5)


def test_freekv_budget_subset_finite(smoke_cfg, small_fkv):
    r, st, _ = _setup(smoke_cfg, small_fkv)
    for t in range(10):
        q, kn, vn = _decode_inputs(smoke_cfg, 2, t)
        o, st, info = r.decode(st, q, kn, vn)
        assert jnp.isfinite(o).all()
        assert info["corrected"].shape == (2, smoke_cfg.n_kv_heads)
    # lengths advance
    assert int(st["length"][0]) == 96 + 10


@pytest.mark.parametrize("method", METHODS)
def test_all_methods_run(smoke_cfg, method):
    fkv = FreeKVConfig(method=method, page_size=8, budget=48, n_sink=8,
                       n_window=8, svd_rank=32)
    r, st, _ = _setup(smoke_cfg, fkv)
    for t in range(4):
        q, kn, vn = _decode_inputs(smoke_cfg, 2, t)
        o, st, info = r.decode(st, q, kn, vn, q_proxy=q)
        assert o.shape == (2, smoke_cfg.n_heads, smoke_cfg.d_head)
        assert jnp.isfinite(o).all(), method


def test_kernel_path_matches_jnp(smoke_cfg):
    outs = {}
    for use_k in (False, True):
        fkv = FreeKVConfig(method="freekv", page_size=8, budget=48, n_sink=8,
                           n_window=8, tau=0.8, use_kernels=use_k)
        r, st, _ = _setup(smoke_cfg, fkv)
        q, kn, vn = _decode_inputs(smoke_cfg, 2, 0)
        o, st, _ = r.decode(st, q, kn, vn)
        outs[use_k] = np.asarray(o)
    np.testing.assert_allclose(outs[True], outs[False], atol=1e-5)


def test_correction_uses_fresh_pages_when_query_jumps(smoke_cfg):
    """A step whose query is orthogonal to the previous one must correct
    (C_i ~ 0 < tau) and therefore attend with freshly selected pages."""
    cfg = smoke_cfg
    fkv = FreeKVConfig(method="freekv", page_size=8, budget=48, n_sink=8,
                       n_window=8, tau=0.8)
    r, st, (k, v, q_last) = _setup(cfg, fkv)
    q, kn, vn = _decode_inputs(cfg, 2, 0)
    o, st, info = r.decode(st, q, kn, vn)
    assert bool(info["corrected"].all())  # random qprev -> corrected
    # identical query next step -> similarity 1 -> no correction
    o2, st2, info2 = r.decode(st, q, kn, vn)
    assert not bool(info2["corrected"].any())


def test_speculative_reuse_matches_arkvale_when_similar(smoke_cfg):
    """If q_i == q_{i-1}, FreeKV's stale pages equal fresh selection, so
    speculative reuse loses nothing vs blocking (ArkVale-style) retrieval."""
    cfg = smoke_cfg
    base = dict(page_size=8, budget=48, n_sink=8, n_window=8, tau=0.8)
    rf, stf, _ = _setup(cfg, FreeKVConfig(method="freekv", **base))
    ra, sta, _ = _setup(cfg, FreeKVConfig(method="arkvale", **base))
    q, kn, vn = _decode_inputs(cfg, 2, 0)
    # step 1 (both correct/recall fresh)
    of1, stf, _ = rf.decode(stf, q, kn, vn)
    oa1, sta, _ = ra.decode(sta, q, kn, vn)
    np.testing.assert_allclose(np.asarray(of1), np.asarray(oa1), atol=2e-5)
    # step 2 with the SAME query: FreeKV reuses, ArkVale re-selects; the
    # selection changed by at most the newly completed pages
    q2 = q + 1e-4 * jax.random.normal(jax.random.fold_in(KEY, 7), q.shape)
    of2, stf, i2 = rf.decode(stf, q2, kn, vn)
    oa2, sta, _ = ra.decode(sta, q2, kn, vn)
    assert not bool(i2["corrected"].any())
    np.testing.assert_allclose(np.asarray(of2), np.asarray(oa2), atol=2e-4)


def test_shadowkv_full_rank_close_to_full(smoke_cfg):
    """ShadowKV with rank == d_head reconstructs keys exactly; with a large
    budget it must match the full-cache oracle."""
    cfg = smoke_cfg
    T = 96
    fkv = FreeKVConfig(method="shadowkv", page_size=8, budget=T + 64,
                       n_sink=16, n_window=16, svd_rank=cfg.d_head)
    r, st, _ = _setup(cfg, fkv, T=T)
    rf, stf, _ = _setup(cfg, FreeKVConfig(method="full"), T=T)
    q, kn, vn = _decode_inputs(cfg, 2, 0)
    o, st, _ = r.decode(st, q, kn, vn)
    of, stf, _ = rf.decode(stf, q, kn, vn)
    np.testing.assert_allclose(np.asarray(o), np.asarray(of), atol=5e-4)


def test_streaming_ignores_middle(smoke_cfg):
    """Streaming output is invariant to middle-context K/V (by construction)."""
    cfg = smoke_cfg
    fkv = FreeKVConfig(method="streaming", page_size=8, budget=32, n_sink=8,
                       n_window=8)
    B, T = 2, 96
    kv, d = cfg.n_kv_heads, cfg.d_head
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, kv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, T, kv, d))
    k2 = k.at[:, 20:60].set(jax.random.normal(jax.random.fold_in(KEY, 9),
                                              (B, 40, kv, d)))
    q_last = jax.random.normal(jax.random.fold_in(KEY, 3), (B, cfg.n_heads, d))
    r = make_retriever(cfg, fkv)
    outs = []
    for kk in (k, k2):
        st = r.init_state(B, 128, jnp.float32)
        st = r.prefill(st, kk, v, q_last)
        q, kn, vn = _decode_inputs(cfg, B, 0)
        o, st, _ = r.decode(st, q, kn, vn)
        outs.append(np.asarray(o))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-6)


def test_top_p_dynamic_budget(smoke_cfg):
    """top_p=1 ~ static top-k; small top_p selects fewer pages, never zero."""
    import jax
    import jax.numpy as jnp
    from repro.core import selection
    cfg = smoke_cfg
    key = jax.random.PRNGKey(3)
    B, H, d, n_pages = 2, cfg.n_heads, cfg.d_head, 16
    q = jax.random.normal(key, (B, H, d)) * 3
    summ = jax.random.normal(jax.random.fold_in(key, 1),
                             (B, n_pages, cfg.n_kv_heads, 2, d))
    length = jnp.array([16 * 8, 16 * 8])
    base = dict(method="freekv", page_size=8, budget=10 ** 5, n_sink=8,
                n_window=8)
    idx_full, _ = selection.select_pages(
        cfg, FreeKVConfig(**base), q, summ, length, 8)
    idx_p, _ = selection.select_pages(
        cfg, FreeKVConfig(**base, select_top_p=0.5), q, summ, length, 8)
    n_full = int((idx_full >= 0).sum())
    n_p = int((idx_p >= 0).sum())
    assert 0 < n_p <= n_full
    # kept pages are a prefix of the full top-k ranking
    import numpy as np
    a, b = np.asarray(idx_p), np.asarray(idx_full)
    for bi in range(B):
        for h in range(cfg.n_kv_heads):
            kept = a[bi, h][a[bi, h] >= 0]
            np.testing.assert_array_equal(kept, b[bi, h][: len(kept)])


def test_host_offload_placement(smoke_cfg, small_fkv):
    """offload='host' places the pool in pinned_host memory (when supported)
    and decode still runs (XLA inserts the transfers)."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.core.offload import place_decode_state, pool_bytes
    fkv = dataclasses.replace(small_fkv, offload="host")
    r = make_retriever(smoke_cfg, fkv)
    st = r.init_state(2, 128, jnp.float32)
    k = jax.random.normal(KEY, (2, 96, smoke_cfg.n_kv_heads, smoke_cfg.d_head))
    v = jax.random.normal(jax.random.fold_in(KEY, 1), k.shape)
    q_last = jax.random.normal(jax.random.fold_in(KEY, 2),
                               (2, smoke_cfg.n_heads, smoke_cfg.d_head))
    st = r.prefill(st, k, v, q_last)
    st = place_decode_state(st, fkv)
    from repro.core.offload import host_memory_kind
    kinds = {getattr(st["pool"].sharding, "memory_kind", None)}
    assert kinds <= {host_memory_kind(), None}
    assert pool_bytes(st) > 0
    q, kn, vn = _decode_inputs(smoke_cfg, 2, 0)
    try:
        o, st2, _ = r.decode(st, q, kn, vn)
    except ValueError as e:          # backend rejects compute on host buffers
        if "memor" in str(e).lower():
            pytest.skip("host-memory compute unsupported on this backend")
        raise
    assert jnp.isfinite(o).all()
