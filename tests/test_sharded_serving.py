"""Tensor-parallel serving (ServeEngine(tp=2), KV-head-group sharding).

Multi-device coverage runs in ONE subprocess with two forced XLA host
devices (``--xla_force_host_platform_device_count`` must be set before jax
initializes, so it cannot run in the main pytest process); the driver at the
bottom of this file executes every scenario and writes a JSON report that a
session-scoped fixture loads once. Assertions:

  * tp=2 greedy outputs are BIT-IDENTICAL to tp=1 on mixed-length
    continuous-batching traffic — recall_overlap on and off, kv_quant none
    and int8 — and the global transfer counters match exactly;
  * the radix-trie prefix cache works under TP (hits on shared prefixes,
    outputs still bit-identical to tp=1 with the same cache config);
  * RecallFlightTracker accounting holds per shard: each shard moves 1/tp of
    every transfer class, including staged buffers dropped at slot turnover;
  * the quantized int8 pool round-trips bit-exactly through the per-shard
    recall (TP wrapper vs the plain single-device dequant gather).

The mp=1 wrapper-identity tests run in-process on the default single device:
a 1-shard mesh must be semantically invisible.
"""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import FreeKVConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# in-process: 1-shard TP wrapper is exactly the plain retriever
# ---------------------------------------------------------------------------
def _mp1_mesh():
    from repro.launch.mesh import make_tp_mesh
    return make_tp_mesh(1)


@pytest.mark.parametrize("kv_quant", ["none", "int8"])
def test_tp_wrapper_mp1_bit_identical(kv_quant):
    from repro.core.retrieval import make_retriever
    from repro.core.sharded_retrieval import TPGroupShardedRetriever
    cfg = get_config("granite-3-8b-smoke")
    fkv = FreeKVConfig(method="freekv", page_size=8, budget=48, n_sink=8,
                       n_window=8, tau=0.8, kv_quant=kv_quant)
    mesh = _mp1_mesh()
    r_tp = make_retriever(cfg, dataclasses.replace(fkv, tp_serving=True),
                          mesh=mesh)
    assert isinstance(r_tp, TPGroupShardedRetriever)
    r_pl = make_retriever(cfg, fkv)

    B, T, H, kv, d = 2, 64, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    key = jax.random.PRNGKey(0)
    ks = jax.random.normal(key, (B, T, kv, d), jnp.float32)
    vs = jax.random.normal(jax.random.fold_in(key, 1), (B, T, kv, d),
                           jnp.float32)
    q0 = jax.random.normal(jax.random.fold_in(key, 2), (B, H, d))
    st_tp = r_tp.prefill(r_tp.init_state(B, T + 32, jnp.float32), ks, vs, q0)
    st_pl = r_pl.prefill(r_pl.init_state(B, T + 32, jnp.float32), ks, vs, q0)
    def _jit_decode(r):
        def f(s, q, kn, vn):
            o, st, info = r.decode(s, q, kn, vn)
            # info carries a static "granularity" string; keep array leaves
            return o, st, {k: v for k, v in info.items()
                           if not isinstance(v, str)}
        return jax.jit(f)

    dec_tp = _jit_decode(r_tp)
    dec_pl = _jit_decode(r_pl)
    for t in range(10):                     # crosses a page-offload boundary
        kq = jax.random.fold_in(key, 100 + t)
        q = jax.random.normal(kq, (B, H, d))
        kn = jax.random.normal(jax.random.fold_in(kq, 1), (B, kv, d))
        vn = jax.random.normal(jax.random.fold_in(kq, 2), (B, kv, d))
        o_tp, st_tp, i_tp = dec_tp(st_tp, q, kn, vn)
        o_pl, st_pl, i_pl = dec_pl(st_pl, q, kn, vn)
        np.testing.assert_array_equal(np.asarray(o_tp), np.asarray(o_pl))
        np.testing.assert_array_equal(np.asarray(st_tp["sel_idx"]),
                                      np.asarray(st_pl["sel_idx"]))
        np.testing.assert_array_equal(np.asarray(i_tp["sync_pages"]),
                                      np.asarray(i_pl["sync_pages"]))
    np.testing.assert_array_equal(np.asarray(st_tp["pool"]),
                                  np.asarray(st_pl["pool"]))


def test_tp_state_specs_shard_kv_dims():
    """Every KV-headed leaf gets 'model' on its KV-head (or q-head) axis;
    replicated leaves (positions, lengths) get none."""
    from jax.sharding import PartitionSpec as P
    from repro.core import paging
    from repro.core.sharded_retrieval import tp_state_specs
    cfg = get_config("granite-3-8b-smoke")
    fkv = FreeKVConfig(method="freekv", page_size=8, budget=48, n_sink=8,
                       n_window=8, kv_quant="int8")
    mesh = _mp1_mesh()
    st = jax.eval_shape(
        lambda: paging.init_kv_state(cfg, fkv, 2, 96, jnp.float32))
    specs = tp_state_specs(cfg, mesh, st)
    assert specs["pool"] == P(None, None, "model", None, None, None)
    assert specs["pool_scale"] == P(None, None, "model", None, None)
    assert specs["summ"] == P(None, None, "model", None, None)
    assert specs["sel_k"] == P(None, "model", None, None, None)
    assert specs["sel_idx"] == P(None, "model", None)
    assert specs["win_k"] == P(None, None, "model", None)
    assert specs["qprev"] == P(None, "model", None)
    assert specs["win_pos"] == P(None, None)
    assert specs["length"] == P(None)


def test_engine_rejects_bad_tp():
    from repro.models.model import init_params
    from repro.serving.engine import ServeEngine
    cfg = get_config("granite-3-8b-smoke")     # n_kv_heads=2
    fkv = FreeKVConfig(method="freekv", page_size=8, budget=48, n_sink=8,
                       n_window=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(AssertionError):
        ServeEngine(cfg, fkv, params, max_len=96, batch_size=1, tp=3)
    with pytest.raises(AssertionError):
        ServeEngine(cfg, dataclasses.replace(fkv, sharded_retrieval=True),
                    params, max_len=96, batch_size=1, tp=2)


# ---------------------------------------------------------------------------
# multi-device scenarios: one subprocess, many assertions
# ---------------------------------------------------------------------------
@pytest.fixture(scope="session")
def tp_report(tmp_path_factory):
    out = tmp_path_factory.mktemp("tp_serving") / "report.json"
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    subprocess.run([sys.executable, os.path.abspath(__file__), str(out)],
                   check=True, timeout=1500, env=env, cwd=REPO)
    return json.loads(out.read_text())


@pytest.mark.parametrize("overlap", [True, False], ids=["overlap", "sync"])
@pytest.mark.parametrize("quant", ["none", "int8"])
def test_tp2_bit_identical_mixed_traffic(tp_report, overlap, quant):
    r = tp_report[f"traffic/overlap={overlap}/quant={quant}"]
    assert r["tp1_tokens"] == r["tp2_tokens"], \
        "tp=2 greedy outputs diverged from tp=1"
    # global transfer counters are exact integers -> must match across tp
    # (canonical location: summary()["recall_overlap"])
    for k in ("exposed_bytes", "hidden_bytes"):
        assert (r["tp1_summary"]["recall_overlap"][k]
                == r["tp2_summary"]["recall_overlap"][k]), k
    assert r["tp2_summary"]["tp"]["tp"] == 2


@pytest.mark.parametrize("quant", ["none", "int8"])
def test_tp2_per_shard_flight_accounting(tp_report, quant):
    """Each shard owns exactly 1/tp of every transfer class — hidden,
    exposed, and staged buffers dropped in flight at slot turnover."""
    r = tp_report[f"traffic/overlap=True/quant={quant}"]
    s2 = r["tp2_summary"]
    per = s2["tp"]["per_shard_transfer_bytes"]
    ro = s2["recall_overlap"]
    assert per["sync"] * 2 == pytest.approx(ro["exposed_bytes"])
    assert per["async"] * 2 == pytest.approx(ro["hidden_bytes"])
    assert per["dropped"] * 2 == pytest.approx(ro["dropped_in_flight_bytes"])
    # dropped-in-flight accounting itself is tp-invariant
    s1 = r["tp1_summary"]
    assert s1["recall_overlap"]["dropped_in_flight_bytes"] == \
        pytest.approx(ro["dropped_in_flight_bytes"])


def test_tp2_prefix_cache_hits(tp_report):
    r = tp_report["prefix_cache"]
    assert r["tp1_tokens"] == r["tp2_tokens"], \
        "prefix-cached tp=2 outputs diverged from tp=1"
    assert r["tp2_hit_tokens"] > 0, "no prefix-cache hits under TP"
    assert r["tp2_hit_tokens"] == r["tp1_hit_tokens"]
    # cached engine agrees with the cold engine of the same tp
    assert r["tp2_tokens"] == r["tp2_cold_tokens"]


def test_tp2_quant_pool_roundtrip(tp_report):
    """int8 pool content recalled per shard is bit-equal to the plain
    single-device dequant gather, and within quantization error of fp."""
    r = tp_report["quant_roundtrip"]
    assert r["bit_equal_vs_plain"] is True
    assert 0.0 < r["max_abs_err_vs_fp"] < 0.1
    assert r["sel_idx_equal"] is True


def test_tp2_static_scheduler_bit_identical(tp_report):
    r = tp_report["static"]
    assert r["tp1_tokens"] == r["tp2_tokens"]


# ---------------------------------------------------------------------------
# subprocess driver (2 forced host devices)
# ---------------------------------------------------------------------------
def _mixed_requests(cfg, rng, n=6):
    from repro.serving.engine import Request
    lens = [40, 72, 56, 88, 48, 64][:n]
    return [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        size=L).astype(np.int32),
                    max_new_tokens=5 + (i % 3))
            for i, L in enumerate(lens)]


def _summary(eng):
    return eng.last_metrics.summary()


def _driver(out_path):
    from repro.models.model import init_params
    from repro.serving.engine import Request, ServeEngine
    assert len(jax.devices()) >= 2, jax.devices()
    cfg = get_config("granite-3-8b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = _mixed_requests(cfg, rng)
    report = {}

    def engine(tp, overlap=True, quant="none", scheduler="continuous",
               prefix_cache_tokens=0):
        fkv = FreeKVConfig(method="freekv", page_size=8, budget=48, n_sink=8,
                           n_window=8, tau=0.8, recall_overlap=overlap,
                           kv_quant=quant)
        return ServeEngine(cfg, fkv, params, max_len=160, batch_size=3,
                           prefill_bucket=24, scheduler=scheduler,
                           prefix_cache_tokens=prefix_cache_tokens, tp=tp)

    def gen(eng, rs=reqs):
        outs = eng.generate([Request(uid=r.uid, tokens=r.tokens,
                                     max_new_tokens=r.max_new_tokens)
                             for r in rs])
        return [c.tokens for c in outs]

    # -- mixed-length continuous traffic, 4 configs x {tp1, tp2} ----------
    for overlap in (True, False):
        for quant in ("none", "int8"):
            e1 = engine(1, overlap, quant)
            t1 = gen(e1)
            e2 = engine(2, overlap, quant)
            t2 = gen(e2)
            report[f"traffic/overlap={overlap}/quant={quant}"] = {
                "tp1_tokens": t1, "tp2_tokens": t2,
                "tp1_summary": _summary(e1), "tp2_summary": _summary(e2)}

    # -- static chunked scheduler under TP --------------------------------
    e1 = engine(1, scheduler="static")
    t1 = gen(e1)
    e2 = engine(2, scheduler="static")
    t2 = gen(e2)
    report["static"] = {"tp1_tokens": t1, "tp2_tokens": t2}

    # -- prefix cache: two waves sharing a 48-token prefix ----------------
    shared = rng.integers(0, cfg.vocab_size, size=48).astype(np.int32)
    waves = []
    for i in range(4):
        suffix = rng.integers(0, cfg.vocab_size, size=24).astype(np.int32)
        waves.append(Request(uid=100 + i,
                             tokens=np.concatenate([shared, suffix]),
                             max_new_tokens=5))
    pc = {}
    for tp in (1, 2):
        e = engine(tp, prefix_cache_tokens=4096)
        toks = gen(e, waves)
        s = _summary(e)
        pc[f"tp{tp}_tokens"] = toks
        pc[f"tp{tp}_hit_tokens"] = sum(
            m.prefix_hit_tokens for m in e.last_metrics.requests)
        pc[f"tp{tp}_summary"] = s
        ec = engine(tp)                       # no cache: reference outputs
        pc[f"tp{tp}_cold_tokens"] = gen(ec, waves)
    report["prefix_cache"] = pc

    # -- quantized pool round-trip through per-shard recall ---------------
    from repro.core.retrieval import make_retriever
    from repro.launch.mesh import make_tp_mesh
    mesh = make_tp_mesh(2)
    base = dict(method="freekv", page_size=8, budget=48, n_sink=8,
                n_window=8, tau=0.8)
    B, T, H, kv, d = 2, 64, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    key = jax.random.PRNGKey(7)
    ks = jax.random.normal(key, (B, T, kv, d), jnp.float32)
    vs = jax.random.normal(jax.random.fold_in(key, 1), (B, T, kv, d),
                           jnp.float32)
    q0 = jax.random.normal(jax.random.fold_in(key, 2), (B, H, d))
    sel = {}
    for name, quant, m in (("tp_int8", "int8", mesh),
                           ("plain_int8", "int8", None),
                           ("plain_fp", "none", None)):
        fkv = FreeKVConfig(**base, kv_quant=quant,
                           tp_serving=m is not None)
        r = make_retriever(cfg, fkv, mesh=m)
        st = r.prefill(r.init_state(B, T + 32, jnp.float32), ks, vs, q0)
        sel[name] = (np.asarray(st["sel_k"]), np.asarray(st["sel_v"]),
                     np.asarray(st["sel_idx"]))
    bit_equal = (np.array_equal(sel["tp_int8"][0], sel["plain_int8"][0])
                 and np.array_equal(sel["tp_int8"][1], sel["plain_int8"][1]))
    idx_equal = np.array_equal(sel["tp_int8"][2], sel["plain_fp"][2])
    err = float(np.max(np.abs(sel["tp_int8"][0] - sel["plain_fp"][0])))
    report["quant_roundtrip"] = {"bit_equal_vs_plain": bool(bit_equal),
                                 "sel_idx_equal": bool(idx_equal),
                                 "max_abs_err_vs_fp": err}

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, default=str)
    print(f"wrote {out_path}")


if __name__ == "__main__":
    _driver(sys.argv[1])
