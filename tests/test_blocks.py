"""Block-level consistency: parallel/chunked forward == recurrent decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ssm, xlstm, moe as moe_mod
from repro.models.attention import attention_chunked, attention_dense
from repro.models.layers import apply_rope, rope_freqs

KEY = jax.random.PRNGKey(0)


def _seq_decode(fwd_state, init_state, step, x):
    T = x.shape[1]
    st = init_state
    ys = []
    for t in range(T):
        y, st = step(x[:, t:t + 1], st)
        ys.append(y)
    return jnp.concatenate(ys, 1), st


def test_mamba_forward_equals_decode():
    cfg = get_config("jamba-1.5-large-398b-smoke")
    p = ssm.mamba_init(KEY, cfg)
    x = 0.5 * jax.random.normal(jax.random.fold_in(KEY, 1), (2, 19, cfg.d_model))
    y_par, stT = ssm.mamba_forward(cfg, p, x, return_state=True)
    y_seq, st = _seq_decode(None, ssm.mamba_init_state(cfg, 2),
                            lambda xt, s: ssm.mamba_decode_step(cfg, p, xt, s), x)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq), atol=1e-5)
    np.testing.assert_allclose(np.asarray(stT["h"]), np.asarray(st["h"]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(stT["conv"]), np.asarray(st["conv"]), atol=1e-6)


@pytest.mark.parametrize("T,chunk", [(12, 4), (17, 8), (32, 32)])
def test_mlstm_forward_equals_decode(T, chunk):
    cfg = get_config("xlstm-350m-smoke")
    p = xlstm.mlstm_init(KEY, cfg)
    x = 0.5 * jax.random.normal(jax.random.fold_in(KEY, 2), (2, T, cfg.d_model))
    y_par, stT = xlstm.mlstm_forward(cfg, p, x, return_state=True, chunk=chunk)
    y_seq, st = _seq_decode(None, xlstm.mlstm_init_state(cfg, 2),
                            lambda xt, s: xlstm.mlstm_decode_step(cfg, p, xt, s), x)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq), atol=2e-5)
    np.testing.assert_allclose(np.asarray(stT["C"]), np.asarray(st["C"]), atol=2e-5)


def test_slstm_forward_equals_decode():
    cfg = get_config("xlstm-350m-smoke")
    p = xlstm.slstm_init(KEY, cfg)
    x = 0.5 * jax.random.normal(jax.random.fold_in(KEY, 3), (2, 21, cfg.d_model))
    y_par, stT = xlstm.slstm_forward(cfg, p, x, return_state=True)
    y_seq, st = _seq_decode(None, xlstm.slstm_init_state(cfg, 2),
                            lambda xt, s: xlstm.slstm_decode_step(cfg, p, xt, s), x)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq), atol=1e-5)


def test_chunked_attention_equals_dense():
    cfg = get_config("granite-3-8b-smoke")
    B, T = 2, 100
    q = jax.random.normal(KEY, (B, T, cfg.n_heads, cfg.d_head))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, cfg.n_kv_heads, cfg.d_head))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, T, cfg.n_kv_heads, cfg.d_head))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    od = attention_dense(cfg, q, k, v, pos, pos, causal=True)
    oc = attention_chunked(cfg, q, k, v, pos, pos, causal=True, chunk=32)
    np.testing.assert_allclose(np.asarray(od), np.asarray(oc), atol=2e-5)
    # sliding window variant
    od = attention_dense(cfg, q, k, v, pos, pos, causal=True, window=24)
    oc = attention_chunked(cfg, q, k, v, pos, pos, causal=True, window=24, chunk=32)
    np.testing.assert_allclose(np.asarray(od), np.asarray(oc), atol=2e-5)


def test_moe_matches_dense_oracle():
    cfg = get_config("deepseek-moe-16b-smoke")
    p = moe_mod.moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (2, 16, cfg.d_model))
    y, aux = moe_mod.apply_moe(cfg, p, x)
    yref = moe_mod.moe_dense_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=1e-4)
    assert float(aux.mean()) > 0.5  # balanced-ish router: aux ~ 1


def test_moe_capacity_drops_tokens():
    """With capacity factor exceeded, dropped tokens get (only) the shared
    expert / zero routed contribution, never garbage."""
    cfg = get_config("deepseek-moe-16b-smoke")
    p = moe_mod.moe_init(KEY, cfg)
    # route everything to one expert by biasing the router
    p = dict(p, router=jnp.zeros_like(p["router"]).at[:, 0].set(100.0))
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (1, 64, cfg.d_model))
    y, aux = moe_mod.apply_moe(cfg, p, x)
    assert jnp.isfinite(y).all()
    assert float(aux.mean()) > 0.5 and jnp.isfinite(aux).all()


def test_rope_relative_property():
    """RoPE: <rope(q,m), rope(k,n)> depends only on m-n."""
    cfg = get_config("granite-3-8b-smoke")
    q = jax.random.normal(KEY, (1, 1, 1, cfg.d_head))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, cfg.d_head))
    def dot_at(m, n):
        qm = apply_rope(cfg, q, jnp.array([[m]]))
        kn = apply_rope(cfg, k, jnp.array([[n]]))
        return float(jnp.sum(qm * kn))
    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-6  # but not position-blind
