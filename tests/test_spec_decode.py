"""Speculative decoding fused with speculative retrieval.

The drafted-window decode loop (``models.model.decode_window_spec``) runs a
per-slot on-device bigram drafter, verifies the drafted block in ONE batched
target pass, commits the longest greedy-consistent prefix, and rolls the
rejected suffix's paged KV back in place (ring snapshot/restore + one
blocking recall that doubles as the next block's prefetch). Assertions:

  * greedy outputs are BIT-IDENTICAL to the non-speculative synchronous
    reference for draft_len={0, 2, 4} x recall_overlap={on, off} x
    kv_quant={none, int8} on slot-turnover traffic, and across schedulers
    on equal-length traffic (the static path pads mixed-length prompts, so
    scheduler comparisons use equal lengths, as benchmarks/dispatch does);
  * an eos accepted mid-draft truncates exactly as the per-step path;
  * priority preemption composes: a rollback-then-swap round-trip (spec
    verify rejects a suffix, the request is then swapped to host with its
    drafter table aboard) reproduces the uninterrupted stream bitwise;
  * telemetry invariants: accepted <= proposed, committed tokens equal the
    scheduler's applied steps, zero host bytes between syncs, accept-rate /
    tokens-per-target-step are consistent ratios;
  * donation census parity with the non-spec window: state + loop carry
    donated, live-buffer census flat across drafted windows;
  * a ``Request.draft_hint`` (oracle reference stream) raises the accept
    rate but CANNOT change outputs;
  * unsupported configurations (static scheduler, host sampling) fall back
    to draft_len=0 instead of diverging.

tp=2 coverage runs in one subprocess with two forced XLA host devices (the
driver at the bottom of this file; module pinned whole to one CI shard, see
conftest._ATOMIC_MODULES).
"""
import dataclasses
import json
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.models.model import init_params, supports_spec_decode
from repro.serving.engine import Request, ServeEngine
from repro.serving.sampling import SamplerConfig, request_key

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    fkv = FreeKVConfig(method="freekv", page_size=8, budget=48, n_sink=8,
                       n_window=8, tau=0.8)
    return cfg, fkv, params


def _prompt(cfg, n, seed=0):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, n).astype(np.int32)


def _turnover_reqs(cfg, n=5, equal_len=False):
    """Mixed lengths over few slots -> slot reuse mid-run; ``equal_len``
    pins one prompt length so the padding static scheduler is comparable."""
    return [Request(uid=i,
                    tokens=_prompt(cfg, 48 if equal_len else 48 + 8 * (i % 2),
                                   seed=i),
                    max_new_tokens=3 if i % 2 else 7) for i in range(n)]


def _run(cfg, fkv, params, reqs, batch_size=2, scheduler="continuous"):
    eng = ServeEngine(cfg, fkv, params, max_len=256, batch_size=batch_size,
                      sampler=SamplerConfig(temperature=0.0),
                      scheduler=scheduler, prefill_bucket=8)
    outs = eng.generate(reqs)
    return outs, eng.last_metrics


def _spec(fkv, draft_len, **kw):
    return dataclasses.replace(fkv, draft_len=draft_len,
                               sample_on_device=True, sync_interval=8, **kw)


def _tokens(outs):
    return {o.uid: o.tokens for o in outs}


# ---------------------------------------------------------------------------
# bit-identity: spec-on vs the non-speculative synchronous reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("overlap", [True, False], ids=["overlap", "sync"])
@pytest.mark.parametrize("quant", ["none", "int8"])
def test_spec_bit_identity(setup, overlap, quant):
    cfg, fkv, params = setup
    base = dataclasses.replace(fkv, recall_overlap=overlap, kv_quant=quant)
    ref, _ = _run(cfg, dataclasses.replace(base, sample_on_device=False),
                  params, _turnover_reqs(cfg))
    for dl in (0, 2, 4):
        outs, em = _run(cfg, _spec(base, dl), params, _turnover_reqs(cfg))
        assert _tokens(outs) == _tokens(ref), \
            f"draft_len={dl} diverged from the synchronous reference"
        assert em.summary()["specdec"]["draft_len"] == dl


def test_scheduler_dimension_equal_len(setup):
    """Equal-length prompts: the continuous spec loop, the static chunked
    fallback (spec forced off there) and the synchronous reference agree."""
    cfg, fkv, params = setup
    mk = lambda: _turnover_reqs(cfg, equal_len=True)  # noqa: E731
    ref, _ = _run(cfg, dataclasses.replace(fkv, sample_on_device=False),
                  params, mk())
    spec, _ = _run(cfg, _spec(fkv, 4), params, mk())
    static, em = _run(cfg, _spec(fkv, 4), params, mk(), scheduler="static")
    assert _tokens(spec) == _tokens(ref)
    assert _tokens(static) == _tokens(ref)
    assert em.summary()["specdec"]["draft_len"] == 0   # fallback, not a bug


def test_eos_accepted_mid_draft(setup):
    """An eos landing inside an accepted drafted block truncates exactly
    where the per-step path stops — later drafted rows never commit."""
    cfg, fkv, params = setup
    prompt = _prompt(cfg, 64, seed=5)
    full, _ = _run(cfg, dataclasses.replace(fkv, sample_on_device=False),
                   params, [Request(uid=0, tokens=prompt, max_new_tokens=8)],
                   batch_size=1)
    eos = full[0].tokens[2]
    cut = full[0].tokens.index(eos) + 1
    outs, _ = _run(cfg, _spec(fkv, 4), params,
                   [Request(uid=0, tokens=prompt, max_new_tokens=8,
                            eos_token=eos)], batch_size=1)
    assert outs[0].tokens == full[0].tokens[:cut]
    assert outs[0].tokens[-1] == eos


# ---------------------------------------------------------------------------
# rollback-then-preempt: swap round-trip with the drafter lane aboard
# ---------------------------------------------------------------------------
def test_rollback_then_preempt_roundtrip(setup):
    """Priority preemption mid-run under spec decode: the victim's state —
    including its draft table and post-rollback rings — swaps to host and
    resumes bit-identically to the never-preempted non-spec run."""
    cfg, fkv, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (40, 64, 24)]
    mk = lambda: [Request(uid=i, tokens=p, max_new_tokens=10,  # noqa: E731
                          priority=(1 if i == 2 else 0))
                  for i, p in enumerate(prompts)]
    base, _ = _run(cfg, dataclasses.replace(fkv, sample_on_device=False),
                   params, mk())
    pre, em = _run(cfg, _spec(fkv, 3, preempt=True), params, mk())
    assert _tokens(pre) == _tokens(base), \
        "preemption under spec decode changed greedy outputs"
    assert em.preemptions >= 1 and em.resumes == em.preemptions
    assert em.swap_out_bytes == em.swap_in_bytes > 0
    assert em.summary()["specdec"]["verify_steps"] > 0


# ---------------------------------------------------------------------------
# telemetry invariants
# ---------------------------------------------------------------------------
def test_spec_telemetry_invariants(setup):
    from repro.obs import Observability, TraceRecorder
    cfg, fkv, params = setup
    eng = ServeEngine(cfg, _spec(fkv, 3), params, max_len=256, batch_size=2,
                      sampler=SamplerConfig(temperature=0.0),
                      prefill_bucket=8,
                      obs=Observability(enabled=True,
                                        trace=TraceRecorder(enabled=True)))
    outs = eng.generate(_turnover_reqs(cfg))
    em = eng.last_metrics
    sd = em.summary()["specdec"]
    assert sd["draft_len"] == 3
    assert 0 <= sd["accepted_tokens"] <= sd["proposed_tokens"]
    # conservation: the verify loop commits every token after each
    # request's prefill-sampled first one, and proposes exactly draft_len
    # per committed slot-step (accepted = committed - slot_steps)
    assert sd["committed_tokens"] == sum(len(o.tokens) - 1 for o in outs)
    slot_steps = sd["proposed_tokens"] / 3
    assert sd["accepted_tokens"] == sd["committed_tokens"] - slot_steps
    assert 0.0 <= sd["accept_rate"] <= 1.0
    assert 1.0 <= sd["tokens_per_step"] <= 4.0
    d = em.summary()["dispatch"]
    assert d["nonsync_host_bytes"] == 0.0, \
        "drafted windows must stay host-sync-free between syncs"
    # the histogram saw every verify iteration that committed something,
    # and each one opened an engine/spec_verify trace span
    assert sd["tokens_per_step_hist"]["count"] == sd["verify_steps"]
    from repro.obs.trace import SPAN_SPEC_VERIFY
    spans = [e for e in eng.obs.trace.events
             if e.get("name") == SPAN_SPEC_VERIFY]
    assert len(spans) == sd["verify_steps"]
    assert sum(s["args"]["committed"] for s in spans) \
        == sd["committed_tokens"]


# ---------------------------------------------------------------------------
# donation census parity with the non-spec window
# ---------------------------------------------------------------------------
def _donation_supported():
    f = jax.jit(lambda x: x + 1, donate_argnums=(0,))
    x = jnp.zeros((8,))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        f(x)
    return x.is_deleted()


def test_spec_window_donates_state(setup):
    """The drafted window donates state + loop carry exactly like the
    non-spec loop: consumed buffers are deleted, census stays flat."""
    cfg, fkv, params = setup
    if not _donation_supported():
        pytest.skip("backend does not implement buffer donation")
    eng = ServeEngine(cfg, _spec(fkv, 2), params, max_len=256, batch_size=2,
                      sampler=SamplerConfig(temperature=0.0))
    assert eng.spec_decode and eng.draft_len == 2
    pool = eng.make_slot_pool(2)
    req = Request(uid=0, tokens=_prompt(cfg, 64), max_new_tokens=32)
    logits1, s1, _, _ = eng.prefill_one(req)
    assert "draft_tab" in s1            # drafter lane rides the decode state
    pool.insert(s1, pool.alloc(0))
    tok = int(np.asarray(eng.sample_slot(logits1, request_key(0, 0), 0))[0])
    loop = {"cur": jnp.asarray(np.array([tok, 0], np.int32)),
            "key": jnp.tile(jnp.asarray(request_key(0, 0))[None], (2, 1)),
            "count": jnp.ones(2, jnp.int32),
            "limit": jnp.asarray(np.array([32, 1], np.int32)),
            "eos": jnp.full((2,), -1, jnp.int32),
            "fin": jnp.asarray(np.array([False, True])),
            "stop_turnover": jnp.asarray(False)}
    old_leaves = jax.tree.leaves(pool.state)
    pool.state, loop, toks, valid, *rest = eng.decode_window(pool.state, loop)
    assert toks.ndim == 3 and toks.shape[1] == 3     # (k, 1 + draft_len, B)
    assert all(leaf.is_deleted() for leaf in old_leaves)
    del rest
    baseline = len(jax.live_arrays())
    deltas = []
    for _ in range(3):
        old_leaves = jax.tree.leaves(pool.state)
        pool.state, loop, *rest = eng.decode_window(pool.state, loop)
        assert all(leaf.is_deleted() for leaf in old_leaves)
        del rest
        deltas.append(len(jax.live_arrays()) - baseline)
    assert max(deltas) - min(deltas) <= 2, deltas


# ---------------------------------------------------------------------------
# draft hints: steer acceptance, never outputs
# ---------------------------------------------------------------------------
def test_draft_hint_boosts_accept_not_outputs(setup):
    cfg, fkv, params = setup
    prompt = _prompt(cfg, 48, seed=11)
    mk = lambda hint=None: [Request(uid=0, tokens=prompt,  # noqa: E731
                                    max_new_tokens=32, draft_hint=hint)]
    base, _ = _run(cfg, _spec(fkv, 0), params, mk(), batch_size=1)
    cold, em_cold = _run(cfg, _spec(fkv, 4), params, mk(), batch_size=1)
    hint = np.concatenate([prompt[-1:],
                           np.asarray(base[0].tokens, np.int32)])
    warm, em_warm = _run(cfg, _spec(fkv, 4), params, mk(hint), batch_size=1)
    assert cold[0].tokens == base[0].tokens
    assert warm[0].tokens == base[0].tokens, \
        "a draft hint must never change greedy outputs"
    cold_acc = em_cold.summary()["specdec"]["accept_rate"]
    warm_acc = em_warm.summary()["specdec"]["accept_rate"]
    assert warm_acc > cold_acc, (cold_acc, warm_acc)


# ---------------------------------------------------------------------------
# unsupported configurations fall back to draft_len=0
# ---------------------------------------------------------------------------
def test_unsupported_configs_fall_back(setup):
    cfg, fkv, params = setup
    assert supports_spec_decode(cfg, _spec(fkv, 4))
    eng = ServeEngine(cfg, _spec(fkv, 4), params, max_len=128, batch_size=2,
                      scheduler="static")
    assert not eng.spec_decode and eng.draft_len == 0
    host = dataclasses.replace(_spec(fkv, 4), sample_on_device=False)
    eng = ServeEngine(cfg, host, params, max_len=128, batch_size=2)
    assert not eng.spec_decode and eng.draft_len == 0
    eng = ServeEngine(cfg, _spec(fkv, 4), params, max_len=128, batch_size=2)
    assert eng.spec_decode and eng.draft_len == 4


# ---------------------------------------------------------------------------
# tp=2: one subprocess with two forced host devices
# ---------------------------------------------------------------------------
@pytest.fixture(scope="session")
def tp_report(tmp_path_factory):
    out = tmp_path_factory.mktemp("tp_specdec") / "report.json"
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    subprocess.run([sys.executable, os.path.abspath(__file__), str(out)],
                   check=True, timeout=1500, env=env, cwd=REPO)
    return json.loads(out.read_text())


@pytest.mark.parametrize("cell", ["overlap=True/quant=none",
                                  "overlap=False/quant=int8"])
def test_tp2_spec_bit_identical(tp_report, cell):
    r = tp_report[cell]
    assert r["tp2_spec_tokens"] == r["tp1_ref_tokens"], \
        "tp=2 spec decode diverged from the tp=1 synchronous reference"
    assert r["specdec"]["draft_len"] == 3
    assert r["specdec"]["verify_steps"] > 0
    assert r["nonsync_host_bytes"] == 0.0


def _driver(out_path):
    assert len(jax.devices()) >= 2, jax.devices()
    cfg = get_config("smollm-360m-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    report = {}
    for overlap, quant in ((True, "none"), (False, "int8")):
        fkv = FreeKVConfig(method="freekv", page_size=8, budget=48, n_sink=8,
                           n_window=8, tau=0.8, recall_overlap=overlap,
                           kv_quant=quant)

        def gen(f, tp):
            eng = ServeEngine(cfg, f, params, max_len=256, batch_size=2,
                              sampler=SamplerConfig(temperature=0.0),
                              prefill_bucket=8, tp=tp)
            outs = eng.generate(_turnover_reqs(cfg))
            return {o.uid: o.tokens for o in outs}, eng.last_metrics

        ref, _ = gen(dataclasses.replace(fkv, sample_on_device=False), tp=1)
        spec, em = gen(_spec(fkv, 3), tp=2)
        s = em.summary()
        report[f"overlap={overlap}/quant={quant}"] = {
            "tp1_ref_tokens": {str(k): v for k, v in ref.items()},
            "tp2_spec_tokens": {str(k): v for k, v in spec.items()},
            "specdec": s["specdec"],
            "nonsync_host_bytes": s["dispatch"]["nonsync_host_bytes"],
        }
    with open(out_path, "w") as f:
        json.dump(report, f)


if __name__ == "__main__":
    _driver(sys.argv[1])
