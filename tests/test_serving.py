"""Serving engine end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.models.model import init_params
from repro.serving.engine import Request, ServeEngine
from repro.serving.sampling import SamplerConfig


def test_engine_generates_batched():
    cfg = get_config("smollm-360m-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    fkv = FreeKVConfig(method="freekv", page_size=8, budget=64, n_sink=8,
                       n_window=8, tau=0.8)
    eng = ServeEngine(cfg, fkv, params, max_len=256, batch_size=2)
    prompt = np.random.default_rng(0).integers(0, cfg.vocab_size, 64).astype(np.int32)
    reqs = [Request(uid=i, tokens=prompt, max_new_tokens=8) for i in range(3)]
    outs = eng.generate(reqs)
    assert len(outs) == 3
    for o in outs:
        assert len(o.tokens) == 8
        assert all(0 <= t < cfg.padded_vocab() for t in o.tokens)
        assert o.decode_s > 0 and o.prefill_s > 0
        assert 0.0 <= o.stats["correction_rate"] <= 1.0


def test_engine_deterministic_greedy():
    cfg = get_config("smollm-360m-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    fkv = FreeKVConfig(method="full", page_size=8, budget=64, n_sink=8, n_window=8)
    eng = ServeEngine(cfg, fkv, params, max_len=128, batch_size=1,
                      sampler=SamplerConfig(temperature=0.0))
    prompt = np.arange(40, dtype=np.int32) % cfg.vocab_size
    a = eng.generate([Request(uid=0, tokens=prompt, max_new_tokens=6)])[0]
    b = eng.generate([Request(uid=1, tokens=prompt, max_new_tokens=6)])[0]
    assert a.tokens == b.tokens


def test_method_consistency_full_vs_freekv_bigbudget():
    """Greedy decode with FreeKV at full budget must match the full cache."""
    cfg = get_config("smollm-360m-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.random.default_rng(1).integers(0, cfg.vocab_size, 72).astype(np.int32)
    outs = {}
    for method, budget in [("full", 0), ("freekv", 4096)]:
        fkv = FreeKVConfig(method=method, page_size=8, budget=max(budget, 64),
                           n_sink=8, n_window=8, tau=0.8)
        eng = ServeEngine(cfg, fkv, params, max_len=256, batch_size=1)
        outs[method] = eng.generate(
            [Request(uid=0, tokens=prompt, max_new_tokens=8)])[0].tokens
    assert outs["full"] == outs["freekv"]
