"""Chunked prefill sweep: bit-identity + exact step accounting.

``prefill_chunk_tokens`` splits admission prefill into budgeted chunks
interleaved with decode windows. The final chunk rebuilds the decode state
from the full accumulated K/V — the prefix-cache extension math — so greedy
outputs must be BIT-IDENTICAL to whole-shot prefill for every budget
(1 token, one page, whole prompt), with the prefix cache hitting or missing,
and with the overlapped recall pipeline on or off. Decode-side accounting
(``EngineMetrics.steps`` / ``active_slot_steps``) must also be identical —
chunking moves prefill work, never decode work — while the new
``scheduling`` counters account every admitted prompt token exactly once.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.serving.engine import Request, ServeEngine
from repro.serving.sampling import SamplerConfig

BUCKET = 8
MAX_NEW = 8


@pytest.fixture(scope="module")
def runs():
    """One traffic pattern per scenario, executed once per config."""
    from repro.models.model import init_params
    cfg = get_config("smollm-360m-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    # short prompts: budget=1 compiles one extension shape per token
    short = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
             for n in (10, 12)]
    shared = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
    waves = [np.concatenate([shared,
                             rng.integers(0, cfg.vocab_size, 24)
                             .astype(np.int32)]) for _ in range(2)]

    def gen(prompts, chunk=0, overlap=True, cache=0, batch=2):
        fkv = FreeKVConfig(method="freekv", page_size=8, budget=64, n_sink=8,
                           n_window=8, tau=0.8, recall_overlap=overlap,
                           prefill_chunk_tokens=chunk)
        eng = ServeEngine(cfg, fkv, params, max_len=256, batch_size=batch,
                          sampler=SamplerConfig(temperature=0.0),
                          prefill_bucket=BUCKET, prefix_cache_tokens=cache)
        reqs = [Request(uid=i, tokens=p, max_new_tokens=MAX_NEW)
                for i, p in enumerate(prompts)]
        outs = {o.uid: o.tokens for o in eng.generate(reqs)}
        return outs, eng.last_metrics

    out = {"short": {}, "cache": {}}
    for budget in (0, 1, BUCKET, 10 ** 6):
        out["short"][budget] = gen(short, chunk=budget)
    out["short"]["sync"] = gen(short, chunk=0, overlap=False)
    out["short"][f"sync/{BUCKET}"] = gen(short, chunk=BUCKET, overlap=False)
    # serial admission (batch=1): the second wave's job opens after the
    # first wave's full-prompt K/V reached the trie, in both modes
    for budget in (0, BUCKET):
        out["cache"][budget] = gen(waves, chunk=budget, cache=4096, batch=1)
    out["cache"]["cold"] = gen(waves, chunk=0, batch=1)
    out["padded"] = [max(BUCKET, -(-len(p) // BUCKET) * BUCKET)
                     for p in short]
    return out


@pytest.mark.parametrize("budget", [1, BUCKET, 10 ** 6])
def test_chunked_outputs_bit_identical(runs, budget):
    base, _ = runs["short"][0]
    chunked, em = runs["short"][budget]
    assert chunked == base, f"budget={budget} changed greedy outputs"
    assert em.prefill_chunks >= len(base)


@pytest.mark.parametrize("budget", [1, BUCKET, 10 ** 6])
def test_chunked_step_accounting_identical(runs, budget):
    """Chunking moves prefill work only: per-request decode work is
    conserved EXACTLY (active_slot_steps = sum of max_new-1), and every
    admitted (bucket-padded) prompt token is chunk-accounted exactly once.
    ``steps`` may grow — decode windows legitimately run while later
    prompts are still chunking (the interleaving chunking exists for)."""
    _, em0 = runs["short"][0]
    _, em = runs["short"][budget]
    assert em.active_slot_steps == em0.active_slot_steps
    assert em.steps >= em0.steps
    assert em0.prefill_chunks == em0.prefill_chunk_tokens == 0
    total = sum(runs["padded"])
    assert em.prefill_chunk_tokens == total
    expect = sum(-(-p // budget) for p in runs["padded"])
    assert em.prefill_chunks == expect


def test_chunked_decode_interleaves_with_prefill(runs):
    """Budget=1: the first request's decode proceeds while the second
    prompt is still chunking — visible as MORE scheduler rounds carrying
    fewer live slots for the same conserved active_slot_steps."""
    _, em0 = runs["short"][0]
    _, em1 = runs["short"][1]
    assert em1.steps > em0.steps
    assert em1.active_slot_steps == em0.active_slot_steps


def test_chunked_bit_identical_without_overlap(runs):
    """recall_overlap off: chunked == whole-shot on the synchronous path
    too (and equals the overlapped outputs — the existing overlap
    bit-identity guarantee composes with chunking)."""
    base_sync, _ = runs["short"]["sync"]
    chunked_sync, em = runs["short"][f"sync/{BUCKET}"]
    assert chunked_sync == base_sync
    assert em.prefill_chunks > 0
    base, _ = runs["short"][0]
    assert base_sync == base


def test_chunked_prefix_cache_hit_bit_identical(runs):
    """A cache-hit admission seeds the accumulated K/V with the cached
    span: outputs still bit-identical, hit accounting unchanged, and only
    the MISSED suffix tokens are chunked."""
    cold, _ = runs["cache"]["cold"]
    whole, em0 = runs["cache"][0]
    chunked, em = runs["cache"][BUCKET]
    assert whole == cold == chunked
    h0 = [m.prefix_hit_tokens for m in em0.requests]
    h1 = [m.prefix_hit_tokens for m in em.requests]
    assert h1 == h0 and h1[1] > 0               # second wave hits the trie
    padded = [m.padded_prompt_tokens for m in em.requests]
    missed = sum(p - h for p, h in zip(padded, h1))
    assert em.prefill_chunk_tokens == missed
    assert em.summary()["scheduling"]["prefill_chunk_tokens"] == missed
