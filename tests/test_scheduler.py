"""Continuous-batching scheduler + KV slot pool: reuse, ordering, consistency."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.models.model import init_params
from repro.serving.engine import Request, ServeEngine
from repro.serving.kv_slots import SlotPool
from repro.serving.sampling import SamplerConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    fkv = FreeKVConfig(method="freekv", page_size=8, budget=64, n_sink=8,
                       n_window=8, tau=0.8)
    return cfg, fkv, params


def _prompt(cfg, n, seed=0):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, n).astype(np.int32)


# ---------------------------------------------------------------------------
# slot pool
# ---------------------------------------------------------------------------
def test_slot_pool_insert_extract_roundtrip(setup):
    cfg, fkv, _ = setup
    pool = SlotPool(cfg, fkv, num_slots=3, max_len=128)
    src = pool._template
    # stamp a recognizable length into the B=1 source state
    src = jax.tree.map(lambda a: a, src)
    src["pos"] = src["pos"] + 7
    slot = pool.alloc(owner_uid=42)
    pool.insert(src, slot)
    got = pool.extract(slot)
    assert int(got["pos"][0]) == 7
    other = pool.extract((slot + 1) % 3)
    assert int(other["pos"][0]) == 0            # neighbors untouched
    pool.free(slot)
    assert pool.free_count == 3
    pool.flush_resets()                         # lazy reset applies here
    assert int(pool.extract(slot)["pos"][0]) == 0


def test_slot_pool_reuse_across_request_waves(setup):
    """More requests than slots: every slot is recycled and all complete."""
    cfg, fkv, params = setup
    eng = ServeEngine(cfg, fkv, params, max_len=256, batch_size=2,
                      sampler=SamplerConfig(temperature=0.0),
                      prefill_bucket=64)       # ragged prompts, one shape
    reqs = [Request(uid=i, tokens=_prompt(cfg, 40 + i, seed=i),
                    max_new_tokens=3) for i in range(5)]
    outs = eng.generate(reqs)
    assert [o.uid for o in outs] == [0, 1, 2, 3, 4]
    assert all(len(o.tokens) == 3 for o in outs)
    assert eng._pool.allocs == 5 > eng._pool.num_slots
    assert eng._pool.free_count == 2            # all slots returned
    em = eng.last_metrics
    assert em.steps > 0 and 0.0 < em.slot_occupancy <= 1.0
    assert all(r.finish_t is not None for r in em.requests)


# ---------------------------------------------------------------------------
# scheduler end-to-end ordering
# ---------------------------------------------------------------------------
def test_short_requests_finish_before_long(setup):
    """A short request co-scheduled with a long one completes first and its
    freed slot admits a queued request before the long request drains."""
    cfg, fkv, params = setup
    eng = ServeEngine(cfg, fkv, params, max_len=256, batch_size=2,
                      sampler=SamplerConfig(temperature=0.0))
    long_req = Request(uid=0, tokens=_prompt(cfg, 64, 0), max_new_tokens=16)
    short_req = Request(uid=1, tokens=_prompt(cfg, 64, 1), max_new_tokens=2)
    queued = Request(uid=2, tokens=_prompt(cfg, 64, 2), max_new_tokens=2)
    eng.generate([long_req, short_req, queued])
    m = {r.uid: r for r in eng.last_metrics.requests}
    assert m[1].finish_step < m[0].finish_step
    assert m[2].finish_step < m[0].finish_step   # admitted into the freed slot
    assert m[1].queue_wait_s <= m[2].queue_wait_s


def test_finished_slots_not_stepped(setup):
    """Engine step count tracks live work, not the longest request times
    slots: 1 long (max_new 16) + 1 short (max_new 2) on 2 slots needs 15
    steps, and total active-slot-steps is sum of per-request decode steps."""
    cfg, fkv, params = setup
    eng = ServeEngine(cfg, fkv, params, max_len=256, batch_size=2,
                      sampler=SamplerConfig(temperature=0.0))
    eng.generate([Request(uid=0, tokens=_prompt(cfg, 64), max_new_tokens=16),
                  Request(uid=1, tokens=_prompt(cfg, 64), max_new_tokens=2)])
    em = eng.last_metrics
    assert em.steps == 15                        # long: 15 decode steps
    assert em.active_slot_steps == 15 + 1        # short adds just 1


def test_continuous_matches_static_greedy(setup):
    cfg, fkv, params = setup
    prompt = _prompt(cfg, 64, seed=3)            # bucket-aligned: no padding
    outs = {}
    for sched in ("continuous", "static"):
        eng = ServeEngine(cfg, fkv, params, max_len=256, batch_size=2,
                          sampler=SamplerConfig(temperature=0.0),
                          scheduler=sched)
        outs[sched] = [o.tokens for o in eng.generate(
            [Request(uid=i, tokens=prompt, max_new_tokens=6)
             for i in range(2)])]
    assert outs["continuous"] == outs["static"]


def test_eos_token_stops_both_schedulers(setup):
    """eos_token truncates generation identically under both schedulers."""
    cfg, fkv, params = setup
    prompt = _prompt(cfg, 64, seed=5)
    full = {}
    for sched in ("continuous", "static"):
        eng = ServeEngine(cfg, fkv, params, max_len=256, batch_size=1,
                          sampler=SamplerConfig(temperature=0.0),
                          scheduler=sched)
        full[sched] = eng.generate(
            [Request(uid=0, tokens=prompt, max_new_tokens=8)])[0].tokens
    assert full["continuous"] == full["static"]
    eos = full["continuous"][2]                  # greedy is deterministic
    cut = full["continuous"].index(eos) + 1      # first occurrence wins
    assert cut <= 3
    for sched in ("continuous", "static"):
        eng = ServeEngine(cfg, fkv, params, max_len=256, batch_size=1,
                          sampler=SamplerConfig(temperature=0.0),
                          scheduler=sched)
        out = eng.generate([Request(uid=0, tokens=prompt, max_new_tokens=8,
                                    eos_token=eos)])[0]
        assert out.tokens == full[sched][:cut]   # truncated at first EOS
        assert out.tokens[-1] == eos


def test_static_stats_exclude_finished_rows(setup):
    """Static fallback: a finished request's stats stop accumulating (the
    wasted-decode fix) — its retrieval traffic is < the long request's."""
    cfg, fkv, params = setup
    eng = ServeEngine(cfg, fkv, params, max_len=256, batch_size=2,
                      sampler=SamplerConfig(temperature=0.0),
                      scheduler="static")
    outs = eng.generate([
        Request(uid=0, tokens=_prompt(cfg, 64), max_new_tokens=12),
        Request(uid=1, tokens=_prompt(cfg, 64), max_new_tokens=2)])
    long_o, short_o = outs
    assert short_o.steps == 1 and long_o.steps == 11
    assert short_o.stats["kv_heads"] < long_o.stats["kv_heads"]
    assert short_o.decode_s < long_o.decode_s


# ---------------------------------------------------------------------------
# prefix cache through the engine
# ---------------------------------------------------------------------------
def test_prefix_cache_hit_preserves_greedy_output(setup):
    cfg, fkv, params = setup
    big = FreeKVConfig(method="freekv", page_size=8, budget=4096, n_sink=8,
                       n_window=8, tau=0.8)
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab_size, 128).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
             for _ in range(2)]
    prompts = [np.concatenate([shared, t]) for t in tails]
    reqs = lambda: [Request(uid=i, tokens=p, max_new_tokens=6)
                    for i, p in enumerate(prompts)]

    ref_eng = ServeEngine(cfg, big, params, max_len=512, batch_size=1,
                          sampler=SamplerConfig(temperature=0.0))
    ref = [o.tokens for o in ref_eng.generate(reqs())]

    eng = ServeEngine(cfg, big, params, max_len=512, batch_size=1,
                      sampler=SamplerConfig(temperature=0.0),
                      prefix_cache_tokens=4096)
    outs = eng.generate(reqs())
    assert [o.tokens for o in outs] == ref
    hits = [o.metrics.prefix_hit_tokens for o in outs]
    assert hits[0] == 0 and hits[1] == 128       # shared prefix reused
    assert eng.prefix_cache.hit_tokens == 128
    em = eng.last_metrics
    assert em.prefix_cache["hit_rate"] > 0
