"""Host-sync-free decode loop: donation (no per-step state copies),
sync-interval bit-identity vs the synchronous path, per-slot RNG stream
stability across slot turnover, and host-transfer accounting."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.models.model import init_params
from repro.serving.engine import Request, ServeEngine
from repro.serving.sampling import SamplerConfig, request_key


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    fkv = FreeKVConfig(method="freekv", page_size=8, budget=64, n_sink=8,
                       n_window=8, tau=0.8)
    return cfg, fkv, params


def _prompt(cfg, n, seed=0):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, n).astype(np.int32)


def _turnover_reqs(cfg, n=5):
    """Mixed lengths over few slots -> slot reuse mid-run."""
    return [Request(uid=i, tokens=_prompt(cfg, 48 + 8 * (i % 2), seed=i),
                    max_new_tokens=3 if i % 2 else 7) for i in range(n)]


def _run(cfg, fkv, params, reqs, batch_size=2, temperature=0.0):
    eng = ServeEngine(cfg, fkv, params, max_len=256, batch_size=batch_size,
                      sampler=SamplerConfig(temperature=temperature),
                      prefill_bucket=64)
    outs = eng.generate(reqs)
    return outs, eng.last_metrics


def _donation_supported():
    f = jax.jit(lambda x: x + 1, donate_argnums=(0,))
    x = jnp.zeros((8,))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        f(x)
    return x.is_deleted()


# ---------------------------------------------------------------------------
# bit-identity across dispatch modes
# ---------------------------------------------------------------------------
def test_sync_interval_bit_identity(setup):
    """Greedy token streams (and per-request retrieval stats) are identical
    for the synchronous reference path and sync_interval in {1, 4, 8}."""
    cfg, fkv, params = setup
    results = {}
    for name, f in [
            ("sync", dataclasses.replace(fkv, sample_on_device=False)),
            ("k1", dataclasses.replace(fkv, sync_interval=1)),
            ("k4", dataclasses.replace(fkv, sync_interval=4)),
            ("k8", dataclasses.replace(fkv, sync_interval=8))]:
        outs, em = _run(cfg, f, params, _turnover_reqs(cfg))
        results[name] = ([o.tokens for o in outs],
                         [o.stats.get("correction_rate", 0.0) for o in outs])
        assert em.slot_occupancy > 0
    ref_tokens, ref_stats = results["sync"]
    for name, (tokens, stats) in results.items():
        assert tokens == ref_tokens, f"{name} diverged from sync path"
        assert np.allclose(stats, ref_stats), f"{name} stats diverged"


def test_eos_stops_mid_window(setup):
    """An eos sampled mid-window truncates exactly as the per-step path."""
    cfg, fkv, params = setup
    prompt = _prompt(cfg, 64, seed=5)
    full, _ = _run(cfg, dataclasses.replace(fkv, sample_on_device=False),
                   params, [Request(uid=0, tokens=prompt, max_new_tokens=8)],
                   batch_size=1)
    eos = full[0].tokens[2]
    cut = full[0].tokens.index(eos) + 1
    outs, _ = _run(cfg, dataclasses.replace(fkv, sync_interval=8), params,
                   [Request(uid=0, tokens=prompt, max_new_tokens=8,
                            eos_token=eos)], batch_size=1)
    assert outs[0].tokens == full[0].tokens[:cut]
    assert outs[0].tokens[-1] == eos


# ---------------------------------------------------------------------------
# donation: the slot pool is updated in place, never copied
# ---------------------------------------------------------------------------
def test_no_per_step_copy_of_slot_pool(setup):
    """The decode window DONATES state + loop carry: the previous step's
    pool buffers are consumed (deleted), and the live-buffer census stays
    flat across windows — no shadow copy of the slot pool anywhere."""
    cfg, fkv, params = setup
    if not _donation_supported():
        pytest.skip("backend does not implement buffer donation")
    eng = ServeEngine(cfg, fkv, params, max_len=256, batch_size=2,
                      sampler=SamplerConfig(temperature=0.0))
    pool = eng.make_slot_pool(2)
    req = Request(uid=0, tokens=_prompt(cfg, 64), max_new_tokens=32)
    logits1, s1, _, _ = eng.prefill_one(req)
    slot = pool.alloc(0)
    pre_splice = jax.tree.leaves(pool.state)
    pool.insert(s1, slot)
    # SlotPool splice donated the old full-batch state (in-place update)
    assert all(leaf.is_deleted() for leaf in pre_splice)

    tok = int(np.asarray(eng.sample_slot(logits1, request_key(0, 0), 0))[0])
    loop = {"cur": jnp.asarray(np.array([tok, 0], np.int32)),
            "key": jnp.tile(jnp.asarray(request_key(0, 0))[None], (2, 1)),
            "count": jnp.ones(2, jnp.int32),
            "limit": jnp.asarray(np.array([32, 1], np.int32)),
            "eos": jnp.full((2,), -1, jnp.int32),
            "fin": jnp.asarray(np.array([False, True])),
            "stop_turnover": jnp.asarray(False)}
    old_leaves = jax.tree.leaves(pool.state)
    pool.state, loop, *rest = eng.decode_window(pool.state, loop)
    # every donated input buffer was consumed — no copy survived
    assert all(leaf.is_deleted() for leaf in old_leaves)
    del rest
    baseline = len(jax.live_arrays())
    deltas = []
    for _ in range(3):
        old_leaves = jax.tree.leaves(pool.state)
        pool.state, loop, *rest = eng.decode_window(pool.state, loop)
        assert all(leaf.is_deleted() for leaf in old_leaves)
        del rest
        deltas.append(len(jax.live_arrays()) - baseline)
    # live-buffer census flat across windows (block outputs are freed as
    # `rest` is dropped; the pool itself is aliased in place)
    assert max(deltas) - min(deltas) <= 2, deltas


# ---------------------------------------------------------------------------
# per-slot RNG streams
# ---------------------------------------------------------------------------
def test_rng_stream_stable_across_turnover(setup):
    """A request's sampled tokens depend only on (seed, uid, token index):
    identical whether it runs alone, co-scheduled through slot turnover,
    under any sync_interval, or on the synchronous path."""
    cfg, fkv, params = setup
    prompt = _prompt(cfg, 64, seed=3)
    mk = lambda uids: [Request(uid=u, tokens=prompt, max_new_tokens=5)
                       for u in uids]
    crowded, _ = _run(cfg, fkv, params, mk([7, 8, 9]), batch_size=1,
                      temperature=0.8)
    crowded = {o.uid: o.tokens for o in crowded}
    for u in (7, 8, 9):
        alone, _ = _run(cfg, fkv, params, mk([u]), batch_size=2,
                        temperature=0.8)
        assert alone[0].tokens == crowded[u]
    for f in (dataclasses.replace(fkv, sync_interval=1),
              dataclasses.replace(fkv, sample_on_device=False)):
        outs, _ = _run(cfg, f, params, mk([7, 8, 9]), batch_size=2,
                       temperature=0.8)
        assert {o.uid: o.tokens for o in outs} == crowded


# ---------------------------------------------------------------------------
# host-transfer accounting
# ---------------------------------------------------------------------------
def test_zero_host_bytes_between_syncs(setup):
    """With on-device sampling nothing crosses the host boundary between
    syncs, and a long request amortizes many steps per sync."""
    cfg, fkv, params = setup
    reqs = [Request(uid=0, tokens=_prompt(cfg, 64), max_new_tokens=16)]
    _, em = _run(cfg, dataclasses.replace(fkv, sync_interval=8), params, reqs)
    d = em.summary()["dispatch"]
    assert d["nonsync_host_bytes"] == 0.0
    assert d["host_syncs"] == 2 and em.steps == 15      # 8 + 7 (early exit)
    assert d["steps_per_sync"] > 4
    # synchronous reference: one sync per step, strictly more traffic
    _, em_sync = _run(cfg, dataclasses.replace(fkv, sample_on_device=False),
                      params, reqs)
    ds = em_sync.summary()["dispatch"]
    assert ds["host_syncs"] == em_sync.steps == 15
    assert ds["host_bytes_per_step"] > d["host_bytes_per_step"]


def test_sync_path_metrics_match(setup):
    """Engine step/occupancy accounting is identical across dispatch modes
    (the window's valid masks reproduce per-step bookkeeping exactly)."""
    cfg, fkv, params = setup
    reqs = lambda: [Request(uid=0, tokens=_prompt(cfg, 64), max_new_tokens=16),
                    Request(uid=1, tokens=_prompt(cfg, 64), max_new_tokens=2)]
    _, em_a = _run(cfg, dataclasses.replace(fkv, sync_interval=8), params,
                   reqs())
    _, em_b = _run(cfg, dataclasses.replace(fkv, sample_on_device=False),
                   params, reqs())
    assert em_a.steps == em_b.steps == 15
    assert em_a.active_slot_steps == em_b.active_slot_steps == 16
    assert em_a.sync_pages == em_b.sync_pages
    assert em_a.async_pages == em_b.async_pages
