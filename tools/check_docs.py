#!/usr/bin/env python3
"""Check that intra-repo markdown links resolve (CI docs job; stdlib only).

Scans every tracked *.md file for inline links/images and verifies that
relative targets exist on disk (anchors are stripped; absolute URLs and
mailto are skipped). Also verifies code-path references of the form
`src/...`/`benchmarks/...`/`tests/...` printed in docs tables exist, so the
module map cannot silently rot.

    python tools/check_docs.py          # exits non-zero on broken links
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
# backticked repo paths in docs prose/tables, e.g. `src/repro/core/recall.py`
PATH_RE = re.compile(
    r"`((?:src|benchmarks|tests|docs|tools|examples)/[A-Za-z0-9_./-]+?)`")
SKIP_DIRS = {".git", ".github", "__pycache__", ".claude", "artifacts"}


def md_files():
    for p in sorted(ROOT.rglob("*.md")):
        if not any(part in SKIP_DIRS for part in p.parts):
            yield p


def check_file(md: Path):
    errors = []
    text = md.read_text(encoding="utf-8")
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (md.parent / rel).resolve()
        if not resolved.exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
    for m in PATH_RE.finditer(text):
        path = m.group(1).rstrip("/")
        if not (ROOT / path).exists():
            errors.append(f"{md.relative_to(ROOT)}: missing path -> {path}")
    return errors


def main() -> int:
    all_errors = []
    n = 0
    for md in md_files():
        n += 1
        all_errors += check_file(md)
    for e in all_errors:
        print(f"ERROR: {e}")
    print(f"checked {n} markdown files: "
          f"{'OK' if not all_errors else f'{len(all_errors)} broken'}")
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main())
