#!/usr/bin/env python
"""Benchmark-regression gate over the committed BENCH_*.json trajectory files.

For every gated section this script

  1. loads the committed ``BENCH_<section>.json`` at the repo root (the
     baseline — written by the benchmark's ``--smoke`` / run.py config and
     committed with the PR that changed the numbers),
  2. re-runs the benchmark command that produces that file (same config, so
     the comparison is apples-to-apples),
  3. compares the re-run metrics against the baseline and **fails on a
     regression beyond the tolerance** (default 25%).

Only machine-independent metrics are gated — accuracies, byte counts,
analytical cost-model latencies, bit-identity flags, within-run ratios.
Raw wall-clock (``us_per_step`` etc.) is recorded in the files but never
gated: CI runners differ in speed, the committed numbers don't.

A metric whose baseline is 0 on a percent-scaled axis (e.g. ``acc_drop``)
is gated absolutely: the new value may not exceed the tolerance itself.

    PYTHONPATH=src python tools/check_bench.py [--tolerance 0.25]
        [--sections breakdown ablation quant_quality dispatch sharded
         serving preempt obs openloop longctx specdec] [--list]

Exit status 0 = no regressions; 1 = regression or missing/failed re-run.
Sections without a committed baseline are skipped with a warning
(bootstrap: the first commit of a new BENCH file establishes the baseline).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# benchmark commands, deduplicated across sections before running
COMMANDS = {
    "costmodel": [sys.executable, "benchmarks/run.py", "--only", "breakdown",
                  "ablation", "quant"],
    "sharded": [sys.executable, "benchmarks/sharded_throughput.py",
                "--smoke"],
    "dispatch": [sys.executable, "benchmarks/dispatch_overhead.py",
                 "--smoke"],
    "serving": [sys.executable, "benchmarks/serving_throughput.py",
                "--smoke"],
    "preempt": [sys.executable, "benchmarks/preempt_latency.py", "--smoke"],
    "obs": [sys.executable, "benchmarks/obs_overhead.py", "--smoke"],
    "openloop": [sys.executable, "benchmarks/openloop_load.py", "--smoke"],
    "longctx": [sys.executable, "benchmarks/longctx_selection.py", "--smoke"],
    "specdec": [sys.executable, "benchmarks/specdec_throughput.py",
                "--smoke"],
}

# (path-into-metrics, direction); direction: "lower" | "higher" | "true"
GATES = {
    "breakdown": {
        "cmd": "costmodel",
        "metrics": [
            (("llama31-8b", "freekv", "total_s"), "lower"),
            (("llama31-8b", "arkvale", "total_s"), "lower"),
            (("llama31-8b", "freekv", "recall_blocking_s"), "lower"),
            (("qwen25-7b", "freekv", "total_s"), "lower"),
        ],
    },
    "ablation": {
        "cmd": "costmodel",
        "metrics": [
            (("+HL+DB+SR(FreeKV)",), "lower"),
            (("+HL+DB",), "lower"),
            (("baseline(NHD,blocking)",), "lower"),
        ],
    },
    "quant_quality": {
        "cmd": "costmodel",
        "metrics": [
            (("none", "needle_acc"), "higher"),
            (("int8", "needle_acc"), "higher"),
            (("int8", "bytes_per_step"), "lower"),
            (("int4", "bytes_per_step"), "lower"),
            (("ratios", "int8_bytes_reduction"), "higher"),
            (("ratios", "int8_acc_drop"), "lower"),
            (("ratios", "int4_acc_drop"), "lower"),
        ],
    },
    "dispatch": {
        "cmd": "dispatch",
        "metrics": [
            # host-sync-free loop: every (scheduler, overlap, quant, tp)
            # cell bit-identical to the synchronous reference; zero bytes
            # cross the host boundary between syncs; k-step-ahead dispatch
            # amortizes syncs and collapses per-step host traffic.
            # us_per_step / dispatch_speedup are recorded, never gated.
            (("bit_identical",), "true"),
            (("dispatch", "nonsync_bytes_per_step"), "lower"),
            (("dispatch", "steps_per_sync"), "higher"),
            (("dispatch", "sync_reduction"), "higher"),
        ],
    },
    "serving": {
        "cmd": "serving",
        "metrics": [
            # continuous batching must beat static chunking and the prefix
            # cache must cut warm TTFT >= 30% — both within-run ratios.
            # ttft_p90_s / itl_p90_s are recorded, never gated (wall clock).
            (("throughput_pass",), "true"),
            (("ttft_pass",), "true"),
            (("throughput_speedup",), "higher"),
            (("ttft_reduction",), "higher"),
            (("slot_occupancy",), "higher"),
        ],
    },
    "preempt": {
        "cmd": "preempt",
        "metrics": [
            # chunked prefill + priority preemption must not change greedy
            # outputs; the p99 inter-token gap and the priority request's
            # first-token wait must improve (within-run on/off ratios);
            # swap traffic moves the packed state and conserves exactly.
            # itl_p99_reduction and the *_s quantiles are recorded, never
            # gated (run-to-run window timing noise); itl_p99_pass holds
            # the fixed >=1.25x tail-reduction bound.
            (("bit_identical",), "true"),
            (("itl_p99_pass",), "true"),
            (("priority_wait_reduction",), "higher"),
            (("preemptions",), "higher"),
            (("swap_conserved",), "true"),
            (("swap_out_bytes",), "lower"),
        ],
    },
    "obs": {
        "cmd": "obs",
        "metrics": [
            # full observability (histograms + trace) must not change the
            # math (bit_identical), add host syncs, or move bytes between
            # sync points; exported trace/snapshot must stay well-formed.
            # overhead_frac / tokens_per_s are recorded, never gated
            # (wall clock) — overhead_ok enforces the <= 5% budget.
            (("bit_identical",), "true"),
            (("overhead_ok",), "true"),
            (("host_syncs_equal",), "true"),
            (("nonsync_bytes_per_step",), "lower"),
            (("trace_valid",), "true"),
            (("snapshot_valid",), "true"),
        ],
    },
    "openloop": {
        "cmd": "openloop",
        "metrics": [
            # every greedy token stream through the HTTP front-end must be
            # bit-identical to the direct-engine run; the live /metrics +
            # /stats endpoints must validate mid-load; serving over HTTP
            # must add zero bytes between host syncs; at the lowest offered
            # load every request meets the (generous) smoke SLO. The
            # per-point TTFT/ITL quantiles and goodput tok/s are recorded,
            # never gated (wall clock).
            (("frontend_bit_identical",), "true"),
            (("endpoints_valid",), "true"),
            (("completed_all",), "true"),
            (("nonsync_bytes_per_step",), "lower"),
            (("slo_attainment_low_load",), "higher"),
            (("load_points",), "higher"),
        ],
    },
    "longctx": {
        "cmd": "longctx",
        "metrics": [
            # centroid-then-token selection: serving with correction on must
            # stay bit-identical to freekv across overlap x quant x tp; the
            # 256K selection-scan byte reduction must hold >= 4x; planted
            # needles must be retrieved within 1% of the exact scan; the
            # 1M extrapolation ratio and the overlap hidden fraction are
            # counts-based (machine-independent). us_* are recorded, never
            # gated (analytic here, but the convention is wall-clock-free).
            (("bit_identical",), "true"),
            (("reduction_ge_4x",), "true"),
            (("needle_within_1pct",), "true"),
            (("reduction_256k",), "higher"),
            (("needle_acc_centroid_256k",), "higher"),
            (("extrapolated_1m", "scan_reduction"), "higher"),
            (("hidden_fraction",), "higher"),
        ],
    },
    "specdec": {
        "cmd": "specdec",
        "metrics": [
            # speculative decoding: every draft_len x overlap x quant x tp
            # cell (and the hinted throughput run) must stay bit-identical
            # to the non-speculative synchronous reference; the oracle-hint
            # decode-attributed speedup must hold >= 1.5x; accept rate and
            # tokens per target step are within-run ratios. Raw tok/s and
            # wall_speedup are recorded, never gated (CI runners differ).
            (("bit_identical",), "true"),
            (("speedup_ge_1p5x",), "true"),
            (("accept_rate",), "higher"),
            (("tokens_per_step",), "higher"),
        ],
    },
    "sharded": {
        "cmd": "sharded",
        "metrics": [
            (("bit_identical",), "true"),
            (("configs", "overlap=1/quant=none", "bit_identical"), "true"),
            (("configs", "overlap=1/quant=int8", "bit_identical"), "true"),
            (("configs", "overlap=1/quant=none",
              "per_shard_sync_reduction"), "higher"),
            (("configs", "overlap=1/quant=int8",
              "per_shard_sync_reduction"), "higher"),
        ],
    },
}


def bench_path(section: str) -> str:
    return os.path.join(ROOT, f"BENCH_{section}.json")


def load_metrics(section: str):
    path = bench_path(section)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f).get("metrics")


def dig(tree, path):
    for k in path:
        if not isinstance(tree, dict) or k not in tree:
            return None
        tree = tree[k]
    return tree


def check_metric(path, direction, base, new, tol):
    """Returns (ok, message)."""
    label = ".".join(path)
    if new is None:
        return False, f"{label}: missing from re-run"
    if base is None:
        return True, f"{label}: no baseline (skipped)"
    if direction == "true":
        ok = bool(new)
        return ok, f"{label}: {new} (must be true)"
    base, new = float(base), float(new)
    if direction == "lower":
        allowed = base * (1 + tol) if base > 0 else tol
        ok = new <= allowed
        arrow = "<="
    else:                                  # higher
        allowed = base * (1 - tol)
        ok = new >= allowed
        arrow = ">="
    return ok, (f"{label}: {new:.6g} {arrow} {allowed:.6g} "
                f"(baseline {base:.6g}, tol {tol:.0%})")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional regression (default 0.25)")
    ap.add_argument("--sections", nargs="*", default=None,
                    help=f"subset of {sorted(GATES)}")
    ap.add_argument("--list", action="store_true",
                    help="print the gated metrics and exit")
    args = ap.parse_args()
    sections = args.sections or sorted(GATES)
    unknown = set(sections) - set(GATES)
    if unknown:
        print(f"unknown sections: {sorted(unknown)}", file=sys.stderr)
        return 1
    if args.list:
        for s in sections:
            for path, d in GATES[s]["metrics"]:
                print(f"{s}: {'.'.join(path)} [{d}]")
        return 0

    baselines = {s: load_metrics(s) for s in sections}
    missing = [s for s in sections if baselines[s] is None]
    for s in missing:
        print(f"WARNING: no committed BENCH_{s}.json — section skipped "
              "(first run establishes the baseline)")
    sections = [s for s in sections if baselines[s] is not None]
    if not sections:
        print("nothing to gate")
        return 0

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    for cmd_key in sorted({GATES[s]["cmd"] for s in sections}):
        cmd = COMMANDS[cmd_key]
        print(f"$ {' '.join(cmd)}")
        r = subprocess.run(cmd, cwd=ROOT, env=env)
        if r.returncode != 0:
            print(f"FAIL: re-run command '{cmd_key}' exited "
                  f"{r.returncode}", file=sys.stderr)
            return 1

    failures = 0
    for s in sections:
        new = load_metrics(s)
        print(f"== {s} ==")
        for path, direction in GATES[s]["metrics"]:
            ok, msg = check_metric(path, direction, dig(baselines[s], path),
                                   dig(new, path), args.tolerance)
            print(f"  [{'ok' if ok else 'REGRESSION'}] {msg}")
            failures += 0 if ok else 1
    if failures:
        print(f"\n{failures} benchmark regression(s) beyond "
              f"{args.tolerance:.0%} tolerance", file=sys.stderr)
        return 1
    print("\nall gated benchmark metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
