#!/usr/bin/env python
"""Validate observability exporter artifacts against their schemas.

Checks (exit 1 on any problem; paths default to the CI smoke artifacts):

* ``--metrics PATH`` — a JSONL file of ``MetricsRegistry.snapshot_line()``
  dicts: every line must parse as JSON and pass
  :func:`repro.obs.validate_snapshot` (schema_version, section shapes,
  histogram bucket invariants).
* ``--trace PATH`` — a Chrome-trace JSON: must parse and pass
  :func:`repro.obs.validate_chrome_trace` (the same well-formedness
  Perfetto's loader needs: traceEvents list, ph/pid/name per event,
  non-negative durations on complete events).
* ``--prom PATH`` — a Prometheus text exposition: every non-comment line
  must be ``name[{labels}] value`` with a finite numeric value, and every
  ``# TYPE`` must be counter/gauge/histogram.
* ``--stats PATH`` — a sliding-window time-series snapshot (the
  ``GET /stats`` payload): must parse and pass
  :func:`repro.obs.validate_timeseries_snapshot` (schema_version,
  finite fields, p50 <= p90 <= p99, window counts <= totals).
* ``--url http://HOST:PORT`` — a LIVE ``--serve-http`` front-end: fetches
  ``/healthz``, ``/metrics`` and ``/stats`` and runs the Prometheus and
  time-series checks on the responses.

    PYTHONPATH=src python tools/check_obs.py --metrics m.jsonl \
        --trace t.json [--prom m.prom] [--stats s.json] \
        [--url http://127.0.0.1:8008]

The exporter formats are documented in docs/observability.md.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.obs import (validate_chrome_trace,  # noqa: E402
                       validate_snapshot, validate_timeseries_snapshot)


def check_metrics_jsonl(path: str) -> list:
    errors = []
    with open(path, encoding="utf-8") as f:
        lines = [ln for ln in f if ln.strip()]
    if not lines:
        return [f"{path}: empty"]
    for i, ln in enumerate(lines, 1):
        try:
            snap = json.loads(ln)
        except json.JSONDecodeError as e:
            errors.append(f"{path}:{i}: invalid JSON ({e})")
            continue
        errors.extend(f"{path}:{i}: {e}" for e in validate_snapshot(snap))
    return errors


def check_trace(path: str) -> list:
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        return [f"{path}: unreadable ({e})"]
    return [f"{path}: {e}" for e in validate_chrome_trace(doc)]


def check_prometheus(path: str) -> list:
    with open(path, encoding="utf-8") as f:
        lines = f.read().splitlines()
    return _prometheus_lines(lines, path)


def _prometheus_lines(lines: list, path: str) -> list:
    errors = []
    if not lines:
        return [f"{path}: empty"]
    for i, ln in enumerate(lines, 1):
        if not ln.strip():
            continue
        if ln.startswith("# TYPE "):
            kind = ln.split()[-1]
            if kind not in ("counter", "gauge", "histogram"):
                errors.append(f"{path}:{i}: unknown metric type {kind!r}")
            continue
        if ln.startswith("#"):
            continue
        parts = ln.rsplit(" ", 1)
        if len(parts) != 2:
            errors.append(f"{path}:{i}: not 'name value'")
            continue
        try:
            v = float(parts[1])
        except ValueError:
            errors.append(f"{path}:{i}: non-numeric value {parts[1]!r}")
            continue
        if not math.isfinite(v) and "+Inf" not in parts[1]:
            errors.append(f"{path}:{i}: non-finite value")
    return errors


def check_stats(path: str) -> list:
    try:
        with open(path, encoding="utf-8") as f:
            snap = json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        return [f"{path}: unreadable ({e})"]
    return [f"{path}: {e}" for e in validate_timeseries_snapshot(snap)]


def check_url(url: str) -> list:
    """Validate a live ``--serve-http`` front-end: /healthz liveness,
    /metrics Prometheus exposition, /stats time-series snapshot."""
    import urllib.error
    import urllib.request
    url = url.rstrip("/")
    errors = []

    def fetch(path):
        with urllib.request.urlopen(url + path, timeout=30.0) as r:
            return r.status, r.read().decode()

    try:
        st, body = fetch("/healthz")
        health = json.loads(body)
        if st != 200 or not health.get("ok"):
            errors.append(f"{url}/healthz: status {st}, body {body!r}")
        st, body = fetch("/metrics")
        if st != 200:
            errors.append(f"{url}/metrics: status {st}")
        else:
            errors.extend(_prometheus_lines(body.splitlines(),
                                            f"{url}/metrics"))
        st, body = fetch("/stats")
        if st != 200:
            errors.append(f"{url}/stats: status {st}")
        else:
            errors.extend(f"{url}/stats: {e}" for e in
                          validate_timeseries_snapshot(json.loads(body)))
    except (urllib.error.URLError, OSError, json.JSONDecodeError) as e:
        errors.append(f"{url}: unreachable/unparseable ({e})")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--metrics", default=None,
                    help="JSONL metrics-registry snapshot file")
    ap.add_argument("--trace", default=None,
                    help="Chrome-trace/Perfetto JSON file")
    ap.add_argument("--prom", default=None,
                    help="Prometheus text exposition file")
    ap.add_argument("--stats", default=None,
                    help="sliding-window time-series snapshot JSON file "
                         "(the GET /stats payload)")
    ap.add_argument("--url", default=None, metavar="http://HOST:PORT",
                    help="validate a live --serve-http front-end "
                         "(/healthz, /metrics, /stats)")
    args = ap.parse_args()
    if not (args.metrics or args.trace or args.prom or args.stats
            or args.url):
        ap.error("nothing to check: pass --metrics / --trace / --prom "
                 "/ --stats / --url")

    errors = []
    for path, fn, label in ((args.metrics, check_metrics_jsonl, "metrics"),
                            (args.trace, check_trace, "trace"),
                            (args.prom, check_prometheus, "prometheus"),
                            (args.stats, check_stats, "stats")):
        if path is None:
            continue
        if not os.path.exists(path):
            errors.append(f"{label}: {path} does not exist")
            continue
        errs = fn(path)
        errors.extend(errs)
        print(f"{label}: {path} — "
              f"{'OK' if not errs else f'{len(errs)} problem(s)'}")
    if args.url:
        errs = check_url(args.url)
        errors.extend(errs)
        print(f"live: {args.url} — "
              f"{'OK' if not errs else f'{len(errs)} problem(s)'}")
    for e in errors:
        print(f"  {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
