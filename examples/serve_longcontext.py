"""End-to-end serving driver: batched long-context requests, comparing KV
retrieval methods (full / quest / arkvale / freekv) on identical prompts —
greedy outputs, per-step decode latency, retrieval statistics — under the
continuous-batching scheduler (``--scheduler static`` for the chunked path).

    PYTHONPATH=src python examples/serve_longcontext.py [--context 512]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.data.synthetic import needle_stream
from repro.models.model import init_params
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--context", type=int, default=512)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--scheduler", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--prefix-cache-tokens", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config("granite-3-8b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    page = 16
    needle = needle_stream(cfg.vocab_size, args.context, page, seed=1)
    prompts = [next(needle).tokens for _ in range(args.batch)]

    budget = max(96, args.context // 4 // page * page)
    methods = {
        "full": FreeKVConfig(method="full"),
        "quest": FreeKVConfig(method="quest", page_size=page, budget=budget,
                              n_sink=page * 2, n_window=page * 2),
        "arkvale": FreeKVConfig(method="arkvale", page_size=page,
                                budget=budget, n_sink=page * 2,
                                n_window=page * 2),
        "freekv": FreeKVConfig(method="freekv", page_size=page, budget=budget,
                               n_sink=page * 2, n_window=page * 2, tau=0.8),
    }
    ref = None
    for name, fkv in methods.items():
        eng = ServeEngine(cfg, fkv, params,
                          max_len=args.context + args.new_tokens + page + 64,
                          batch_size=args.batch, scheduler=args.scheduler,
                          prefix_cache_tokens=args.prefix_cache_tokens)
        reqs = [Request(uid=i, tokens=p, max_new_tokens=args.new_tokens)
                for i, p in enumerate(prompts)]
        outs = eng.generate(reqs)
        toks = outs[0].tokens
        if name == "full":
            ref = toks
        agree = (np.mean([a == b for a, b in zip(toks, ref)])
                 if ref else float("nan"))
        o = outs[0]
        em = eng.last_metrics
        print(f"{name:8s} step={o.decode_s/max(o.steps, 1)*1e3:7.1f} ms "
              f"match_vs_full={agree:.2f} "
              f"corr_rate={o.stats.get('correction_rate', 0):.3f} "
              f"occupancy={em.slot_occupancy if em else 0:.2f} "
              f"tokens={toks[:8]}...")


if __name__ == "__main__":
    main()
