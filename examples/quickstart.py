"""Quickstart: FreeKV serving on CPU with a reduced model.

    PYTHONPATH=src python examples/quickstart.py [--kv-quant int8]
        [--draft-len 4]

``--kv-quant`` stores the offloaded KV pool at int8 / packed int4 with fused
dequant-on-recall (src/repro/quant) — the completion prints the recall-bytes
saving and host-pool compression from ``EngineMetrics.summary()["kv_quant"]``.

``--draft-len N`` turns on speculative decoding: an on-device bigram drafter
proposes N tokens per step and one batched verify pass commits the longest
greedy-consistent prefix — outputs are bit-identical to ``--draft-len 0``,
and the run prints the accept rate + tokens per target step from
``EngineMetrics.summary()["specdec"]``.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.models.model import init_params
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--kv-quant", choices=("none", "int8", "int4"),
                    default="none",
                    help="quantized host KV tier for the offloaded pool")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="tag requests with a TTFT SLO (ms); prints the "
                         "attainment + goodput line from summary()['slo']")
    ap.add_argument("--slo-itl-ms", type=float, default=None,
                    help="mean inter-token-latency SLO (ms)")
    ap.add_argument("--draft-len", type=int, default=0,
                    help="speculative decoding: drafted tokens per verify "
                         "step (0 = off; outputs bit-identical either way)")
    ap.add_argument("--no-spec-decode", action="store_true",
                    help="force draft_len=0 regardless of --draft-len")
    args = ap.parse_args()

    cfg = get_config("smollm-360m-smoke")          # reduced llama-style model
    params = init_params(cfg, jax.random.PRNGKey(0))
    fkv = FreeKVConfig(method="freekv", page_size=8, budget=64, n_sink=8,
                       n_window=8, tau=0.8, kv_quant=args.kv_quant,
                       draft_len=0 if args.no_spec_decode else args.draft_len)
    engine = ServeEngine(cfg, fkv, params, max_len=256, batch_size=2,
                         slo_ttft_ms=args.slo_ttft_ms,
                         slo_itl_ms=args.slo_itl_ms)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 80).astype(np.int32)
               for _ in range(2)]
    reqs = [Request(uid=i, tokens=p, max_new_tokens=16)
            for i, p in enumerate(prompts)]
    for out in engine.generate(reqs):
        print(f"request {out.uid}: {out.tokens}")
        print(f"  prefill {out.prefill_s*1e3:.1f} ms, "
              f"decode {out.decode_s/out.steps*1e3:.1f} ms/step, "
              f"correction_rate={out.stats['correction_rate']:.3f}, "
              f"query_similarity={out.stats['mean_similarity']:.3f}")
    sd = engine.last_metrics.specdec_summary()
    if sd["draft_len"] > 0:
        print(f"spec-decode (draft_len={sd['draft_len']}): accept rate "
              f"{sd['accept_rate']:.3f}, {sd['tokens_per_step']:.2f} tokens "
              f"per target step")
    kq = engine.last_metrics.summary()["kv_quant"]
    if kq["mode"] != "none":
        print(f"kv_quant={kq['mode']}: block {kq['dense_block_bytes']} -> "
              f"{kq['page_block_bytes']} B, saved {kq['bytes_saved']:.0f} B "
              f"transfer, pool compression {kq['pool_compression']:.2f}x")
    slo = engine.last_metrics.slo_summary()
    if slo["tagged"]:
        print(f"SLO (ttft<={slo['ttft_ms']}ms, itl<={slo['itl_ms']}ms): "
              f"{slo['attained']}/{slo['tagged']} attained "
              f"({slo['attainment']:.1%}), goodput "
              f"{slo['goodput_tokens_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
