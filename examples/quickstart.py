"""Quickstart: FreeKV serving on CPU with a reduced model.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.models.model import init_params
from repro.serving.engine import Request, ServeEngine


def main():
    cfg = get_config("smollm-360m-smoke")          # reduced llama-style model
    params = init_params(cfg, jax.random.PRNGKey(0))
    fkv = FreeKVConfig(method="freekv", page_size=8, budget=64, n_sink=8,
                       n_window=8, tau=0.8)
    engine = ServeEngine(cfg, fkv, params, max_len=256, batch_size=2)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 80).astype(np.int32)
               for _ in range(2)]
    reqs = [Request(uid=i, tokens=p, max_new_tokens=16)
            for i, p in enumerate(prompts)]
    for out in engine.generate(reqs):
        print(f"request {out.uid}: {out.tokens}")
        print(f"  prefill {out.prefill_s*1e3:.1f} ms, "
              f"decode {out.decode_s/out.steps*1e3:.1f} ms/step, "
              f"correction_rate={out.stats['correction_rate']:.3f}, "
              f"query_similarity={out.stats['mean_similarity']:.3f}")


if __name__ == "__main__":
    main()
