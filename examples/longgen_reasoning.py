"""Long-generation scenario (the paper's reasoning-model case): short prompt,
long decode, correction statistics under different tau — shows speculative
retrieval's correction machinery at work.

    PYTHONPATH=src python examples/longgen_reasoning.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.models.model import init_params
from repro.serving.engine import Request, ServeEngine
from repro.serving.sampling import SamplerConfig


def main():
    cfg = get_config("smollm-360m-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 48).astype(np.int32)
    for tau in (0.8, 0.9):
        fkv = FreeKVConfig(method="freekv", page_size=8, budget=96, n_sink=16,
                           n_window=16, tau=tau)
        eng = ServeEngine(cfg, fkv, params, max_len=512, batch_size=1,
                          sampler=SamplerConfig(temperature=0.6, top_p=0.95))
        out = eng.generate([Request(uid=0, tokens=prompt,
                                    max_new_tokens=96)])[0]
        print(f"tau={tau}: generated {len(out.tokens)} tokens, "
              f"correction_rate={out.stats['correction_rate']:.3f}, "
              f"mean_query_similarity={out.stats['mean_similarity']:.3f}, "
              f"{out.decode_s/out.steps*1e3:.1f} ms/step")


if __name__ == "__main__":
    main()
