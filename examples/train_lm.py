"""Train a small LM on the synthetic pipeline for a few hundred steps with
checkpointing — the training-substrate driver.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import lm_batches
from repro.training import checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m-smoke")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt.npz")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    params, opt_state = init_train(cfg, opt, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params")
    step = jax.jit(make_train_step(cfg, opt))
    data = lm_batches(cfg.vocab_size, args.seq, args.batch, seed=0)
    t0 = time.time()
    for i in range(args.steps):
        params, opt_state, m = step(params, opt_state,
                                    {"tokens": jnp.asarray(next(data))})
        if i % 20 == 0 or i == args.steps - 1:
            tput = args.batch * args.seq * (i + 1) / (time.time() - t0)
            print(f"step {i:4d} loss={float(m['loss']):.3f} "
                  f"lr={float(m['lr']):.2e} grad_norm={float(m['grad_norm']):.2f} "
                  f"tok/s={tput:.0f}")
    checkpoint.save(args.ckpt, {"params": params, "opt": opt_state})
    print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
