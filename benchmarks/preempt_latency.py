"""Preemption/chunked-prefill latency benchmark: tail ITL + priority wait.

Two experiments on synthetic traffic (CPU smoke arch; wall-clock numbers are
CPU-relative, the *within-run ratios* are the result):

1. **chunked prefill, tail ITL** — short decode-heavy requests co-batched
   with one long-prefill request. Whole-shot admission stalls the running
   decoders for the entire prefill: one huge inter-token gap that the
   per-request ITL *mean* averages away but the always-on per-token gap
   histogram (``request_token_gap_seconds``) exposes at p99. With
   ``prefill_chunk_tokens`` the prefill interleaves with decode windows, so
   the p99 gap drops to ~one chunk's compute. Outputs must stay
   bit-identical (the final chunk rebuilds the decode state from the full
   accumulated K/V).
2. **priority preemption, first-token wait** — a strictly-higher-priority
   request queued behind a long low-priority decode on a full pool. FIFO
   admission makes it wait out the whole decode; with ``preempt`` the
   victim's paged KV swaps to the host tier (packed quantized width), the
   priority request takes the slot immediately, and the victim resumes
   bit-identically. Swap byte counts are deterministic state sizes (gated
   "lower"); swap-in must equal swap-out exactly.

``--smoke`` runs the CI preset and writes ``BENCH_preempt.json`` at the repo
root — the committed baseline ``tools/check_bench.py`` gates: bit_identical,
itl_p99_reduction, priority_wait_reduction, preemptions, swap byte counts.

    PYTHONPATH=src python benchmarks/preempt_latency.py [--smoke]
        [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.models.model import init_params
from repro.serving.engine import Request, ServeEngine
from repro.serving.sampling import SamplerConfig


def make_engine(cfg, params, args, slots, **fkv_kw):
    fkv = FreeKVConfig(method=args.method, page_size=args.page_size,
                       budget=args.budget, n_sink=args.page_size,
                       n_window=args.page_size, tau=0.8, **fkv_kw)
    return ServeEngine(cfg, fkv, params,
                       max_len=args.long_context + args.long_new
                       + 2 * args.bucket,
                       batch_size=slots,
                       sampler=SamplerConfig(temperature=0.0),
                       prefill_bucket=args.bucket)


def chunk_requests(cfg, args, seed=0):
    """Decode-heavy short requests + one long-prefill straggler between
    them: whole-shot admission of the straggler stalls the running lane."""
    rng = np.random.default_rng(seed)
    short = lambda uid: Request(  # noqa: E731
        uid=uid, tokens=rng.integers(0, cfg.vocab_size, args.context)
        .astype(np.int32), max_new_tokens=args.short_new)
    long_req = Request(uid=1, tokens=rng.integers(
        0, cfg.vocab_size, args.long_context).astype(np.int32),
        max_new_tokens=args.long_new)
    return [short(0), long_req, short(2)]


def run_chunked(cfg, params, args):
    print("== experiment 1: long prefill vs co-batched decode tail ITL ==")
    out = {}
    for label, chunk in (("off", 0), ("on", args.chunk)):
        eng = make_engine(cfg, params, args, slots=2,
                          prefill_chunk_tokens=chunk)
        reqs = chunk_requests(cfg, args)
        eng.generate(reqs)                      # warmup: compile all shapes
        outs = eng.generate(reqs)               # measured
        em = eng.last_metrics
        s = em.summary()
        gap = s["scheduling"]["token_gap_s"]
        out[label] = {"tokens": [c.tokens for c in outs],
                      "itl_p99_s": gap["p99"], "itl_max_s": gap["max"],
                      "prefill_chunks": em.prefill_chunks,
                      "prefill_chunk_tokens": em.prefill_chunk_tokens,
                      "tokens_per_s": s["tokens_per_s"]}
        print(f"  chunk={'%4d' % chunk if chunk else ' off'} "
              f"itl_p99={gap['p99']*1e3:8.1f}ms "
              f"itl_max={gap['max']*1e3:8.1f}ms "
              f"chunks={em.prefill_chunks}")
    ident = out["on"]["tokens"] == out["off"]["tokens"]
    red = out["off"]["itl_p99_s"] / max(out["on"]["itl_p99_s"], 1e-9)
    ok = red >= 1.25
    print(f"  p99 inter-token gap reduction: {red:.2f}x "
          f"[{'PASS' if ok else 'FAIL'}: chunked must cut the tail "
          f">= 25%] bit_identical={ident}")
    out["itl_p99_reduction"] = red
    out["itl_p99_pass"] = bool(ok)
    out["bit_identical"] = bool(ident)
    return out


def run_preempt(cfg, params, args, seed=3):
    print("== experiment 2: strict-priority preemption, first-token wait ==")
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=0, tokens=rng.integers(0, cfg.vocab_size,
                                               args.context)
                    .astype(np.int32),
                    max_new_tokens=args.victim_new, priority=0),
            Request(uid=1, tokens=rng.integers(0, cfg.vocab_size,
                                               args.context)
                    .astype(np.int32),
                    max_new_tokens=args.short_new, priority=1)]
    out = {}
    for label, preempt in (("off", False), ("on", True)):
        eng = make_engine(cfg, params, args, slots=1, preempt=preempt)
        eng.generate(reqs)                      # warmup: compile all shapes
        outs = eng.generate(reqs)               # measured
        em = eng.last_metrics
        hi = next(m for m in em.requests if m.uid == 1)
        out[label] = {"tokens": [c.tokens for c in outs],
                      "priority_ttft_s": hi.ttft_s,
                      "preemptions": em.preemptions,
                      "resumes": em.resumes,
                      "swap_out_bytes": em.swap_out_bytes,
                      "swap_in_bytes": em.swap_in_bytes}
        print(f"  preempt={label:3s} priority-ttft="
              f"{hi.ttft_s*1e3:8.1f}ms preemptions={em.preemptions} "
              f"swap={em.swap_out_bytes/1e3:.1f}kB")
    ident = out["on"]["tokens"] == out["off"]["tokens"]
    red = (out["off"]["priority_ttft_s"]
           / max(out["on"]["priority_ttft_s"], 1e-9))
    fired = out["on"]["preemptions"] >= 1
    conserved = out["on"]["swap_out_bytes"] == out["on"]["swap_in_bytes"]
    print(f"  priority first-token wait reduction: {red:.2f}x "
          f"[{'PASS' if red > 1 and fired else 'FAIL'}] "
          f"bit_identical={ident} swap_conserved={conserved}")
    out["priority_wait_reduction"] = red
    out["bit_identical"] = bool(ident)
    out["swap_conserved"] = bool(conserved)
    return out


SMOKE = dict(context=64, long_context=384, short_new=24, long_new=4,
             victim_new=32, chunk=64, bucket=64, page_size=8, budget=64)


def main():
    from _common import bench_json
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m-smoke")
    ap.add_argument("--method", default="freekv")
    ap.add_argument("--context", type=int, default=64)
    ap.add_argument("--long-context", type=int, default=512)
    ap.add_argument("--short-new", type=int, default=32)
    ap.add_argument("--long-new", type=int, default=4)
    ap.add_argument("--victim-new", type=int, default=48)
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--bucket", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--budget", type=int, default=64)
    ap.add_argument("--json", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized preset — writes BENCH_preempt.json")
    args = ap.parse_args()
    if args.smoke:
        for k, v in SMOKE.items():
            setattr(args, k, v)

    cfg = get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    results = {"args": vars(args),
               "chunked": run_chunked(cfg, params, args),
               "preempt": run_preempt(cfg, params, args)}
    if args.smoke:
        ch, pr = results["chunked"], results["preempt"]
        metrics = {
            "bit_identical": bool(ch["bit_identical"]
                                  and pr["bit_identical"]),
            # the fixed >=1.25x bound is the gate; the raw ratio is noisy
            # across runs (window timing) and recorded for trends only
            "itl_p99_pass": ch["itl_p99_pass"],
            "itl_p99_reduction": ch["itl_p99_reduction"],
            "priority_wait_reduction": pr["priority_wait_reduction"],
            "preemptions": pr["on"]["preemptions"],
            "swap_conserved": pr["swap_conserved"],
            # deterministic state size: gate "lower" so the swap unit can
            # only shrink (e.g. a packed-width regression would grow it)
            "swap_out_bytes": pr["on"]["swap_out_bytes"],
            # wall-clock quantiles recorded for trend-watching only
            "itl_p99_on_s": ch["on"]["itl_p99_s"],
            "itl_p99_off_s": ch["off"]["itl_p99_s"],
        }
        bench_json("preempt", {"arch": args.arch, "method": args.method,
                               **SMOKE}, metrics)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, default=str)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
