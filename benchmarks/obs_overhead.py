"""Observability overhead + exporter-validity benchmark.

Runs the same decode-dominated continuous-batching workload twice on the
host-sync-free loop (``sync_interval=8``):

* **obs off** — ``Observability.off()``: registry counters only (they are
  the engine's bookkeeping and always run), no histograms, no trace.
* **obs on (full)** — per-step latency + speculation-quality histograms
  AND the Chrome-trace/Perfetto recorder capturing the request lifecycle,
  decode windows/steps and recall-pipeline spans.

Gated results (``tools/check_bench.py``):

* **bit_identical** — greedy token streams must match exactly: telemetry
  is pulled from ``decode_window``'s device-side stat blocks at sync
  boundaries and never touches the math.
* **overhead_ok** — full observability costs <= 5% tokens/s (best-of-N
  walls; the raw fraction is recorded but never gated — runners differ).
* **nonsync_bytes_per_step == 0** and **host_syncs_equal** — turning
  observability on adds ZERO host syncs and zero bytes between sync
  points: speculation telemetry rides the existing (k, B) stat blocks.
* **trace_valid / snapshot_valid** — the emitted trace JSON is
  well-formed Chrome-trace (loads in Perfetto) and the metrics snapshot
  matches the schema in docs/observability.md; both are also written to
  ``--artifacts`` for CI upload.

    PYTHONPATH=src python benchmarks/obs_overhead.py [--smoke]
        [--artifacts DIR]

Writes the ``BENCH_obs.json`` trajectory file (schema: _common.bench_json).
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.configs.base import FreeKVConfig  # noqa: E402
from repro.models.model import init_params  # noqa: E402
from repro.obs import (Observability, TraceRecorder,  # noqa: E402
                       validate_chrome_trace, validate_snapshot)
from repro.serving.engine import Request, ServeEngine  # noqa: E402
from repro.serving.sampling import SamplerConfig  # noqa: E402

SMOKE = dict(arch="granite-3-8b-smoke", context=64, requests=4, slots=2,
             new_tokens=48, page_size=8, budget=48, repeats=5)
FULL = dict(arch="granite-3-8b-smoke", context=256, requests=8, slots=4,
            new_tokens=96, page_size=16, budget=96, repeats=5)

OVERHEAD_BUDGET = 0.05


def make_requests(cfg, context, n, new_tokens, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        context).astype(np.int32),
                    max_new_tokens=new_tokens)
            for i in range(n)]


def run(arch, context, requests, slots, new_tokens, page_size, budget,
        repeats, artifacts=None, quiet=False):
    cfg = get_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    fkv = FreeKVConfig(method="freekv", page_size=page_size, budget=budget,
                       n_sink=page_size, n_window=page_size, tau=0.8,
                       sync_interval=8)
    max_len = context + new_tokens + page_size
    mk = lambda: make_requests(cfg, context, requests, new_tokens)  # noqa: E731

    best, tokens, engines = {}, {}, {}
    for mode in ("off", "on"):
        obs = (Observability.off() if mode == "off" else
               Observability(enabled=True, trace=TraceRecorder(enabled=True)))
        engines[mode] = ServeEngine(cfg, fkv, params, max_len=max_len,
                                    batch_size=slots,
                                    sampler=SamplerConfig(temperature=0.0),
                                    scheduler="continuous", obs=obs)
        engines[mode].generate(mk())            # warmup: compile all shapes
    # interleave the timed repeats (off, on, off, on, ...) and take the
    # best wall per mode: drifting background load on shared CI runners
    # then hits both modes alike instead of biasing one phase
    for _ in range(repeats):
        for mode in ("off", "on"):
            eng = engines[mode]
            if mode == "on":
                # fresh recorder so the artifact trace covers one run
                eng.obs.trace = TraceRecorder(enabled=True)
            outs = eng.generate(mk())
            s = eng.last_metrics.summary()
            if mode not in best or s["wall_s"] < best[mode]["wall_s"]:
                best[mode] = s
            tokens[mode] = [c.tokens for c in outs]
    if not quiet:
        for mode in ("off", "on"):
            print(f"  obs={mode:3s} tok/s={best[mode]['tokens_per_s']:8.2f} "
                  f"wall={best[mode]['wall_s']:6.3f}s "
                  f"host_syncs={best[mode]['dispatch']['host_syncs']}")

    on, off = best["on"], best["off"]
    overhead = on["wall_s"] / max(off["wall_s"], 1e-9) - 1.0
    em_on = engines["on"].last_metrics
    obs_on = engines["on"].obs

    snap = em_on.registry.snapshot()
    snap_errs = validate_snapshot(snap)
    trace_doc = obs_on.trace.chrome_trace()
    trace_errs = validate_chrome_trace(trace_doc)
    if artifacts:
        os.makedirs(artifacts, exist_ok=True)
        em_on.registry.write_jsonl(os.path.join(artifacts,
                                                "obs_metrics.jsonl"),
                                   extra={"arch": arch, "bench": "obs"})
        with open(os.path.join(artifacts, "obs_metrics.prom"), "w",
                  encoding="utf-8") as f:
            f.write(em_on.registry.to_prometheus())
        obs_on.trace.write(os.path.join(artifacts, "obs_trace.json"))
        if not quiet:
            print(f"  artifacts -> {artifacts}/ (obs_metrics.jsonl, "
                  "obs_metrics.prom, obs_trace.json)")

    spec = on["speculation"]
    metrics = {
        "bit_identical": tokens["on"] == tokens["off"],
        "tokens_per_s_off": off["tokens_per_s"],
        "tokens_per_s_on": on["tokens_per_s"],
        "overhead_frac": overhead,
        "overhead_ok": overhead <= OVERHEAD_BUDGET,
        "host_syncs_off": off["dispatch"]["host_syncs"],
        "host_syncs_on": on["dispatch"]["host_syncs"],
        "host_syncs_equal": (on["dispatch"]["host_syncs"]
                             == off["dispatch"]["host_syncs"]),
        "nonsync_bytes_per_step": on["dispatch"]["nonsync_bytes_per_step"],
        "trace_valid": not trace_errs,
        "trace_events": len(trace_doc["traceEvents"]),
        "snapshot_valid": not snap_errs,
        "spec_hit_rate_count": spec["hit_rate"]["count"],
        "spec_hit_rate_mean": spec["hit_rate_mean"],
        "correction_rate_count": spec["correction_rate"]["count"],
        "decode_step_count": on["latency"]["decode_step_s"]["count"],
    }
    if trace_errs and not quiet:
        print(f"  trace errors: {trace_errs[:5]}")
    if snap_errs and not quiet:
        print(f"  snapshot errors: {snap_errs[:5]}")
    return metrics


def main():
    from _common import bench_json
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run — still writes BENCH_obs.json")
    ap.add_argument("--artifacts", default=None, metavar="DIR",
                    help="write metrics snapshot (JSONL + Prometheus) and "
                         "trace JSON here for CI artifact upload")
    ap.add_argument("--no-json", action="store_true")
    args = ap.parse_args()
    config = dict(SMOKE) if args.smoke else dict(FULL)
    print("== observability overhead: obs off vs full (hist + trace) ==")
    res = run(**config, artifacts=args.artifacts)
    ok = (res["bit_identical"] and res["overhead_ok"]
          and res["host_syncs_equal"] and res["nonsync_bytes_per_step"] == 0
          and res["trace_valid"] and res["snapshot_valid"])
    print(f"bit_identical={res['bit_identical']} "
          f"overhead={res['overhead_frac']*100:+.1f}% "
          f"(budget {OVERHEAD_BUDGET*100:.0f}%) "
          f"host_syncs_equal={res['host_syncs_equal']} "
          f"nonsync_B/step={res['nonsync_bytes_per_step']:.1f} "
          f"trace_valid={res['trace_valid']} "
          f"snapshot_valid={res['snapshot_valid']} "
          f"[{'PASS' if ok else 'FAIL'}]")
    if not args.no_json:
        bench_json("obs", config, res)
    if not ok:
        sys.exit(1)
    return res


if __name__ == "__main__":
    main()
