"""Host-sync-free decode loop: dispatch-overhead + bit-identity sweep (own
process: it forces XLA host devices for the tp=2 cells before jax
initializes).

Two measurements:

* **bit_identical** — for every cell of scheduler={continuous, static} x
  recall_overlap={on, off} x kv_quant={none, int8} x tp={1, 2}, the greedy
  token streams of the host-sync-free loop (``sync_interval=8``, on-device
  sampling, donated state) must match the synchronous per-step reference
  (``sample_on_device=False``) and the static chunked scheduler exactly.
  Any False fails CI via ``tools/check_bench.py``.

* **dispatch overhead** — a decode-dominated run measures per-step wall
  time and per-step host-boundary traffic at sync_interval 1 vs 8: steps
  per sync rises, host bytes per step collapse, and the bytes moved
  BETWEEN syncs are exactly 0 (the loop's defining property; gated).
  Wall-clock speedup is recorded but never gated (CI runners differ).

    PYTHONPATH=src python benchmarks/dispatch_overhead.py [--smoke]

Writes the ``BENCH_dispatch.json`` trajectory file (schema: _common.bench_json).
"""
import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")

import argparse
import dataclasses
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.configs.base import FreeKVConfig  # noqa: E402
from repro.models.model import init_params  # noqa: E402
from repro.serving.engine import Request, ServeEngine  # noqa: E402
from repro.serving.sampling import SamplerConfig  # noqa: E402

SMOKE = dict(arch="granite-3-8b-smoke", context=64, requests=4, slots=2,
             short_new=3, long_new=6, page_size=8, budget=48,
             timing_new=48)
FULL = dict(arch="granite-3-8b-smoke", context=256, requests=8, slots=4,
            short_new=4, long_new=12, page_size=16, budget=96,
            timing_new=128)


def equal_len_requests(cfg, context, n, short_new, long_new, seed=0):
    """Equal prompt LENGTHS (contents differ) so the static chunked path
    pads nothing and scheduler outputs are comparable bit-for-bit."""
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab_size, context
                                        ).astype(np.int32),
                    max_new_tokens=short_new if i % 2 == 0 else long_new)
            for i in range(n)]


def _engine(cfg, params, fkv, max_len, slots, scheduler, tp):
    return ServeEngine(cfg, fkv, params, max_len=max_len, batch_size=slots,
                       sampler=SamplerConfig(temperature=0.0),
                       scheduler=scheduler, tp=tp)


def identity_sweep(cfg, params, base, max_len, slots, reqs_fn, quiet):
    ident_all = True
    configs = {}
    for overlap in (True, False):
        for quant in ("none", "int8"):
            for tp in (1, 2):
                fkv = dataclasses.replace(base, recall_overlap=overlap,
                                          kv_quant=quant)
                runs = {
                    "continuous/sync": (
                        "continuous",
                        dataclasses.replace(fkv, sample_on_device=False)),
                    "continuous/k8": (
                        "continuous",
                        dataclasses.replace(fkv, sync_interval=8)),
                    "static": ("static", fkv),
                }
                tokens = {}
                for rname, (sched, f) in runs.items():
                    eng = _engine(cfg, params, f, max_len, slots, sched, tp)
                    tokens[rname] = [c.tokens for c in eng.generate(reqs_fn())]
                ref = tokens["continuous/sync"]
                ident = all(t == ref for t in tokens.values())
                ident_all &= ident
                name = (f"sched=all/overlap={int(overlap)}/quant={quant}"
                        f"/tp={tp}")
                configs[name] = {"bit_identical": bool(ident)}
                if not quiet:
                    print(f"  {name:44s} bit_identical={ident}")
    return bool(ident_all), configs


def timing_sweep(cfg, params, base, max_len, slots, context, timing_new,
                 quiet):
    """Decode-dominated single-request run: per-step wall time and
    host-boundary traffic at sync_interval 1 vs 8."""
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, context).astype(np.int32)
    out = {}
    for k in (1, 8):
        fkv = dataclasses.replace(base, sync_interval=k)
        eng = _engine(cfg, params, fkv, max_len + timing_new, slots,
                      "continuous", 1)
        mk = lambda: [Request(uid=0, tokens=prompt,  # noqa: E731
                              max_new_tokens=timing_new)]
        eng.generate(mk())                      # warmup: compile all shapes
        outs = eng.generate(mk())
        em = eng.last_metrics
        d = em.summary()["dispatch"]
        out[k] = {
            "us_per_step": 1e6 * outs[0].decode_s / max(outs[0].steps, 1),
            "steps": em.steps,
            "host_syncs": d["host_syncs"],
            "steps_per_sync": d["steps_per_sync"],
            "host_bytes_per_step": d["host_bytes_per_step"],
            "nonsync_bytes_per_step": d["nonsync_bytes_per_step"],
        }
        if not quiet:
            print(f"  sync_interval={k}: {out[k]['us_per_step']:.0f} us/step,"
                  f" {out[k]['steps_per_sync']:.2f} steps/sync,"
                  f" {out[k]['host_bytes_per_step']:.0f} B/step host traffic")
    return {
        "k1": out[1], "k8": out[8],
        "steps_per_sync": out[8]["steps_per_sync"],
        "nonsync_bytes_per_step": out[8]["nonsync_bytes_per_step"],
        # host round trips per decoded token are the dispatch-stall cost the
        # k-step-ahead loop removes (pulled BYTES stay tiny either way: the
        # block a sync pulls scales with k, so bytes/step are ~flat)
        "sync_reduction": (out[1]["host_syncs"]
                           / max(out[8]["host_syncs"], 1)),
        "host_bytes_reduction": (out[1]["host_bytes_per_step"]
                                 / max(out[8]["host_bytes_per_step"], 1e-9)),
        "dispatch_speedup": (out[1]["us_per_step"]
                             / max(out[8]["us_per_step"], 1e-9)),
    }


def run(arch, context, requests, slots, short_new, long_new, page_size,
        budget, timing_new, quiet=False):
    cfg = get_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    base = FreeKVConfig(method="freekv", page_size=page_size, budget=budget,
                        n_sink=page_size, n_window=page_size, tau=0.8)
    max_len = context + long_new + page_size
    reqs_fn = lambda: equal_len_requests(cfg, context, requests,  # noqa: E731
                                         short_new, long_new)
    ident, configs = identity_sweep(cfg, params, base, max_len, slots,
                                    reqs_fn, quiet)
    dispatch = timing_sweep(cfg, params, base, max_len, slots, context,
                            timing_new, quiet)
    return {"bit_identical": ident, "configs": configs, "dispatch": dispatch}


def main():
    from _common import bench_json
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run — still writes BENCH_dispatch.json")
    ap.add_argument("--no-json", action="store_true")
    args = ap.parse_args()
    config = dict(SMOKE) if args.smoke else dict(FULL)
    print(f"devices: {jax.devices()}")
    res = run(**config)
    status = "PASS" if res["bit_identical"] else "FAIL"
    print(f"bit_identical across dispatch sweep: {res['bit_identical']} "
          f"[{status}]")
    d = res["dispatch"]
    print(f"steps/sync {d['steps_per_sync']:.2f} | host syncs "
          f"{d['k1']['host_syncs']} -> {d['k8']['host_syncs']} "
          f"({d['sync_reduction']:.1f}x) | between-sync bytes/step "
          f"{d['nonsync_bytes_per_step']:.1f} | dispatch speedup "
          f"{d['dispatch_speedup']:.2f}x")
    if not args.no_json:
        bench_json("dispatch", config, res)
    if not res["bit_identical"]:
        sys.exit(1)
    return res


if __name__ == "__main__":
    main()
