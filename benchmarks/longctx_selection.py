"""Long-context selection sweep: exact full-scan vs centroid-then-token.

FreeKV's exact selection scans every host-pool page summary per decode step
— O(n_pages). The ``centroid`` retriever (core/centroid_index) scores the
C cluster bounding boxes first and runs exact page scoring only on the
inherited-score candidate set — O(C + candidates). This benchmark measures
what that buys at long context (own process: it forces XLA host devices for
the tp=2 cells before jax initializes):

* **selection sweep** (32K -> 256K-token pools on CPU): per-step
  selection-scan bytes + FLOPs for exact vs centroid, needle-retrieval
  accuracy of each against planted needle pages, and the fraction of the
  exact top-k the centroid selection recovers. The byte/FLOP accounting is
  analytic from counts (repo convention: the jnp paths compute full-width
  with masking; a real kernel scans only what the counts say).
* **1M-token extrapolation**: the analytic cost model (``_common.HwModel``)
  extends the measured per-step scan counts to a 1M-token pool —
  machine-independent (fixed constants), so the reduction ratio is gated.
* **engine bit-identity cells**: ``retriever="centroid"`` vs
  ``retriever="freekv"`` greedy token streams over
  overlap={on,off} x kv_quant={none,int8} x tp={1,2} — correction-on
  centroid serving must be bit-identical to freekv on the smoke config
  (any False fails CI via tools/check_bench.py).
* **recall-overlap hidden fraction**: a decode-dominated centroid run
  reports how much recall traffic the speculative stream hides
  (EngineMetrics.summary()["recall_overlap"]); with ``--artifacts DIR`` it
  also writes the metrics snapshot + Perfetto trace for the nightly job
  (validated by tools/check_obs.py).

    PYTHONPATH=src python benchmarks/longctx_selection.py [--smoke]
        [--artifacts DIR] [--no-json]

Writes the ``BENCH_longctx.json`` trajectory file (schema: _common.bench_json).
"""
import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")

import argparse
import dataclasses
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from _common import HwModel, bench_json  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.configs.base import FreeKVConfig  # noqa: E402
from repro.core import centroid_index, selection  # noqa: E402

SMOKE = dict(pools=(32768, 262144), page_size=32, budget_pages=96,
             n_cent=64, steps=4, needles=16,
             context=64, requests=4, slots=2, short_new=3, long_new=6,
             eng_page=8, eng_budget=48, eng_cent=4, hidden_new=48)
FULL = dict(pools=(32768, 65536, 131072, 262144), page_size=32,
            budget_pages=96, n_cent=64, steps=8, needles=16,
            context=128, requests=6, slots=3, short_new=4, long_new=10,
            eng_page=8, eng_budget=48, eng_cent=4, hidden_new=96)


# ---------------------------------------------------------------------------
# selection-level sweep (summaries only — no token pool materialized)
# ---------------------------------------------------------------------------
def _make_summaries(key, N, kv, d, n_proc_clusters=48):
    """Cluster-structured page summaries: per-page box = process-cluster
    center +- spread (the distribution the centroid index is built for)."""
    kc, ka, kn = jax.random.split(key, 3)
    centers = jax.random.normal(kc, (n_proc_clusters, kv, d))
    assign = jax.random.randint(ka, (N,), 0, n_proc_clusters)
    mid = centers[assign] + 0.2 * jax.random.normal(kn, (N, kv, d))
    w = 0.3 * jnp.abs(jax.random.normal(jax.random.fold_in(kn, 1),
                                        (N, kv, d))) + 0.05
    return jnp.stack([mid - w, mid + w], axis=2)[None]   # (1, N, kv, 2, d)


def _plant_needles(summ, needle_pages, u, strength=3.0):
    """One semantic needle *region*: the needle pages' summaries sit in a
    tight ball around ``strength * |u|`` (kv, d) in key space — a distinct
    passage whose pages resemble each other, which is what the centroid
    index clusters on. A query aligned with u scores them at the top of the
    exact scan; the index must keep them reachable through the cluster the
    region lands in (scattering needles across many fat clusters instead
    would overflow any fixed candidate budget with tied cluster scores —
    that regime is the index's documented failure mode, not its use case)."""
    n = needle_pages.shape[0]
    kv, d = summ.shape[2], summ.shape[4]
    jit = 0.05 * jax.random.normal(jax.random.PRNGKey(7), (n, kv, d))
    mid = strength * jnp.abs(u)[None] + jit
    summ = summ.at[0, needle_pages, :, 0, :].set(mid - 0.1)
    summ = summ.at[0, needle_pages, :, 1, :].set(mid + 0.1)
    return summ


def _scan_counts(N, n_cent, m, kv, d, itemsize=4):
    """Per-step selection-scan bytes + FLOPs from counts. Exact scans every
    page summary; centroid scans C cluster boxes (stage 1), assigns the one
    completed page against the C means, and scores only the m gathered
    candidates (stage 2)."""
    box = 2 * d * itemsize                     # one (lo, hi) summary row
    exact_bytes = N * kv * box
    cent_bytes = (n_cent * kv * box            # stage 1: cluster boxes
                  + m * kv * box               # stage 2: candidates
                  + n_cent * kv * d * itemsize)  # incremental assignment
    # two dot products over d per (page|box, head-group) score
    exact_flops = N * kv * 4 * d
    cent_flops = (n_cent + m) * kv * 4 * d + n_cent * kv * 3 * d
    return exact_bytes, cent_bytes, exact_flops, cent_flops


def selection_sweep(p, quiet):
    cfg = get_config("granite-3-8b-smoke")
    kv, d, H = cfg.n_kv_heads, cfg.d_head, cfg.n_heads
    ps = p["page_size"]
    n_sel = p["budget_pages"]
    fkv = FreeKVConfig(method="centroid", page_size=ps,
                       budget=(n_sel + 2) * ps, n_sink=ps, n_window=ps,
                       tau=0.8, centroid_count=p["n_cent"],
                       group_pool="mean_qk")
    out = {}
    for T in p["pools"]:
        N = T // ps
        key = jax.random.PRNGKey(T)
        summ = _make_summaries(key, N, kv, d)
        rng = np.random.default_rng(T)
        needle_pages = jnp.asarray(rng.choice(
            np.arange(ps // ps + 1, N - 2), size=p["needles"],
            replace=False))
        u = jax.random.normal(jax.random.fold_in(key, 9), (kv, d))
        summ = _plant_needles(summ, needle_pages, u)
        length = jnp.full((1,), T, jnp.int32)
        st = {"summ": summ, "length": length}
        st.update(centroid_index.build(summ, length, p["n_cent"], ps,
                                       jnp.float32))
        m = centroid_index.candidate_count(N, n_sel)
        acc_e = acc_c = ovl = 0.0
        nset = set(np.asarray(needle_pages).tolist())
        for t in range(p["steps"]):
            qn = 0.25 * jax.random.normal(jax.random.fold_in(key, 100 + t),
                                          (1, H, d))
            q = jnp.repeat(jnp.abs(u)[None].reshape(1, kv, 1, d), H // kv,
                           axis=2).reshape(1, H, d) + qn
            e_idx, _ = selection.select_pages(cfg, fkv, q, summ, length,
                                              n_sel)
            c_idx, _ = centroid_index.centroid_select(cfg, fkv, q, st, n_sel)
            e = set(np.asarray(e_idx[0, 0]).tolist()) - {-1}
            c = set(np.asarray(c_idx[0, 0]).tolist()) - {-1}
            acc_e += len(nset & e) / len(nset)
            acc_c += len(nset & c) / len(nset)
            ovl += len(e & c) / max(len(e), 1)
        acc_e /= p["steps"]
        acc_c /= p["steps"]
        ovl /= p["steps"]
        eb, cb, ef, cf = _scan_counts(N, p["n_cent"], m, kv, d)
        out[str(T)] = {
            "n_pages": N, "candidates": m,
            "needle_acc_exact": acc_e, "needle_acc_centroid": acc_c,
            "topk_overlap_frac": ovl,
            "scan_bytes_exact": eb, "scan_bytes_centroid": cb,
            "scan_bytes_reduction": eb / cb,
            "scan_flops_exact": ef, "scan_flops_centroid": cf,
            "scan_flops_reduction": ef / cf,
        }
        if not quiet:
            r = out[str(T)]
            print(f"  pool={T:>7d} pages={N:>5d} "
                  f"bytes {eb/1e6:7.2f}MB -> {cb/1e6:5.2f}MB "
                  f"({r['scan_bytes_reduction']:5.1f}x)  "
                  f"needle exact={acc_e:.3f} centroid={acc_c:.3f} "
                  f"overlap={ovl:.3f}")
    return out


def extrapolate_1m(p, hw=HwModel()):
    """Analytic scan cost at a 1M-token pool (counts x fixed HW constants —
    machine-independent, so the ratio is gated)."""
    cfg = get_config("granite-3-8b-smoke")
    kv, d = cfg.n_kv_heads, cfg.d_head
    N = 1_000_000 // p["page_size"]
    m = centroid_index.candidate_count(N, p["budget_pages"])
    eb, cb, ef, cf = _scan_counts(N, p["n_cent"], m, kv, d)
    us_e = (eb / hw.hbm_bw + ef / hw.peak_flops) * 1e6
    us_c = (cb / hw.hbm_bw + cf / hw.peak_flops) * 1e6
    return {"pool_tokens": 1_000_000, "n_pages": N,
            "us_exact": us_e, "us_centroid": us_c,
            "scan_reduction": eb / cb}


# ---------------------------------------------------------------------------
# engine cells: centroid vs freekv bit-identity + hidden fraction
# ---------------------------------------------------------------------------
def _requests(cfg, context, n, short_new, long_new, seed=0):
    from repro.serving.engine import Request
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        context).astype(np.int32),
                    max_new_tokens=short_new if i % 2 == 0 else long_new)
            for i in range(n)]


def engine_cells(p, artifacts, quiet):
    from repro.models.model import init_params
    from repro.obs import Observability, TraceRecorder
    from repro.serving.engine import Request, ServeEngine
    from repro.serving.sampling import SamplerConfig
    cfg = get_config("granite-3-8b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    base = FreeKVConfig(retriever="centroid", page_size=p["eng_page"],
                        budget=p["eng_budget"], n_sink=p["eng_page"],
                        n_window=p["eng_page"], tau=0.8,
                        centroid_count=p["eng_cent"],
                        centroid_refresh_interval=3)
    def engine(fkv, tp, max_new, obs=None):
        kw = {} if obs is None else {"obs": obs}
        max_len = p["context"] + max_new + 2 * p["eng_page"]
        return ServeEngine(cfg, fkv, params, max_len=max_len,
                           batch_size=p["slots"],
                           sampler=SamplerConfig(temperature=0.0),
                           scheduler="continuous", tp=tp, **kw)

    ident_all = True
    configs = {}
    for overlap in (True, False):
        for quant in ("none", "int8"):
            for tp in (1, 2):
                fkv_c = dataclasses.replace(base, recall_overlap=overlap,
                                            kv_quant=quant)
                fkv_f = dataclasses.replace(fkv_c, method="freekv",
                                            retriever="")
                toks = {}
                for name, f in (("centroid", fkv_c), ("freekv", fkv_f)):
                    eng = engine(f, tp, p["long_new"])
                    toks[name] = [c.tokens for c in eng.generate(
                        _requests(cfg, p["context"], p["requests"],
                                  p["short_new"], p["long_new"]))]
                ident = toks["centroid"] == toks["freekv"]
                ident_all &= ident
                cell = f"overlap={int(overlap)}/quant={quant}/tp={tp}"
                configs[cell] = {"bit_identical": bool(ident)}
                if not quiet:
                    print(f"  {cell:32s} bit_identical={ident}")

    # decode-dominated centroid run: overlap hidden fraction + obs artifacts
    obs = Observability(enabled=True, trace=TraceRecorder(enabled=True))
    eng = engine(dataclasses.replace(base, recall_overlap=True), 1,
                 p["hidden_new"], obs=obs)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, p["context"]).astype(np.int32)
    eng.generate([Request(uid=0, tokens=prompt,
                          max_new_tokens=p["hidden_new"])])
    ro = eng.last_metrics.summary()["recall_overlap"]
    if artifacts:
        os.makedirs(artifacts, exist_ok=True)
        eng.last_metrics.registry.write_jsonl(
            os.path.join(artifacts, "obs_metrics.jsonl"),
            extra={"bench": "longctx", "retriever": "centroid"})
        with open(os.path.join(artifacts, "obs_metrics.prom"), "w",
                  encoding="utf-8") as f:
            f.write(eng.last_metrics.registry.to_prometheus())
        eng.obs.trace.write(os.path.join(artifacts, "obs_trace.json"))
        if not quiet:
            print(f"  artifacts -> {artifacts}/ (obs_metrics.jsonl, "
                  "obs_metrics.prom, obs_trace.json)")
    return bool(ident_all), configs, ro


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="exact vs centroid-then-token selection at long context")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (32K + 256K points)")
    ap.add_argument("--artifacts", default=None, metavar="DIR",
                    help="write obs metrics snapshot + trace for the "
                         "nightly job")
    ap.add_argument("--no-json", action="store_true",
                    help="skip writing BENCH_longctx.json")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    p = SMOKE if args.smoke else FULL

    if not args.quiet:
        print("== selection sweep (exact vs centroid) ==")
    sweep = selection_sweep(p, args.quiet)
    ext = extrapolate_1m(p)
    if not args.quiet:
        print(f"== 1M extrapolation: {ext['us_exact']:.1f}us -> "
              f"{ext['us_centroid']:.1f}us scan "
              f"({ext['scan_reduction']:.1f}x) ==")
        print("== engine cells (centroid vs freekv, correction on) ==")
    bit, configs, ro = engine_cells(p, args.artifacts, args.quiet)

    top = sweep[str(max(p["pools"]))]
    needle_ok = all(s["needle_acc_centroid"] >= s["needle_acc_exact"] - 0.01
                    for s in sweep.values())
    metrics = {
        "sweep": sweep,
        "reduction_256k": top["scan_bytes_reduction"],
        "reduction_ge_4x": top["scan_bytes_reduction"] >= 4.0,
        "needle_within_1pct": needle_ok,
        "needle_acc_exact_256k": top["needle_acc_exact"],
        "needle_acc_centroid_256k": top["needle_acc_centroid"],
        "topk_overlap_256k": top["topk_overlap_frac"],
        "extrapolated_1m": ext,
        "bit_identical": bit,
        "configs": configs,
        "hidden_fraction": ro["hidden_fraction"],
        "hidden_bytes": ro["hidden_bytes"],
        "exposed_bytes": ro["exposed_bytes"],
    }
    if not args.quiet:
        print(f"bit_identical={bit} reduction_256k="
              f"{top['scan_bytes_reduction']:.1f}x "
              f"hidden_fraction={ro['hidden_fraction']:.3f}")
    if not args.no_json:
        bench_json("longctx", {**p, "smoke": args.smoke}, metrics)
    return 0


if __name__ == "__main__":
    sys.exit(main())
