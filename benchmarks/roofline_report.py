"""§Roofline report: renders the per-(arch x shape x mesh) roofline table from
the dry-run artifacts (artifacts/dryrun/*.json) — compute / memory /
collective terms, dominant bottleneck, MODEL_FLOPS / HLO_FLOPs ratio, and a
one-line "what would move the dominant term" note.
"""
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

NOTES = {
    ("compute",): "more chips / lower precision / fewer remat recomputes",
    ("memory",): "fuse reads, shrink resident KV (larger pages / lower "
                 "budget), bf16 everywhere, avoid pool rewrites",
    ("collective",): "reshard to cut all-gathers (head- vs seq-parallel), "
                     "overlap collectives with compute, shard-local recall",
}


def load(mesh="single"):
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, f"*__{mesh}.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "error": r.get("error", "?")})
            continue
        ro, mem = r["roofline"], r["memory"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": ro["compute_s"], "memory_s": ro["memory_s"],
            "collective_s": ro["collective_s"], "dominant": ro["dominant"],
            "useful": ro["useful_flops_ratio"],
            "mem_gb": mem["per_device_total"] / 1e9,
            "fits": mem["fits_16GB"],
        })
    return rows


def render_markdown(mesh="single"):
    rows = load(mesh)
    out = [f"| arch | shape | compute (s) | memory (s) | collective (s) | "
           f"dominant | useful FLOPs | GB/dev | fits |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | ERROR {r['error'][:40]} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['dominant']} | {r['useful']:.3f} | {r['mem_gb']:.2f} | "
            f"{'y' if r['fits'] else 'N'} |")
    return "\n".join(out)


def main():
    all_rows = {}
    for mesh in ("single", "multi"):
        rows = load(mesh)
        if not rows:
            continue
        all_rows[mesh] = rows
        ok = [r for r in rows if "error" not in r]
        print(f"roofline/{mesh},{len(ok)},of={len(rows)}")
        for r in ok:
            print(f"roofline/{mesh}/{r['arch']}/{r['shape']},"
                  f"{max(r['compute_s'], r['memory_s'], r['collective_s'])*1e6:.1f},"
                  f"dominant={r['dominant']};useful={r['useful']:.3f};"
                  f"mem={r['mem_gb']:.2f}GB")
    return all_rows


if __name__ == "__main__":
    main()
