"""Benchmark driver: one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows and writes a machine-readable
``BENCH_<section>.json`` trajectory file at the repo root per section
(schema: {benchmark, config, metrics, git_sha} — see ``_common.bench_json``)
so perf history is trackable across PRs.

  accuracy            Tables 2/3 proxy (attention fidelity + page overlap)
  breakdown           Fig. 1 right (latency decomposition cost model)
  e2e                 Fig. 7 (end-to-end latency, speedup vs ArkVale)
  ablation            Fig. 9 (HL / DB / SR cumulative)
  measured            real-engine CPU wall-clock per decode step
  similarity          Fig. 3 / Table 8 (adjacent-step query cosine)
  correction          Table 9 (correction rate vs tau/drift)
  selection_ablation  App. B.2 (MaxQ..MeanS) + B.3 (tau sweep)
  quant               quantized host KV tier: needle accuracy + recall bytes
  roofline            Roofline table from dry-run artifacts

Run separately (needs its own process: forces 8 XLA host devices):
  PYTHONPATH=src python benchmarks/sharded_quality.py   # opt2 accuracy cost
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SECTIONS = ("accuracy", "breakdown", "e2e", "ablation", "measured",
            "similarity", "correction", "selection_ablation", "quant",
            "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help=f"subset of {SECTIONS}")
    ap.add_argument("--no-json", action="store_true",
                    help="skip writing BENCH_<name>.json trajectory files")
    args, _ = ap.parse_known_args()
    todo = set(args.only) if args.only else set(SECTIONS)
    from _common import bench_json

    def emit(name, config, metrics):
        if not args.no_json and metrics:   # no file for empty sections
            bench_json(name, config, metrics)

    print("name,us_per_call,derived")

    # One config dict per section, passed verbatim to BOTH the benchmark
    # call and the trajectory file, so BENCH_<name>.json metadata can never
    # desynchronize from what actually ran.
    if "accuracy" in todo:
        import retrieval_accuracy
        cfg = dict(arch="granite-3-8b-smoke", B=4, T=512, steps=48)
        emit("accuracy", cfg, retrieval_accuracy.run(**cfg))
    if todo & {"breakdown", "e2e", "ablation", "measured"}:
        import latency
        if "breakdown" in todo:
            cfg = dict(B=1, context=32768)
            emit("breakdown", cfg,
                 {arch: latency.breakdown(arch, **cfg)
                  for arch in ("llama31-8b", "qwen25-7b")})
        if "e2e" in todo:
            cfg = dict(arch="llama31-8b")
            emit("e2e", cfg, latency.e2e(**cfg))
        if "ablation" in todo:
            cfg = dict(arch="llama31-8b", B=4, context=32768)
            emit("ablation", cfg, latency.ablation(**cfg))
        if "measured" in todo:
            cfg = dict(arch="granite-3-8b-smoke", B=2, T=256, steps=12)
            emit("measured", cfg, latency.measured(**cfg))
    if todo & {"similarity", "correction"}:
        import similarity_correction
        if "similarity" in todo:
            cfg = dict(arch="smollm-360m-smoke", train_steps=40)
            emit("similarity", cfg,
                 similarity_correction.model_query_similarity(**cfg))
        if "correction" in todo:
            cfg = dict(arch="granite-3-8b-smoke", B=4, T=512, steps=48)
            emit("correction", cfg,
                 similarity_correction.correction_rates(**cfg))
    if "selection_ablation" in todo:
        import selection_ablation
        cfg = dict(arch="granite-3-8b-smoke", B=4, T=512)
        emit("selection_ablation", cfg,
             {"group_pool": selection_ablation.run(**cfg),
              "tau_sweep": selection_ablation.tau_sweep(**cfg)})
    if "quant" in todo:
        import quant_quality
        emit("quant_quality", quant_quality.SMOKE_CONFIG,
             quant_quality.run(**quant_quality.SMOKE_CONFIG))
    if "roofline" in todo:
        import roofline_report
        emit("roofline", {"meshes": ["single", "multi"]},
             roofline_report.main())


if __name__ == "__main__":
    main()
