"""Benchmark driver: one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  accuracy            Tables 2/3 proxy (attention fidelity + page overlap)
  breakdown           Fig. 1 right (latency decomposition cost model)
  e2e                 Fig. 7 (end-to-end latency, speedup vs ArkVale)
  ablation            Fig. 9 (HL / DB / SR cumulative)
  measured            real-engine CPU wall-clock per decode step
  similarity          Fig. 3 / Table 8 (adjacent-step query cosine)
  correction          Table 9 (correction rate vs tau/drift)
  selection_ablation  App. B.2 (MaxQ..MeanS) + B.3 (tau sweep)
  roofline            Roofline table from dry-run artifacts

Run separately (needs its own process: forces 8 XLA host devices):
  PYTHONPATH=src python benchmarks/sharded_quality.py   # opt2 accuracy cost
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SECTIONS = ("accuracy", "breakdown", "e2e", "ablation", "measured",
            "similarity", "correction", "selection_ablation", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help=f"subset of {SECTIONS}")
    args, _ = ap.parse_known_args()
    todo = set(args.only) if args.only else set(SECTIONS)
    print("name,us_per_call,derived")

    if "accuracy" in todo:
        import retrieval_accuracy
        retrieval_accuracy.run()
    if todo & {"breakdown", "e2e", "ablation", "measured"}:
        import latency
        if "breakdown" in todo:
            latency.breakdown("llama31-8b")
            latency.breakdown("qwen25-7b")
        if "e2e" in todo:
            latency.e2e("llama31-8b")
        if "ablation" in todo:
            latency.ablation("llama31-8b")
        if "measured" in todo:
            latency.measured()
    if todo & {"similarity", "correction"}:
        import similarity_correction
        if "similarity" in todo:
            similarity_correction.model_query_similarity()
        if "correction" in todo:
            similarity_correction.correction_rates()
    if "selection_ablation" in todo:
        import selection_ablation
        selection_ablation.run()
        selection_ablation.tau_sweep()
    if "roofline" in todo:
        import roofline_report
        roofline_report.main()


if __name__ == "__main__":
    main()
