"""Quantized host KV tier sweep (src/repro/quant): needle-retrieval accuracy,
per-step recall bytes, and measured per-step latency for kv_quant in
{none, int8, int4} against the fp16-accounted dense baseline.

Task: a *needle* benchmark built for retrieval quality. Background K/V are
low-norm noise; a few needle tokens with strong, distinctive keys are planted
in the selectable page region, and each decode step queries one needle. The
full-cache oracle's output is then dominated by that needle's value, so a
method "retrieves the needle" iff its attention output stays within a small
relative error of the oracle. Selection runs on full-precision summaries in
every mode (quantization only changes recalled page *content*), so accuracy
differences isolate exactly the dequantization error.

Reported per mode:
  needle_acc     fraction of (step, row) needle retrievals within rel-err 0.1
  out_err        mean relative L2 error vs the full-cache oracle
  bytes_per_step host->device recall bytes per decode step (moved blocks x
                 packed block bytes; fp16 accounting for kv_quant="none")
  us_per_step    measured wall-clock per jitted decode step (CPU-relative;
                 the delta vs "none" is the dequant overhead)

Acceptance targets (ISSUE 3): int8 needle_acc within 1% of fp16;
bytes_per_step reduced >= 1.9x (int8) and >= 3.5x (int4).

    PYTHONPATH=src python benchmarks/quant_quality.py [--smoke]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from _common import bench_json, csv_row
from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.core.retrieval import make_retriever
from repro.quant import page_block_bytes

MODES = ("none", "int8", "int4")

SMOKE_CONFIG = dict(arch="granite-3-8b-smoke", B=2, T=256, steps=16,
                    n_needles=6, seed=0)


def needle_problem(cfg, B, T, p, n_needles, seed):
    """Background noise K/V + planted needles with strong distinctive keys.

    Returns (k, v, needle_pages, queries_fn): ``queries_fn(step)`` yields a
    query aimed at one needle (round-robin) with small per-step jitter."""
    kv, d, H = cfg.n_kv_heads, cfg.d_head, cfg.n_heads
    rng = np.random.default_rng(seed)
    k = 0.3 * rng.standard_normal((B, T, kv, d))
    v = 0.3 * rng.standard_normal((B, T, kv, d))
    # needle positions: middle of distinct pages, clear of sink/window
    lo_page, hi_page = 2, T // p - 3
    pages = rng.choice(np.arange(lo_page, hi_page), size=n_needles,
                       replace=False)
    positions = pages * p + p // 2
    dirs = rng.standard_normal((n_needles, kv, d))
    dirs /= np.linalg.norm(dirs, axis=-1, keepdims=True)
    payloads = rng.standard_normal((n_needles, kv, d))
    payloads /= np.linalg.norm(payloads, axis=-1, keepdims=True)
    # key amplitude such that the needle logit (a^2 * attn_scale) clears the
    # aggregate background mass (~T tokens at exp(0)) by a wide margin; the
    # payload is strong too (distinctive value), so accuracy measures
    # signal fidelity rather than the noise floor set by the page's amax
    a, pa = 10.0, 6.0
    for i, pos in enumerate(positions):
        k[:, pos] = a * dirs[i]
        v[:, pos] = pa * payloads[i]

    def queries_fn(step):
        # jitter keyed by (seed, step) — NOT the shared rng — so every
        # kv_quant mode scores against identical query realizations and
        # accuracy deltas isolate the dequantization error alone
        qrng = np.random.default_rng((seed, step))
        i = step % n_needles
        q = np.repeat(a * dirs[i], H // kv, axis=0)        # (H, d)
        q = q + 0.05 * qrng.standard_normal(q.shape)
        return jnp.asarray(np.broadcast_to(q, (B, H, d)), jnp.float32)

    return (jnp.asarray(k, jnp.float32), jnp.asarray(v, jnp.float32),
            pages, queries_fn)


def run(arch="granite-3-8b-smoke", B=2, T=512, steps=32, n_needles=8,
        seed=0, group_size=16, err_thresh=0.1, quiet=False):
    cfg = get_config(arch)
    p = 16
    # budget sized so every needle page fits the selection set: accuracy then
    # isolates recalled-content fidelity (the dequant error), not selection
    budget = 2 * p + (n_needles + 2) * p
    fkv_base = dict(method="freekv", page_size=p, budget=budget,
                    n_sink=p, n_window=p, tau=0.8)
    k, v, _needle_pages, queries_fn = needle_problem(cfg, B, T, p, n_needles,
                                                     seed)
    q_last = queries_fn(0)
    max_len = T + steps + p

    # oracle: exact dense cache
    rf = make_retriever(cfg, FreeKVConfig(method="full"))
    stf0 = rf.prefill(rf.init_state(B, max_len, jnp.float32), k, v, q_last)

    results = {}
    for mode in MODES:
        fkv = FreeKVConfig(kv_quant=mode, quant_group_size=group_size,
                           **fkv_base)
        r = make_retriever(cfg, fkv)
        st = r.prefill(r.init_state(B, max_len, jnp.float32), k, v, q_last)
        stf = stf0

        @jax.jit
        def step_fn(st, q, kn, vn):
            o, st, info = r.decode(st, q, kn, vn)
            return o, st, (info["sync_pages"], info["async_pages"])

        rng = np.random.default_rng(seed + 1)
        errs, succ, blocks, step_s = [], [], 0.0, 0.0
        # warm-up compile (and the oracle's eager op caches) untimed
        q0 = queries_fn(0)
        kn0 = jnp.asarray(0.3 * rng.standard_normal((B, cfg.n_kv_heads,
                                                     cfg.d_head)), jnp.float32)
        o, _, _ = step_fn(st, q0, kn0, kn0)
        jax.block_until_ready(o)
        rf.decode(stf, q0, kn0, kn0)
        for i in range(steps):
            q = queries_fn(i)
            kn = jnp.asarray(0.3 * rng.standard_normal(
                (B, cfg.n_kv_heads, cfg.d_head)), jnp.float32)
            vn = jnp.asarray(0.3 * rng.standard_normal(
                (B, cfg.n_kv_heads, cfg.d_head)), jnp.float32)
            ts = time.perf_counter()            # time the engine step only —
            o, st, (sync, async_) = step_fn(st, q, kn, vn)
            jax.block_until_ready(o)            # the oracle is not the SUT
            step_s += time.perf_counter() - ts
            of, stf, _ = rf.decode(stf, q, kn, vn)
            rel = (jnp.linalg.norm(o - of, axis=-1)
                   / jnp.maximum(jnp.linalg.norm(of, axis=-1), 1e-6))
            rel = np.asarray(rel)                       # (B, H)
            errs.append(float(rel.mean()))
            succ.append(float((rel.max(axis=1) < err_thresh).mean()))
            blocks += float(np.asarray(sync).sum() + np.asarray(async_).sum())
        wall = step_s
        blk_bytes = page_block_bytes(fkv, cfg.d_head, itemsize=2)  # fp16 acct
        results[mode] = {
            "needle_acc": float(np.mean(succ)),
            "out_err": float(np.mean(errs)),
            "block_bytes": blk_bytes,
            "bytes_per_step": blocks / steps * blk_bytes,
            "blocks_per_step": blocks / steps,
            "us_per_step": wall / steps * 1e6,
        }
        if not quiet:
            m = results[mode]
            csv_row(f"quant_quality/{arch}/{mode}", m["us_per_step"],
                    f"needle_acc={m['needle_acc']:.3f};"
                    f"out_err={m['out_err']:.4f};"
                    f"bytes_per_step={m['bytes_per_step']:.0f}")

    base = results["none"]
    results["ratios"] = {
        f"{m}_bytes_reduction": (base["bytes_per_step"]
                                 / results[m]["bytes_per_step"]
                                 if results[m]["bytes_per_step"] else 0.0)
        for m in ("int8", "int4")
    }
    results["ratios"].update({
        f"{m}_acc_drop": base["needle_acc"] - results[m]["needle_acc"]
        for m in ("int8", "int4")
    })
    results["ratios"].update({
        f"{m}_latency_overhead": (results[m]["us_per_step"]
                                  / base["us_per_step"] - 1.0)
        for m in ("int8", "int4")
    })
    if not quiet:
        rr = results["ratios"]
        csv_row("quant_quality/ratios", 0.0,
                f"int8_bytes={rr['int8_bytes_reduction']:.2f}x;"
                f"int4_bytes={rr['int4_bytes_reduction']:.2f}x;"
                f"int8_acc_drop={rr['int8_acc_drop']:.4f};"
                f"int4_acc_drop={rr['int4_acc_drop']:.4f}")
    return results


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small T/steps) — still writes the "
                         "BENCH_quant_quality.json trajectory file")
    args = ap.parse_args()
    config = dict(SMOKE_CONFIG) if args.smoke \
        else dict(arch="granite-3-8b-smoke", B=2, T=512, steps=32,
                  n_needles=8, seed=0)
    res = run(**config)
    bench_json("quant_quality", config, res)
    return res


if __name__ == "__main__":
    main()
