"""Speculative decoding fused with speculative retrieval: bit-identity +
throughput sweep (own process: it forces XLA host devices for the tp=2
cells before jax initializes).

Two measurements:

* **bit_identical** — for every cell of draft_len={0, 2, 4} x
  recall_overlap={on, off} x kv_quant={none, int8} x tp={1, 2}, the greedy
  token streams of the speculative host-sync-free loop (``sync_interval=8``,
  on-device sampling + drafting, donated state) must match the
  non-speculative synchronous per-step reference (``draft_len=0,
  sample_on_device=False``) exactly. The drafter only proposes; the batched
  verify pass accepts the longest prefix that greedy decoding would have
  produced anyway, so ANY mismatch is a bug. Any False fails CI via
  ``tools/check_bench.py``.

* **throughput** — a decode-dominated run measures tokens/sec at
  draft_len=0 vs draft_len>0 under a high-accept workload: the baseline
  run's own greedy continuation is replayed as each request's
  ``draft_hint`` (prompt-lookup style — hints steer only the proposer,
  verification guarantees the outputs stay bit-identical, which the run
  re-asserts). Reported per draft_len: accept_rate, tokens per target
  step, wall and decode-only speedups. The gated ``speedup_ge_1p5x`` bool
  uses the decode-attributed ratio (prefill does identical work in both
  runs and is excluded); raw tokens/sec are recorded but never gated
  (CI runners differ).

    PYTHONPATH=src python benchmarks/specdec_throughput.py [--smoke]

Writes the ``BENCH_specdec.json`` trajectory file (schema:
_common.bench_json).
"""
import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")

import argparse
import dataclasses
import sys
import time

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.configs.base import FreeKVConfig  # noqa: E402
from repro.models.model import init_params  # noqa: E402
from repro.serving.engine import Request, ServeEngine  # noqa: E402
from repro.serving.sampling import SamplerConfig  # noqa: E402

SMOKE = dict(arch="smollm-360m-smoke", context=48, requests=4, slots=2,
             short_new=5, long_new=9, page_size=8, budget=48,
             timing_new=96, timing_draft_lens=(4,))
FULL = dict(arch="smollm-360m-smoke", context=128, requests=8, slots=4,
            short_new=6, long_new=14, page_size=8, budget=64,
            timing_new=192, timing_draft_lens=(2, 4, 6))

IDENT_DRAFT_LENS = (0, 2, 4)


def equal_len_requests(cfg, context, n, short_new, long_new, seed=0):
    """Equal prompt LENGTHS (contents differ): prompt padding never enters
    the picture, so every scheduler/draft_len cell is comparable
    bit-for-bit."""
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab_size, context
                                        ).astype(np.int32),
                    max_new_tokens=short_new if i % 2 == 0 else long_new)
            for i in range(n)]


def _engine(cfg, params, fkv, max_len, slots, tp=1):
    return ServeEngine(cfg, fkv, params, max_len=max_len, batch_size=slots,
                       sampler=SamplerConfig(temperature=0.0),
                       scheduler="continuous", tp=tp)


def identity_sweep(cfg, params, base, max_len, slots, reqs_fn, quiet):
    ident_all = True
    configs = {}
    for overlap in (True, False):
        for quant in ("none", "int8"):
            for tp in (1, 2):
                fkv = dataclasses.replace(base, recall_overlap=overlap,
                                          kv_quant=quant)
                ref_eng = _engine(cfg, params, dataclasses.replace(
                    fkv, draft_len=0, sample_on_device=False),
                    max_len, slots, tp)
                ref = [c.tokens for c in ref_eng.generate(reqs_fn())]
                ident = True
                for dl in IDENT_DRAFT_LENS:
                    eng = _engine(cfg, params, dataclasses.replace(
                        fkv, draft_len=dl, sample_on_device=True,
                        sync_interval=8), max_len, slots, tp)
                    toks = [c.tokens for c in eng.generate(reqs_fn())]
                    ident &= toks == ref
                ident_all &= ident
                name = (f"dl={'/'.join(map(str, IDENT_DRAFT_LENS))}"
                        f"/overlap={int(overlap)}/quant={quant}/tp={tp}")
                configs[name] = {"bit_identical": bool(ident)}
                if not quiet:
                    print(f"  {name:44s} bit_identical={ident}")
    return bool(ident_all), configs


def timing_sweep(cfg, params, base, max_len, slots, context, requests,
                 timing_new, draft_lens, quiet):
    """Decode-dominated equal-length batch, draft_len=0 vs each draft_len>0
    with the baseline's own continuation fed back as the draft hint."""
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, context).astype(np.int32)
               for _ in range(requests)]

    def run(draft_len, hints=None):
        fkv = dataclasses.replace(base, draft_len=draft_len,
                                  sample_on_device=True, sync_interval=8)
        eng = _engine(cfg, params, fkv, max_len, slots)
        mk = lambda: [Request(  # noqa: E731
            uid=i, tokens=p, max_new_tokens=timing_new,
            draft_hint=None if hints is None else hints[i])
            for i, p in enumerate(prompts)]
        eng.generate(mk())                  # warmup: compile all shapes
        t0 = time.perf_counter()
        outs = eng.generate(mk())
        wall_s = time.perf_counter() - t0
        decode_s = sum(o.decode_s for o in outs)
        toks = sum(len(o.tokens) for o in outs)
        em = eng.last_metrics
        return (sorted(outs, key=lambda o: o.uid), toks, wall_s, decode_s,
                em.summary()["specdec"], em.summary()["dispatch"])

    outs0, toks0, wall0, dec0, _, _ = run(0)
    base_wall = toks0 / wall0
    base_dec = toks0 / dec0
    if not quiet:
        print(f"  draft_len=0: {base_wall:.0f} tok/s wall, "
              f"{base_dec:.0f} tok/s decode")
    hints = [np.concatenate([prompts[o.uid][-1:],
                             np.asarray(o.tokens, np.int32)])
             for o in outs0]
    out = {"baseline": {"tokens": toks0, "tok_per_s_wall": base_wall,
                        "tok_per_s_decode": base_dec}}
    best = 0.0
    ident_all = True
    for dl in draft_lens:
        outs, toks, wall, dec, spec, disp = run(dl, hints)
        ident = [o.tokens for o in outs] == [o.tokens for o in outs0]
        ident_all &= ident
        cell = {
            "bit_identical": bool(ident),
            "accept_rate": spec["accept_rate"],
            "tokens_per_step": spec["tokens_per_step"],
            "tok_per_s_wall": toks / wall,
            "tok_per_s_decode": toks / dec,
            "wall_speedup": (toks / wall) / base_wall,
            "decode_speedup": (toks / dec) / base_dec,
            "nonsync_bytes_per_step": disp["nonsync_bytes_per_step"],
        }
        best = max(best, cell["decode_speedup"])
        out[f"dl={dl}"] = cell
        if not quiet:
            print(f"  draft_len={dl}: accept {cell['accept_rate']:.3f} | "
                  f"{cell['tokens_per_step']:.2f} tok/target-step | wall "
                  f"x{cell['wall_speedup']:.2f} | decode "
                  f"x{cell['decode_speedup']:.2f} | identical={ident}")
    out["speedup"] = best
    out["speedup_ge_1p5x"] = bool(best >= 1.5)
    out["bit_identical"] = bool(ident_all)
    return out


def run(arch, context, requests, slots, short_new, long_new, page_size,
        budget, timing_new, timing_draft_lens, quiet=False):
    cfg = get_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    base = FreeKVConfig(method="freekv", page_size=page_size, budget=budget,
                        n_sink=page_size, n_window=page_size, tau=0.8)
    max_len = context + long_new + page_size
    reqs_fn = lambda: equal_len_requests(cfg, context, requests,  # noqa: E731
                                         short_new, long_new)
    ident, configs = identity_sweep(cfg, params, base, max_len, slots,
                                    reqs_fn, quiet)
    timing = timing_sweep(cfg, params, base, context + timing_new + page_size,
                          slots, context, requests, timing_new,
                          timing_draft_lens, quiet)
    spec = timing[f"dl={timing_draft_lens[-1]}"]
    return {
        "bit_identical": bool(ident and timing["bit_identical"]),
        "accept_rate": spec["accept_rate"],
        "tokens_per_step": spec["tokens_per_step"],
        "speedup": timing["speedup"],
        "speedup_ge_1p5x": timing["speedup_ge_1p5x"],
        "configs": configs,
        "timing": timing,
    }


def main():
    from _common import bench_json
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run — still writes BENCH_specdec.json")
    ap.add_argument("--no-json", action="store_true")
    args = ap.parse_args()
    config = dict(SMOKE) if args.smoke else dict(FULL)
    print(f"devices: {jax.devices()}")
    res = run(**config)
    status = "PASS" if res["bit_identical"] else "FAIL"
    print(f"bit_identical across specdec sweep: {res['bit_identical']} "
          f"[{status}]")
    print(f"accept {res['accept_rate']:.3f} | "
          f"{res['tokens_per_step']:.2f} tokens/target-step | decode "
          f"speedup {res['speedup']:.2f}x "
          f"(>=1.5x: {res['speedup_ge_1p5x']})")
    if not args.no_json:
        bench_json("specdec", config, res)
    if not res["bit_identical"]:
        sys.exit(1)
    return res


if __name__ == "__main__":
    main()
