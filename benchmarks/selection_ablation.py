"""App. B.2/B.3 ablations: group-consistent selection variants (MaxQ, MeanQ,
MaxQK, MeanQK, MaxS, MeanS) + correction thresholds, scored by oracle-page
overlap and attention-output error on the structured process."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from _common import attention_process, csv_row
from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.core import selection
from repro.core.retrieval import make_retriever

VARIANTS = {
    "MaxQ": dict(group_pool="max_qk", q_pool="max"),
    "MeanQ": dict(group_pool="max_qk", q_pool="mean"),
    "MaxQK": dict(group_pool="max_qk", q_pool=None),
    "MeanQK": dict(group_pool="mean_qk", q_pool=None),
    "MaxS": dict(group_pool="max_softmax", q_pool=None),
    "MeanS": dict(group_pool="mean_softmax", q_pool=None),   # paper's choice
}


def run(arch="granite-3-8b-smoke", B=4, T=512, n_queries=32, quiet=False):
    cfg = get_config(arch)
    p = 16
    key = jax.random.PRNGKey(2)
    k, v, query_walk = attention_process(key, cfg, B, T)
    qs = query_walk(n_queries)
    length = jnp.full((B,), T, jnp.int32)
    n_pages = T // p
    kp = k.reshape(B, n_pages, p, cfg.n_kv_heads, cfg.d_head)
    summ = jnp.stack([kp.min(2), kp.max(2)], axis=3)
    n_sel = 8
    results = {}
    for name, kw in VARIANTS.items():
        fkv = FreeKVConfig(method="freekv", page_size=p, budget=10 ** 6,
                           n_sink=p, n_window=p, group_pool=kw["group_pool"])
        hits = []
        for i in range(n_queries):
            idx, _ = selection.select_pages(cfg, fkv, qs[:, i], summ, length,
                                            n_sel, q_pool=kw["q_pool"])
            oracle = selection.oracle_pages(cfg, fkv, qs[:, i], k, length,
                                            n_sel)
            ai, bi = np.asarray(idx), np.asarray(oracle)
            hit = 0.0
            for b in range(B):
                for h in range(cfg.n_kv_heads):
                    sa = set(ai[b, h][ai[b, h] >= 0].tolist())
                    sb = set(bi[b, h][bi[b, h] >= 0].tolist())
                    hit += len(sa & sb) / max(len(sb), 1)
            hits.append(hit / (B * cfg.n_kv_heads))
        results[name] = float(np.mean(hits))
        if not quiet:
            csv_row(f"selection_ablation/{name}", 0.0,
                    f"oracle_overlap={results[name]:.3f}")
    return results


def tau_sweep(arch="granite-3-8b-smoke", B=4, T=512, steps=40, quiet=False):
    """Correction threshold sweep (App. B.3 Table 7 analogue): output error
    vs full cache as a function of tau (tau=0: pure speculation; tau=1:
    always re-select)."""
    cfg = get_config(arch)
    p = 16
    key = jax.random.PRNGKey(3)
    k, v, query_walk = attention_process(key, cfg, B, T, drift=0.15)
    qs = query_walk(steps)
    rf = make_retriever(cfg, FreeKVConfig(method="full"))
    out = {}
    for tau in (0.0, 0.7, 0.8, 0.9, 1.0):
        fkv = FreeKVConfig(method="freekv", page_size=p, budget=128,
                           n_sink=32, n_window=32, tau=tau)
        r = make_retriever(cfg, fkv)
        st = r.init_state(B, T + steps + p, jnp.float32)
        st = r.prefill(st, k, v, qs[:, 0])
        stf = rf.init_state(B, T + steps + p, jnp.float32)
        stf = rf.prefill(stf, k, v, qs[:, 0])
        errs, rates = [], []
        for i in range(1, steps):
            q = qs[:, i]
            kn, vn = k[:, i % T], v[:, i % T]
            o, st, info = r.decode(st, q, kn, vn)
            of, stf, _ = rf.decode(stf, q, kn, vn)
            err = (jnp.linalg.norm(o - of, axis=-1)
                   / jnp.maximum(jnp.linalg.norm(of, axis=-1), 1e-6))
            errs.append(float(err.mean()))
            rates.append(float(np.asarray(info["corrected"]).mean()))
        out[tau] = (float(np.mean(errs)), float(np.mean(rates)))
        if not quiet:
            csv_row(f"tau_sweep/tau{tau}", 0.0,
                    f"out_err={out[tau][0]:.4f};corr_rate={out[tau][1]:.3f}")
    return out


def main():
    run()
    tau_sweep()


if __name__ == "__main__":
    main()
