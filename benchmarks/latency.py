"""Latency benchmarks mirroring the paper's efficiency figures.

  breakdown  — Fig. 1 (right): per-decode-step latency decomposition
               (compute / selection / blocking recall) per method, from the
               analytical cost model at the paper's setting (32K context,
               B=2048 budget) on llama31-8b / qwen25-7b.
  e2e        — Fig. 7: end-to-end decode latency and speedups vs ArkVale
               across batch sizes, long-input (32K in / 512 out) and
               long-generation (600 in / 16K out) scenarios.
  ablation   — Fig. 9: hybrid layouts (HL), double-buffered streamed recall
               (DB), speculative retrieval (SR) toggled cumulatively.
  measured   — wall-clock per-decode-step of the real engine on CPU with the
               reduced model (relative ordering check of the implementations).
  overlap    — the overlapped double-buffered recall pipeline
               (core/recall_pipeline): hidden-transfer fraction from the sim
               cost model at the paper's setting, plus measured pipeline
               on/off per-step wall-clock + bit-identity on CPU.

``--smoke`` runs a CI-sized subset (cost-model sections + a short measured
overlap check); see docs/benchmarks.md for how to read the output.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from _common import HwModel, attention_process, csv_row, decode_step_cost
from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.core.retrieval import make_retriever

METHODS = ("full", "streaming", "raas", "quest", "arkvale", "shadowkv",
           "infinigen", "freekv")
PAPER_FKV = FreeKVConfig(method="freekv", page_size=32, budget=2048,
                         n_sink=512, n_window=512, tau=0.9)


def breakdown(arch="llama31-8b", B=1, context=32768):
    cfg = get_config(arch)
    rows = {}
    for m in METHODS:
        c = decode_step_cost(cfg, PAPER_FKV, m, B, context)
        rows[m] = c
        csv_row(f"breakdown/{arch}/{m}", c.total_s * 1e6,
                f"compute={c.compute_s*1e6:.1f}us;select={c.select_s*1e6:.1f}us;"
                f"recall_block={c.recall_blocking_s*1e6:.1f}us;"
                f"recall_total={c.recall_total_s*1e6:.1f}us")
    return rows


def e2e(arch="llama31-8b"):
    cfg = get_config(arch)
    out = {}
    for scenario, (ctx_in, gen) in {"long_input": (32768, 512),
                                    "long_gen": (600, 16384)}.items():
        for B in (1, 4, 8):
            totals = {}
            for m in METHODS:
                # decode dominates; context grows during generation
                t = 0.0
                for chunk_start in range(0, gen, 1024):
                    ctx = ctx_in + chunk_start
                    steps = min(1024, gen - chunk_start)
                    t += steps * decode_step_cost(cfg, PAPER_FKV, m, B,
                                                  ctx).total_s
                totals[m] = t
            base = totals["arkvale"]
            for m in METHODS:
                sp = base / totals[m]
                csv_row(f"e2e/{arch}/{scenario}/B{B}/{m}",
                        totals[m] * 1e6, f"speedup_vs_arkvale={sp:.2f}x")
            out[(scenario, B)] = totals
    return out


def ablation(arch="llama31-8b", B=4, context=32768):
    """Fig. 9: start from a no-optimization retrieval baseline and apply
    HL -> +DB -> +SR cumulatively."""
    cfg = get_config(arch)
    hw = HwModel()
    p, d = PAPER_FKV.page_size, cfg.d_head
    kv = cfg.n_kv_heads
    n_attn = sum(1 for m, _ in cfg.layers if m == "attn")
    n_sel = (PAPER_FKV.budget - PAPER_FKV.n_sink - PAPER_FKV.n_window) // p
    recall_bytes = B * kv * n_sel * 2 * p * d * 2 * n_attn
    base_cost = decode_step_cost(cfg, PAPER_FKV, "arkvale", B, context)
    variants = {}
    # baseline: NHD host layout -> fragmented d-sized transfers, blocking
    t_frag = hw.transfer_time(recall_bytes, d * 2, double_buffered=False)
    variants["baseline(NHD,blocking)"] = base_cost.compute_s + base_cost.select_s + t_frag
    # +HL: contiguous (2,p,d) units
    t_hl = hw.transfer_time(recall_bytes, 2 * p * d * 2, double_buffered=False)
    variants["+HL"] = base_cost.compute_s + base_cost.select_s + t_hl
    # +DB: double-buffered streaming
    t_db = hw.transfer_time(recall_bytes, 2 * p * d * 2, double_buffered=True)
    variants["+HL+DB"] = base_cost.compute_s + base_cost.select_s + t_db
    # +SR: overlap with compute, only corrected heads block
    fk = decode_step_cost(cfg, PAPER_FKV, "freekv", B, context)
    variants["+HL+DB+SR(FreeKV)"] = fk.total_s
    base = variants["baseline(NHD,blocking)"]
    for k, v in variants.items():
        csv_row(f"ablation/{arch}/{k}", v * 1e6, f"speedup={base / v:.2f}x")
    return variants


def measured(arch="granite-3-8b-smoke", B=2, T=256, steps=12):
    """Wall-clock per-step of the actual implementations on CPU (relative)."""
    cfg = get_config(arch)
    p = 16
    fkv_base = dict(page_size=p, budget=64, n_sink=16, n_window=16, tau=0.8,
                    svd_rank=32)
    key = jax.random.PRNGKey(0)
    k, v, query_walk = attention_process(key, cfg, B, T)
    qs = query_walk(steps + 2)
    rows = {}
    for m in METHODS:
        fkv = FreeKVConfig(method=m, **fkv_base)
        r = make_retriever(cfg, fkv)
        st = r.init_state(B, T + steps + p, jnp.float32)
        st = r.prefill(st, k, v, qs[:, 0])

        @jax.jit
        def step(st, q, kn, vn):
            o, st, _ = r.decode(st, q, kn, vn, q_proxy=q)
            return o, st
        o, st2 = step(st, qs[:, 1], k[:, 0], v[:, 0])
        jax.block_until_ready(o)
        t0 = time.perf_counter()
        for i in range(steps):
            o, st = step(st, qs[:, i + 1], k[:, i], v[:, i])
        jax.block_until_ready(o)
        dt = (time.perf_counter() - t0) / steps
        rows[m] = dt
        csv_row(f"measured_step/{arch}/{m}", dt * 1e6, "cpu_walltime")
    return rows


def overlap_sim(arch="llama31-8b", context=32768):
    """Hidden-transfer fraction of the recall pipeline (sim cost model).

    For each batch size: what fraction of FreeKV's recall bytes stream
    behind decode compute (staged double buffer) vs block the step
    (correction top-up + any overflow past the compute window). The paper's
    claim — transfer latency fully hidden at the default correction rate —
    corresponds to a fraction > 0.8."""
    cfg = get_config(arch)
    out = {}
    for B in (1, 4, 8):
        c = decode_step_cost(cfg, PAPER_FKV, "freekv", B, context)
        hidden = ((c.recall_total_s - c.recall_blocking_s) / c.recall_total_s
                  if c.recall_total_s else 0.0)
        out[B] = hidden
        csv_row(f"overlap_sim/{arch}/B{B}", c.recall_total_s * 1e6,
                f"hidden_fraction={hidden:.3f};"
                f"blocking={c.recall_blocking_s*1e6:.1f}us")
    return out


def overlap_measured(arch="granite-3-8b-smoke", B=2, T=256, steps=12,
                     reps=3):
    """Measured per-step wall-clock with the pipeline on vs off (CPU,
    relative; best of ``reps`` to damp container jitter) + greedy
    bit-identity of the two paths."""
    cfg = get_config(arch)
    p = 16
    base = dict(method="freekv", page_size=p, budget=64, n_sink=16,
                n_window=16, tau=0.8)
    key = jax.random.PRNGKey(0)
    k, v, query_walk = attention_process(key, cfg, B, T)
    qs = query_walk(steps + 2)
    rows = {}
    outs = {}
    for overlap in (False, True):
        fkv = FreeKVConfig(recall_overlap=overlap, **base)
        r = make_retriever(cfg, fkv)
        st0 = r.init_state(B, T + steps * reps + p, jnp.float32)
        st0 = r.prefill(st0, k, v, qs[:, 0])

        @jax.jit
        def step(st, q, kn, vn):
            o, st, _ = r.decode(st, q, kn, vn)
            return o, st
        o, _ = step(st0, qs[:, 1], k[:, 0], v[:, 0])
        jax.block_until_ready(o)
        best = float("inf")
        os_ = []
        st = st0
        for rep in range(reps):
            t0 = time.perf_counter()
            for i in range(steps):
                o, st = step(st, qs[:, i + 1], k[:, i], v[:, i])
                if rep == 0:
                    os_.append(o)
            jax.block_until_ready(o)
            best = min(best, (time.perf_counter() - t0) / steps)
        rows[overlap] = best
        outs[overlap] = [np.asarray(x) for x in os_]
        csv_row(f"overlap_measured/{arch}/pipeline={overlap}", best * 1e6,
                "cpu_walltime_best")
    identical = all(np.array_equal(a, b) for a, b
                    in zip(outs[True], outs[False]))
    csv_row(f"overlap_measured/{arch}/bit_identical", float(identical),
            f"speed_ratio_on_off={rows[True]/rows[False]:.3f}")
    return rows, identical


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized subset: cost-model sections + short "
                         "measured overlap check on the smoke arch")
    args = ap.parse_args()
    if args.smoke:
        breakdown()
        ablation()
        overlap_sim()
        overlap_measured(steps=4)
        return
    breakdown()
    breakdown("qwen25-7b")
    e2e()
    ablation()
    overlap_sim()
    overlap_sim("qwen25-7b")
    measured()
    overlap_measured()


if __name__ == "__main__":
    main()
