"""Query-similarity (paper Fig. 3 / Table 8) and correction-rate (Table 9)
measurements on our models.

Two sources:
  * a briefly-trained reduced model decoding synthetic text (real q vectors
    through the full stack), per-layer mean adjacent-step cosine similarity;
  * the structured attention process at several drift rates, correction rate
    vs tau (Table 9 analogue).
"""
import jax
import jax.numpy as jnp
import numpy as np

from _common import attention_process, csv_row
from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.core.correction import query_similarity
from repro.core.retrieval import make_retriever
from repro.data.synthetic import lm_batches
from repro.models.model import init_params, prefill, serve_step
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train, make_train_step


def model_query_similarity(arch="smollm-360m-smoke", train_steps=40,
                           decode_steps=24, quiet=False):
    """Train briefly, then decode and measure per-step query similarity via
    serve_step's aggregated stats (sim_sum / sim_cnt)."""
    cfg = get_config(arch)
    opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=train_steps + 10)
    params, opt_state = init_train(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt))
    data = lm_batches(cfg.vocab_size, 128, 8, seed=0)
    for _ in range(train_steps):
        params, opt_state, _ = step(params, opt_state,
                                    {"tokens": jnp.asarray(next(data))})
    fkv = FreeKVConfig(method="freekv", page_size=8, budget=96, n_sink=16,
                       n_window=16, tau=0.8)
    batch = {"tokens": jnp.asarray(next(data))[:2, :96]}
    logits, st = jax.jit(lambda p, b: prefill(
        cfg, fkv, p, b, max_len=256, state_dtype=jnp.float32))(params, batch)
    sims = []
    tok = jnp.argmax(logits, -1)[:, None]
    sstep = jax.jit(lambda p, s, t: serve_step(cfg, fkv, p, s, t,
                                               collect_stats=True))
    for i in range(decode_steps):
        logits, st, stats = sstep(params, st, tok)
        tok = jnp.argmax(logits, -1)[:, None]
        if i > 0:  # step 0 compares against prefill qprev
            sims.append(float(np.sum(np.asarray(stats["sim_sum"]))
                              / np.sum(np.asarray(stats["sim_cnt"]))))
    mean_sim = float(np.mean(sims))
    if not quiet:
        csv_row(f"query_similarity/{arch}", 0.0,
                f"mean_adjacent_cos={mean_sim:.3f}")
    return mean_sim


def correction_rates(arch="granite-3-8b-smoke", B=4, T=512, steps=48,
                     quiet=False):
    """Correction rate vs tau and query drift (Table 9 analogue)."""
    cfg = get_config(arch)
    p = 16
    out = {}
    for drift in (0.02, 0.1, 0.3):
        key = jax.random.PRNGKey(1)
        k, v, query_walk = attention_process(key, cfg, B, T, drift=drift)
        qs = query_walk(steps)
        for tau in (0.8, 0.9):
            fkv = FreeKVConfig(method="freekv", page_size=p, budget=128,
                               n_sink=32, n_window=32, tau=tau)
            r = make_retriever(cfg, fkv)
            st = r.init_state(B, T + steps + p, jnp.float32)
            st = r.prefill(st, k, v, qs[:, 0])
            rates, sims = [], []
            for i in range(1, steps):
                o, st, info = r.decode(st, qs[:, i], k[:, i % T], v[:, i % T])
                rates.append(float(np.asarray(info["corrected"]).mean()))
                sims.append(float(np.asarray(info["similarity"]).mean()))
            out[(drift, tau)] = (float(np.mean(rates)), float(np.mean(sims)))
            if not quiet:
                csv_row(f"correction_rate/drift{drift}/tau{tau}", 0.0,
                        f"rate={np.mean(rates):.3f};sim={np.mean(sims):.3f}")
    return out


def main():
    model_query_similarity()
    correction_rates()


if __name__ == "__main__":
    main()
