"""Serving-throughput benchmark: continuous batching vs static chunking, and
prefix-cache TTFT on shared-prefix traffic.

Two experiments on synthetic mixed traffic (CPU smoke arch; wall-clock numbers
are CPU-relative, the *ratios* are the result):

1. mixed-length workload — requests alternate short (few new tokens) and long
   (many new tokens) generations. The static scheduler locksteps each chunk to
   its longest request; the continuous scheduler refills freed slots, so
   tokens/sec must be strictly higher.
2. shared-prefix workload — every prompt shares a >= 50% prefix. With the
   radix-trie prefix cache the engine skips the transformer forward for the
   matched span; mean TTFT of the cache-hit requests must drop >= 30%.

``--smoke`` runs a smaller preset and writes ``BENCH_serving.json`` at the
repo root (via ``benchmarks/_common.bench_json``) — the committed baseline
``tools/check_bench.py`` gates: throughput_pass / ttft_pass booleans and
the within-run speedup/reduction ratios (wall-clock itself is never gated).

    PYTHONPATH=src python benchmarks/serving_throughput.py [--smoke]
        [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.models.model import init_params
from repro.serving.engine import Request, ServeEngine
from repro.serving.sampling import SamplerConfig


def make_engine(cfg, fkv, params, args, scheduler, prefix_cache_tokens=0):
    return ServeEngine(cfg, fkv, params,
                       max_len=args.context + args.long_new + 2 * args.bucket,
                       batch_size=args.slots,
                       sampler=SamplerConfig(temperature=0.0),
                       scheduler=scheduler, prefill_bucket=args.bucket,
                       prefix_cache_tokens=prefix_cache_tokens)


def mixed_requests(cfg, args, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(args.requests):
        short = i % 2 == 0
        n_ctx = args.context // 2 if short else args.context
        prompt = rng.integers(0, cfg.vocab_size, n_ctx).astype(np.int32)
        reqs.append(Request(uid=i, tokens=prompt,
                            max_new_tokens=args.short_new if short
                            else args.long_new))
    return reqs


def shared_prefix_requests(cfg, args, seed=1):
    rng = np.random.default_rng(seed)
    n_shared = args.prefix_context * 3 // 4     # 75% shared prefix
    shared = rng.integers(0, cfg.vocab_size, n_shared).astype(np.int32)
    reqs = []
    for i in range(args.prefix_requests):
        tail = rng.integers(0, cfg.vocab_size,
                            args.prefix_context - n_shared).astype(np.int32)
        reqs.append(Request(uid=i, tokens=np.concatenate([shared, tail]),
                            max_new_tokens=args.short_new))
    return reqs


def run_mixed(cfg, fkv, params, args):
    print("== experiment 1: mixed-length traffic, continuous vs static ==")
    out = {}
    for scheduler in ("static", "continuous"):
        eng = make_engine(cfg, fkv, params, args, scheduler)
        reqs = mixed_requests(cfg, args)
        eng.generate(reqs)                      # warmup: compile all shapes
        eng.generate(reqs)
        s = eng.last_metrics.summary()
        out[scheduler] = s
        extra = ("" if scheduler == "static" else
                 f" steps={s['steps']:4d} occupancy={s['slot_occupancy']:.2f}"
                 f" ttft={s['ttft_s_mean']*1e3:7.1f}ms")
        print(f"  {scheduler:10s} tok/s={s['tokens_per_s']:8.2f} "
              f"wall={s['wall_s']:6.2f}s{extra}")
    speedup = (out["continuous"]["tokens_per_s"]
               / max(out["static"]["tokens_per_s"], 1e-9))
    ok = out["continuous"]["tokens_per_s"] > out["static"]["tokens_per_s"]
    print(f"  continuous/static throughput: {speedup:.2f}x "
          f"[{'PASS' if ok else 'FAIL'}: continuous must be strictly higher]")
    out["throughput_speedup"] = speedup
    out["throughput_pass"] = bool(ok)
    return out


def run_prefix(cfg, fkv, params, args):
    """TTFT isolation: prefill-bound traffic (longer context, one slot per
    request so queue wait reflects prefill serialization, not decode)."""
    print("== experiment 2: >=50% shared-prefix traffic, prefix cache ==")
    out = {}
    for label, cache_tokens in (("cache_off", 0),
                                ("cache_on", args.cache_tokens)):
        eng = ServeEngine(
            cfg, fkv, params,
            max_len=args.prefix_context + args.short_new + 2 * args.bucket,
            batch_size=args.prefix_requests,
            sampler=SamplerConfig(temperature=0.0),
            scheduler="continuous", prefill_bucket=args.bucket,
            prefix_cache_tokens=cache_tokens)
        reqs = shared_prefix_requests(cfg, args)
        eng.generate(reqs)                      # warmup: compile all shapes
        if eng.prefix_cache is not None:
            eng.prefix_cache.clear()            # timed run re-populates
        eng.generate(reqs)
        rms = eng.last_metrics.requests
        # requests that hit the cache (first request is the cold insert)
        warm = [r for r in rms if r.prefix_hit_tokens > 0] or rms[1:]
        ttft = sum(r.ttft_s for r in warm) / len(warm)
        out[label] = {"summary": eng.last_metrics.summary(),
                      "warm_ttft_s": ttft,
                      "warm_requests": len(warm)}
        hit = (eng.prefix_cache.stats()["hit_token_rate"]
               if eng.prefix_cache else 0.0)
        print(f"  {label:10s} warm-ttft={ttft*1e3:7.1f}ms "
              f"tok/s={out[label]['summary']['tokens_per_s']:8.2f} "
              f"hit_token_rate={hit:.2f}")
    red = 1 - out["cache_on"]["warm_ttft_s"] / out["cache_off"]["warm_ttft_s"]
    ok = red >= 0.30
    print(f"  warm-request TTFT reduction: {red*100:.1f}% "
          f"[{'PASS' if ok else 'FAIL'}: >= 30% required]")
    out["ttft_reduction"] = red
    out["ttft_pass"] = bool(ok)
    return out


SMOKE = dict(context=128, requests=6, slots=2, short_new=3, long_new=12,
             bucket=32, page_size=16, budget=96, prefix_context=512,
             prefix_requests=4)


def main():
    from _common import bench_json
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m-smoke")
    ap.add_argument("--method", default="freekv")
    ap.add_argument("--context", type=int, default=256)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--short-new", type=int, default=4)
    ap.add_argument("--long-new", type=int, default=24)
    ap.add_argument("--bucket", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--budget", type=int, default=128)
    ap.add_argument("--prefix-context", type=int, default=1024)
    ap.add_argument("--prefix-requests", type=int, default=4)
    ap.add_argument("--cache-tokens", type=int, default=1 << 20)
    ap.add_argument("--json", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized preset — writes BENCH_serving.json")
    args = ap.parse_args()
    if args.smoke:
        for k, v in SMOKE.items():
            setattr(args, k, v)

    cfg = get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    fkv = FreeKVConfig(method=args.method, page_size=args.page_size,
                       budget=args.budget, n_sink=args.page_size,
                       n_window=args.page_size, tau=0.8)
    results = {"args": vars(args),
               "mixed": run_mixed(cfg, fkv, params, args),
               "prefix": run_prefix(cfg, fkv, params, args)}
    if args.smoke:
        cont = results["mixed"]["continuous"]
        metrics = {
            "throughput_speedup": results["mixed"]["throughput_speedup"],
            "throughput_pass": results["mixed"]["throughput_pass"],
            "ttft_reduction": results["prefix"]["ttft_reduction"],
            "ttft_pass": results["prefix"]["ttft_pass"],
            "slot_occupancy": cont["slot_occupancy"],
            "spec_hit_rate_mean": cont["speculation"]["hit_rate_mean"],
            # wall-clock latency quantiles recorded for trend-watching only
            # (never gated — see tools/check_bench.py)
            "ttft_p90_s": cont["latency"]["ttft_s"]["p90"],
            "itl_p90_s": cont["latency"]["itl_s"]["p90"],
        }
        bench_json("serving", {"arch": args.arch, "method": args.method,
                               **SMOKE}, metrics)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, default=str)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
