import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")

"""Tensor-parallel serving scaling sweep (must run in its own process: it
forces XLA host devices before jax initializes).

Runs the serving-throughput mixed-length continuous-batching traffic through
``ServeEngine(tp=1)`` and ``ServeEngine(tp=2)`` for every
(recall_overlap, kv_quant) combination and reports

  * **bit_identical** — greedy token streams must match exactly across tp
    (the KV-head-group sharding's defining property; any False fails CI via
    ``tools/check_bench.py``);
  * throughput (tokens/s; CPU-relative — forced host devices share the same
    silicon, so tp=2 wall-clock measures sharding *overhead*, not speedup:
    the per-shard numbers below carry the scaling story);
  * **per-shard host-link traffic** — each shard moves 1/tp of every
    transfer class over its own host link, the quantity that actually
    scales serving (recall bandwidth per device halves at tp=2).

    PYTHONPATH=src python benchmarks/sharded_throughput.py [--smoke]

Writes the ``BENCH_sharded.json`` trajectory file (schema: _common.bench_json).
"""
import argparse
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.configs.base import FreeKVConfig  # noqa: E402
from repro.models.model import init_params  # noqa: E402
from repro.serving.engine import Request, ServeEngine  # noqa: E402
from repro.serving.sampling import SamplerConfig  # noqa: E402

SMOKE = dict(arch="granite-3-8b-smoke", context=96, requests=6, slots=3,
             short_new=4, long_new=8, bucket=48, page_size=8, budget=48)
FULL = dict(arch="granite-3-8b-smoke", context=256, requests=10, slots=4,
            short_new=4, long_new=16, bucket=64, page_size=16, budget=96)


def mixed_requests(cfg, context, n, short_new, long_new, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        short = i % 2 == 0
        n_ctx = context // 2 if short else context
        prompt = rng.integers(0, cfg.vocab_size, n_ctx).astype(np.int32)
        reqs.append(Request(uid=i, tokens=prompt,
                            max_new_tokens=short_new if short else long_new))
    return reqs


def run(arch, context, requests, slots, short_new, long_new, bucket,
        page_size, budget, tps=(1, 2), quiet=False):
    cfg = get_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    base = dict(method="freekv", page_size=page_size, budget=budget,
                n_sink=page_size, n_window=page_size, tau=0.8)
    max_len = context + long_new + 2 * bucket
    metrics = {"bit_identical": True, "configs": {}}

    for overlap in (True, False):
        for quant in ("none", "int8"):
            name = f"overlap={int(overlap)}/quant={quant}"
            tokens, summaries = {}, {}
            for tp in tps:
                fkv = FreeKVConfig(**base, recall_overlap=overlap,
                                   kv_quant=quant)
                eng = ServeEngine(cfg, fkv, params, max_len=max_len,
                                  batch_size=slots,
                                  sampler=SamplerConfig(temperature=0.0),
                                  scheduler="continuous",
                                  prefill_bucket=bucket, tp=tp)
                reqs = mixed_requests(cfg, context, requests, short_new,
                                      long_new)
                eng.generate(reqs)              # warmup: compile all shapes
                outs = eng.generate(mixed_requests(cfg, context, requests,
                                                   short_new, long_new))
                tokens[tp] = [c.tokens for c in outs]
                summaries[tp] = eng.last_metrics.summary()
            ident = all(tokens[tp] == tokens[tps[0]] for tp in tps)
            metrics["bit_identical"] &= ident
            row = {"bit_identical": bool(ident)}
            for tp in tps:
                s = summaries[tp]
                row[f"tp{tp}"] = {
                    "tokens_per_s": s["tokens_per_s"],
                    "wall_s": s["wall_s"],
                    "slot_occupancy": s["slot_occupancy"],
                    "recall_bytes_sync": s["recall_overlap"]["exposed_bytes"],
                    "recall_bytes_async": s["recall_overlap"]["hidden_bytes"],
                    "per_shard_transfer_bytes":
                        s["tp"]["per_shard_transfer_bytes"],
                }
            tp_hi = tps[-1]
            sync1 = summaries[tps[0]]["recall_overlap"]["exposed_bytes"]
            row["per_shard_sync_reduction"] = (
                sync1 / max(row[f"tp{tp_hi}"]["per_shard_transfer_bytes"]
                            ["sync"], 1e-9))
            row["tp_overhead"] = (summaries[tp_hi]["wall_s"]
                                  / max(summaries[tps[0]]["wall_s"], 1e-9))
            metrics["configs"][name] = row
            if not quiet:
                print(f"  {name:24s} bit_identical={ident} "
                      f"tp{tp_hi}_overhead={row['tp_overhead']:.2f}x "
                      f"per_shard_sync_reduction="
                      f"{row['per_shard_sync_reduction']:.2f}x")
    metrics["bit_identical"] = bool(metrics["bit_identical"])
    return metrics


def main():
    from _common import bench_json
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run — still writes BENCH_sharded.json")
    ap.add_argument("--no-json", action="store_true")
    args = ap.parse_args()
    config = dict(SMOKE) if args.smoke else dict(FULL)
    print(f"devices: {jax.devices()}")
    res = run(**config)
    status = "PASS" if res["bit_identical"] else "FAIL"
    print(f"bit_identical across tp sweep: {res['bit_identical']} [{status}]")
    if not args.no_json:
        bench_json("sharded", config, res)
    if not res["bit_identical"]:
        sys.exit(1)
    return res


if __name__ == "__main__":
    main()
