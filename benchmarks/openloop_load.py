"""Open-loop goodput harness: Poisson arrivals through the HTTP front-end.

Sweeps offered load (requests/s) with a *seeded open-loop* arrival process
— clients fire on an exponential inter-arrival schedule regardless of how
fast the server drains, the load-testing regime where queueing delay and
SLO misses actually show up (closed-loop harnesses self-throttle and hide
them). Every request is streamed over HTTP (``POST /generate`` chunked
NDJSON) against ``launch/serve.py --serve-http``'s exact serving stack:
``EngineService`` mailbox -> continuous scheduler service mode -> per-token
events back through asyncio.

Per load point it reports client-observed p50/p99 TTFT and inter-token
gaps, server-side SLO attainment and goodput (tokens/s from SLO-meeting
requests only, ``EngineMetrics.slo_summary()``), producing the
goodput-vs-offered-load curve. Gated results (``tools/check_bench.py``):

* **frontend_bit_identical** — greedy token streams through the HTTP
  front-end match a direct ``engine.generate`` run of the same requests
  bit-for-bit, and every ``done`` record equals its streamed token
  sequence (no loss/reorder across the thread/asyncio bridge).
* **endpoints_valid** — mid-load ``GET /metrics`` (Prometheus), ``/stats``
  (schema-versioned sliding-window snapshot) and ``/healthz`` all parse
  and validate (``validate_timeseries_snapshot``).
* **nonsync_bytes_per_step == 0** — serving over HTTP with full
  observability adds no host traffic between sync points.
* **slo_attainment_low_load** — at the lowest offered load every request
  meets the (generous) smoke SLO; wall-clock quantiles are recorded but
  never gated.

    PYTHONPATH=src python benchmarks/openloop_load.py [--smoke]

Writes ``BENCH_openloop.json`` (schema: _common.bench_json).
"""
from __future__ import annotations

import argparse
import os
import sys
import threading
import time

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.configs.base import FreeKVConfig  # noqa: E402
from repro.models.model import init_params  # noqa: E402
from repro.obs import Observability, validate_timeseries_snapshot  # noqa: E402
from repro.serving.engine import Request, ServeEngine  # noqa: E402
from repro.serving.frontend import (EngineService,  # noqa: E402
                                    http_generate, http_get_json,
                                    http_get_text, serve_http_background)
from repro.serving.sampling import SamplerConfig  # noqa: E402

SMOKE = dict(arch="granite-3-8b-smoke", context=64, slots=2, new_tokens=16,
             requests=6, loads=(2.0, 8.0, 32.0), page_size=8, budget=48,
             slo_ttft_ms=60_000.0, slo_itl_ms=10_000.0)
FULL = dict(arch="granite-3-8b-smoke", context=256, slots=4, new_tokens=48,
            requests=16, loads=(1.0, 4.0, 16.0), page_size=16, budget=96,
            slo_ttft_ms=60_000.0, slo_itl_ms=10_000.0)


def make_requests(cfg, context, n, new_tokens, seed):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        context).astype(np.int32),
                    max_new_tokens=new_tokens)
            for i in range(n)]


def _pct(vals, q):
    return float(np.percentile(vals, q)) if len(vals) else 0.0


class _Client(threading.Thread):
    """One open-loop request: stream /generate, record event recv times."""

    def __init__(self, port, req, slo_ttft_ms, slo_itl_ms):
        super().__init__(daemon=True)
        self.port, self.req = port, req
        self.payload = {"tokens": [int(t) for t in req.tokens],
                        "max_new_tokens": req.max_new_tokens,
                        "uid": req.uid, "slo_ttft_ms": slo_ttft_ms,
                        "slo_itl_ms": slo_itl_ms}
        self.recv_t: list = []          # client-side token arrival times
        self.tokens: list = []          # streamed token values, in order
        self.done: dict = {}
        self.t_post = 0.0
        self.error = None

    def run(self):
        try:
            self.t_post = time.perf_counter()
            for ev in http_generate("127.0.0.1", self.port, self.payload):
                if ev.get("event") == "token":
                    self.recv_t.append(time.perf_counter())
                    self.tokens.append(ev["token"])
                elif ev.get("event") == "done":
                    self.done = ev
                elif ev.get("event") == "error":   # pragma: no cover
                    self.error = ev
        except Exception as e:          # pragma: no cover - harness bug
            self.error = e


def _check_endpoints(port, svc):
    """Hit /healthz + /metrics + /stats mid-load; returns list of errors."""
    errs = []
    deadline = time.time() + 30.0
    while time.time() < deadline:       # wait for the scheduler to attach
        if svc.em is not None and svc.em.steps > 0:
            break
        time.sleep(0.005)
    st, health = http_get_json("127.0.0.1", port, "/healthz")
    if st != 200 or not health.get("ok") or not health.get("engine_running"):
        errs.append(f"/healthz unhealthy under load: {st} {health}")
    st, prom = http_get_text("127.0.0.1", port, "/metrics")
    if st != 200 or "# TYPE" not in prom:
        errs.append(f"/metrics not a Prometheus exposition: {st}")
    st, stats = http_get_json("127.0.0.1", port, "/stats")
    if st != 200:
        errs.append(f"/stats -> {st}")
    else:
        errs.extend(f"/stats: {e}"
                    for e in validate_timeseries_snapshot(stats))
    return errs


def run_point(eng, reqs, rps, slo_ttft_ms, slo_itl_ms, seed):
    """One offered-load point: fresh service + HTTP server, Poisson
    arrivals, client-observed latencies + server-side SLO summary."""
    svc = EngineService(eng, seed=0).start()
    fe, stop, th = serve_http_background(svc)
    arrivals = np.random.default_rng(seed).exponential(
        1.0 / rps, len(reqs)).cumsum()
    clients = [_Client(fe.port, r, slo_ttft_ms, slo_itl_ms) for r in reqs]
    endpoint_errs = None
    try:
        t0 = time.perf_counter()
        for i, c in enumerate(clients):
            delay = t0 + arrivals[i] - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            c.start()
            if endpoint_errs is None and i >= len(clients) // 2:
                endpoint_errs = _check_endpoints(fe.port, svc)
        for c in clients:
            c.join(timeout=600.0)
        wall = time.perf_counter() - t0
    finally:
        stop.set()
        th.join(timeout=30.0)
        svc.stop()
    em = eng.last_metrics
    for c in clients:
        if c.error is not None:
            raise RuntimeError(f"client uid={c.req.uid} failed: {c.error}")
    ttft = [c.recv_t[0] - c.t_post for c in clients if c.recv_t]
    itl = [g for c in clients
           for g in np.diff(c.recv_t)] if clients else []
    slo = em.slo_summary()
    d = em.summary()["dispatch"]
    point = {
        "offered_rps": rps,
        "completed": len([c for c in clients if c.done]),
        "wall_s": wall,
        "tokens_per_s": sum(len(c.tokens) for c in clients) / max(wall, 1e-9),
        "ttft_p50_s": _pct(ttft, 50), "ttft_p99_s": _pct(ttft, 99),
        "itl_p50_s": _pct(itl, 50), "itl_p99_s": _pct(itl, 99),
        "slo_attainment": slo["attainment"],
        "goodput_tokens_per_s": slo["goodput_tokens_per_s"],
        "nonsync_bytes_per_step": d["nonsync_bytes_per_step"],
        "endpoint_errors": endpoint_errs or [],
    }
    streamed = {c.req.uid: list(c.tokens) for c in clients}
    done_match = all(c.done.get("tokens") == c.tokens for c in clients)
    return point, streamed, done_match


def run(arch, context, slots, new_tokens, requests, loads, page_size,
        budget, slo_ttft_ms, slo_itl_ms, quiet=False):
    cfg = get_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    fkv = FreeKVConfig(method="freekv", page_size=page_size, budget=budget,
                       n_sink=page_size, n_window=page_size, tau=0.8,
                       sync_interval=8)
    eng = ServeEngine(cfg, fkv, params,
                      max_len=context + new_tokens + page_size + 64,
                      batch_size=slots,
                      sampler=SamplerConfig(temperature=0.0),
                      scheduler="continuous", obs=Observability.full(),
                      slo_ttft_ms=slo_ttft_ms, slo_itl_ms=slo_itl_ms)

    # per-point request sets; the first doubles as the warmup batch AND the
    # direct-engine reference for the frontend bit-identity gate
    req_sets = [make_requests(cfg, context, requests, new_tokens,
                              seed=100 + i) for i in range(len(loads))]
    direct = {out.uid: [int(t) for t in out.tokens]
              for out in eng.generate(req_sets[0], seed=0)}

    points, bit_identical, dones_match, ep_errs = {}, True, True, []
    for i, rps in enumerate(loads):
        point, streamed, done_ok = run_point(
            eng, req_sets[i], rps, slo_ttft_ms, slo_itl_ms, seed=7 + i)
        points[f"rps={rps:g}"] = point
        dones_match = dones_match and done_ok
        ep_errs.extend(point["endpoint_errors"])
        if i == 0:
            bit_identical = streamed == direct
        if not quiet:
            print(f"  rps={rps:6.1f} tok/s={point['tokens_per_s']:7.2f} "
                  f"ttft p50/p99={point['ttft_p50_s']*1e3:6.1f}/"
                  f"{point['ttft_p99_s']*1e3:6.1f} ms "
                  f"itl p99={point['itl_p99_s']*1e3:6.1f} ms "
                  f"slo={point['slo_attainment']:.0%} "
                  f"goodput={point['goodput_tokens_per_s']:7.2f} tok/s")
    if ep_errs and not quiet:
        print(f"  endpoint errors: {ep_errs[:5]}")

    pts = list(points.values())
    metrics = {
        "frontend_bit_identical": bit_identical and dones_match,
        "endpoints_valid": not ep_errs,
        "completed_all": all(p["completed"] == requests for p in pts),
        "nonsync_bytes_per_step": max(p["nonsync_bytes_per_step"]
                                      for p in pts),
        "slo_attainment_low_load": pts[0]["slo_attainment"],
        "load_points": len(pts),
        "points": points,
    }
    return metrics


def main():
    from _common import bench_json
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep — still writes BENCH_openloop.json")
    ap.add_argument("--no-json", action="store_true")
    args = ap.parse_args()
    config = dict(SMOKE) if args.smoke else dict(FULL)
    print("== open-loop goodput vs offered load (HTTP front-end) ==")
    res = run(**config)
    ok = (res["frontend_bit_identical"] and res["endpoints_valid"]
          and res["completed_all"] and res["nonsync_bytes_per_step"] == 0
          and res["slo_attainment_low_load"] == 1.0)
    print(f"frontend_bit_identical={res['frontend_bit_identical']} "
          f"endpoints_valid={res['endpoints_valid']} "
          f"completed_all={res['completed_all']} "
          f"nonsync_B/step={res['nonsync_bytes_per_step']:.1f} "
          f"slo_attainment_low_load={res['slo_attainment_low_load']:.0%} "
          f"[{'PASS' if ok else 'FAIL'}]")
    if not args.no_json:
        config["loads"] = list(config["loads"])
        bench_json("openloop", config, res)
    if not ok:
        sys.exit(1)
    return res


if __name__ == "__main__":
    main()
