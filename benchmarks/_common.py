"""Shared benchmark infrastructure.

1. A *structured attention process* generator: keys form clusters, queries walk
   slowly between clusters (mimicking the paper's observation of high
   adjacent-step query similarity + vertical attention-map lines), so KV
   retrieval quality actually matters and speculative reuse is non-trivially
   testable.

2. The analytical transfer/latency cost model used for Fig-1/7/9-style
   results. This container has no accelerator: wall-clock numbers are
   CPU-relative; the cost model carries the hardware reasoning (bandwidths,
   transfer granularity efficiency, overlap) for the v5e+host target.
"""
from __future__ import annotations

import dataclasses
import os
import sys
from dataclasses import dataclass

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, FreeKVConfig


# ---------------------------------------------------------------------------
# structured synthetic attention process
# ---------------------------------------------------------------------------
def attention_process(key, cfg: ArchConfig, B, T, n_clusters=24,
                      drift=0.05, dtype=jnp.float32):
    """Returns (k (B,T,kv,dh), v, queries (B,n_steps,H,dh) generator fn).

    Keys: cluster centers + noise; query at step i: near one cluster center,
    with a slow random walk over clusters (so adjacent queries are similar —
    cos ~ 0.9 — but occasionally jump, triggering correction)."""
    kv, dh, H = cfg.n_kv_heads, cfg.d_head, cfg.n_heads
    kc, kk, kq = jax.random.split(key, 3)
    centers = jax.random.normal(kc, (n_clusters, kv, dh))
    assign = jax.random.randint(kk, (B, T), 0, n_clusters)
    noise = 0.3 * jax.random.normal(jax.random.fold_in(kk, 1), (B, T, kv, dh))
    k = centers[assign] + noise
    v = jax.random.normal(jax.random.fold_in(kk, 2), (B, T, kv, dh))

    def query_walk(n_steps, seed=0):
        rng = np.random.default_rng(seed)
        cur = rng.integers(0, n_clusters, size=B)
        qs = []
        cen = np.asarray(centers)  # (C, kv, dh)
        for i in range(n_steps):
            jump = rng.random(B) < drift
            cur = np.where(jump, rng.integers(0, n_clusters, size=B), cur)
            base = cen[cur]                       # (B, kv, dh)
            q = np.repeat(base, H // kv, axis=1)  # (B, H, dh)
            # scale -> peaked attention on the current cluster's pages, so
            # retrieval quality separates methods clearly
            q = 2.5 * q + 0.15 * rng.standard_normal(q.shape)
            qs.append(q)
        return jnp.asarray(np.stack(qs, 1), dtype)  # (B, n_steps, H, dh)

    return k.astype(dtype), v.astype(dtype), query_walk


# ---------------------------------------------------------------------------
# latency cost model (paper Fig. 1/7/9 structure)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class HwModel:
    peak_flops: float = 197e12        # bf16/chip (v5e-class)
    hbm_bw: float = 819e9
    host_link_bw: float = 20e9        # host<->device DMA (PCIe-gen4-class)
    link_latency_per_xfer: float = 2e-6   # per-transfer setup cost
    dma_saturation_bytes: float = 64e3    # unit size for ~50% efficiency

    def transfer_time(self, total_bytes, unit_bytes, double_buffered=True):
        """Granularity-aware host->device transfer time: each contiguous unit
        pays a setup latency; efficiency(unit) = unit/(unit + sat/2).
        Double buffering overlaps setup with payload (paper's DB)."""
        if total_bytes == 0:
            return 0.0
        n_units = max(1, int(np.ceil(total_bytes / max(unit_bytes, 1))))
        eff = unit_bytes / (unit_bytes + self.dma_saturation_bytes / 8)
        payload = total_bytes / (self.host_link_bw * max(eff, 1e-3))
        setup = n_units * self.link_latency_per_xfer
        if double_buffered:
            return max(payload, setup) + self.link_latency_per_xfer
        return payload + setup


@dataclass
class StepCost:
    compute_s: float
    select_s: float
    recall_blocking_s: float
    recall_total_s: float
    total_s: float


def decode_step_cost(cfg: ArchConfig, fkv: FreeKVConfig, method: str, B: int,
                     context: int, hw: HwModel = HwModel(),
                     correction_rate: float = 0.15) -> StepCost:
    """Analytical per-decode-step latency for one request batch.

    Mirrors the paper's latency decomposition (Fig. 1 right): model compute
    (memory-bound at decode: weights+budget-KV reads), selection scoring, and
    the recall transfer split into blocking vs overlapped portions.
    """
    p, d = fkv.page_size, cfg.d_head
    kv, H = cfg.n_kv_heads, cfg.n_heads
    n_layers_attn = sum(1 for m, _ in cfg.layers if m == "attn")
    act = cfg.param_counts()["active"]
    itemsize = 2

    # --- compute: decode is memory-bound -> weights + resident-KV traffic
    resident_tokens = (context if method in ("full", "quest")
                       else min(fkv.budget, context))
    kv_bytes = (B * resident_tokens * kv * d * 2 * itemsize * n_layers_attn
                * (H // kv if method == "quest" else 1))
    compute = max(2 * act * B / hw.peak_flops,
                  (act * itemsize + kv_bytes) / hw.hbm_bw)

    # --- selection: q @ summaries over all pages, all layers
    n_pages = context // p
    sel_flops = B * H * n_pages * 2 * d * 2 * n_layers_attn
    select = sel_flops / hw.peak_flops + n_layers_attn * 2e-6

    # --- recall volume (quant-aware: the quantized host tier shrinks the
    # transferred page payload to bits/8 per element + fp32 scale bytes)
    n_sel = max(0, (fkv.budget - fkv.n_sink - fkv.n_window) // p)
    from repro.quant import page_block_bytes
    page_bytes = page_block_bytes(fkv, d, itemsize)    # K+V contiguous (HND)
    if method in ("full", "quest", "raas", "streaming"):
        recall_bytes, unit = 0, page_bytes
    elif method == "shadowkv":
        v_bytes = page_bytes // 2      # V half: payload and scales both halve
        recall_bytes = B * kv * n_sel * v_bytes * n_layers_attn
        unit = v_bytes                                 # V-only pages
    elif method == "infinigen":
        recall_bytes = B * kv * n_sel * page_bytes * n_layers_attn
        unit = d * itemsize                            # token-wise transfers
    else:
        recall_bytes = B * kv * n_sel * page_bytes * n_layers_attn
        unit = page_bytes
    db = method == "freekv"
    recall_total = hw.transfer_time(recall_bytes, unit, double_buffered=db)

    # --- overlap semantics
    if method == "freekv":
        # speculative: only corrected heads block; the rest overlaps with
        # compute (fully hidden if recall <= compute)
        blocking = correction_rate * recall_total
        hidden_budget = compute
        overflow = max(0.0, (1 - correction_rate) * recall_total - hidden_budget)
        blocking += overflow
        select_blocking = 0.0 if recall_total <= hidden_budget else select
    elif method == "infinigen":
        # prefetch-next-layer: overlap with one layer's compute only
        per_layer = compute / max(cfg.n_layers, 1)
        blocking = max(0.0, recall_total - n_layers_attn * per_layer)
        select_blocking = select
    elif method in ("arkvale", "shadowkv"):
        blocking = recall_total
        select_blocking = select
    else:
        blocking = 0.0
        select_blocking = select if method in ("quest", "raas") else 0.0
    total = compute + select_blocking + blocking
    return StepCost(compute, select, blocking, recall_total, total)


def csv_row(name, us, derived=""):
    print(f"{name},{us:.3f},{derived}")


# ---------------------------------------------------------------------------
# machine-readable perf trajectory files (BENCH_<name>.json at the repo root)
# ---------------------------------------------------------------------------
def _jsonable(obj):
    """Best-effort conversion of benchmark return values to JSON types."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return _jsonable(dataclasses.asdict(obj))
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, (str, int, bool)) or obj is None:
        return obj
    if isinstance(obj, float):
        return obj if obj == obj and abs(obj) != float("inf") else str(obj)
    return str(obj)


def git_sha() -> str:
    import subprocess
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            timeout=10, check=True).stdout.strip()
    except Exception:  # noqa: BLE001  (no git / not a checkout)
        return "unknown"


def bench_json(name: str, config: dict, metrics) -> str:
    """Write ``BENCH_<name>.json`` at the repo root and return its path.

    Schema: {"benchmark", "config", "metrics", "git_sha"} — one file per
    benchmark section, overwritten per run, so perf history is trackable
    across PRs by diffing the committed trajectory files (docs/benchmarks.md
    keeps the human-readable trajectory table)."""
    import json
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, f"BENCH_{name}.json")
    payload = {"benchmark": name, "config": _jsonable(config),
               "metrics": _jsonable(metrics), "git_sha": git_sha()}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {os.path.relpath(path, root)}")
    return path
