import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Quality impact of sharded speculative retrieval (beyond-paper §Perf opt2).

Runs REAL multi-device execution on 8 forced host devices (mesh 1x8 data x
model): shard-local top-(k/8) selection vs global top-k, on the structured
attention process — reports attention-output error vs the full-cache oracle
and the page-selection overlap between the two schemes.

    PYTHONPATH=src python benchmarks/sharded_quality.py
"""
import dataclasses
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import jax
import jax.numpy as jnp
import numpy as np


def run(B=8, T=512, steps=32, quiet=False):
    from _common import attention_process
    from repro.configs import get_config
    from repro.configs.base import FreeKVConfig
    from repro.core.retrieval import make_retriever

    cfg = get_config("granite-3-8b-smoke")
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    p = 16
    # pool pages must divide the model axis: pad via pool_pad_pages
    base = dict(method="freekv", page_size=p, budget=128 + 2 * p,
                n_sink=p, n_window=p, tau=0.8, pool_pad_pages=8)
    key = jax.random.PRNGKey(0)
    k, v, query_walk = attention_process(key, cfg, B, T)
    qs = query_walk(steps)
    rf = make_retriever(cfg, FreeKVConfig(method="full"))
    results = {}
    with mesh:
        for name, shard, os_ in (("global", False, 1), ("sharded", True, 1),
                                 ("sharded+rerank", True, 2)):
            fkv = FreeKVConfig(**base, sharded_retrieval=shard,
                               sharded_overselect=os_)
            r = make_retriever(cfg, fkv, mesh=mesh if shard else None)
            st = r.init_state(B, T + steps + p, jnp.float32)
            st = r.prefill(st, k, v, qs[:, 0])
            stf = rf.init_state(B, T + steps + p, jnp.float32)
            stf = rf.prefill(stf, k, v, qs[:, 0])
            errs, idxs = [], []
            for i in range(1, steps):
                q = qs[:, i]
                kn, vn = k[:, i % T], v[:, i % T]
                o, st, _ = r.decode(st, q, kn, vn)
                of, stf, _ = rf.decode(stf, q, kn, vn)
                err = (jnp.linalg.norm(o - of, axis=-1)
                       / jnp.maximum(jnp.linalg.norm(of, axis=-1), 1e-6))
                errs.append(float(err.mean()))
                idxs.append(np.asarray(st["sel_idx"]))
            results[name] = {"err": float(np.mean(errs)), "idx": idxs[-1]}
    def _overlap(name):
        a, b = results[name]["idx"], results["global"]["idx"]
        ov = []
        for bi in range(B):
            for h in range(cfg.n_kv_heads):
                sa = set(a[bi, h][a[bi, h] >= 0].tolist())
                sb = set(b[bi, h][b[bi, h] >= 0].tolist())
                ov.append(len(sa & sb) / max(len(sb), 1))
        return float(np.mean(ov))
    if not quiet:
        print("name,us_per_call,derived")
        print(f"sharded_quality/global,0.0,out_err={results['global']['err']:.4f}")
        for name in ("sharded", "sharded+rerank"):
            print(f"sharded_quality/{name},0.0,out_err={results[name]['err']:.4f};"
                  f"selection_overlap_vs_global={_overlap(name):.3f}")
    return results, _overlap("sharded")


if __name__ == "__main__":
    run()
