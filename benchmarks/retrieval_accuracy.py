"""Accuracy proxy for paper Tables 2/3: attention-output fidelity + oracle-page
overlap of every KV compression method vs the exact full-cache oracle, on the
structured attention process (clustered keys, slowly-drifting queries).

Reported per method:
  out_err   mean relative L2 error of decode attention output vs full cache
  overlap   mean |selected ∩ oracle-top| / |oracle-top| page overlap
  corr_rate fraction of KV heads corrected per step (FreeKV only)
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from _common import attention_process, csv_row
from repro.configs import get_config
from repro.configs.base import FreeKVConfig
from repro.core import selection
from repro.core.retrieval import make_retriever

METHODS = ("freekv", "arkvale", "infinigen", "quest", "shadowkv", "raas",
           "streaming")


def run(arch="granite-3-8b-smoke", B=4, T=512, steps=48, budget_frac=0.25,
        seed=0, quiet=False):
    cfg = get_config(arch)
    p = 16
    budget = int(T * budget_frac) // p * p
    fkv_base = dict(page_size=p, budget=budget, n_sink=p * 2, n_window=p * 2,
                    tau=0.8, svd_rank=min(48, cfg.d_head))
    key = jax.random.PRNGKey(seed)
    k, v, query_walk = attention_process(key, cfg, B, T)
    qs = query_walk(steps, seed=seed + 1)
    q_last = qs[:, 0]

    # oracle: full cache
    rf = make_retriever(cfg, FreeKVConfig(method="full"))
    n_sel = max(1, (budget - 4 * p) // p)
    results = {}
    for method in METHODS:
        fkv = FreeKVConfig(method=method, **fkv_base)
        r = make_retriever(cfg, fkv)
        st = r.init_state(B, T + steps + p, jnp.float32)
        st = r.prefill(st, k, v, q_last)
        stf = rf.init_state(B, T + steps + p, jnp.float32)
        stf = rf.prefill(stf, k, v, q_last)
        errs, overlaps, corrs = [], [], []
        t0 = time.perf_counter()
        for i in range(steps):
            q = qs[:, i]
            kn = k[:, (i * 7) % T]    # recycled keys as new-token K/V
            vn = v[:, (i * 7) % T]
            o, st, info = r.decode(st, q, kn, vn, q_proxy=qs[:, max(i - 1, 0)])
            of, stf, _ = rf.decode(stf, q, kn, vn)
            err = (jnp.linalg.norm(o - of, axis=-1)
                   / jnp.maximum(jnp.linalg.norm(of, axis=-1), 1e-6))
            errs.append(float(err.mean()))
            corrs.append(float(np.asarray(info["corrected"]).mean()))
            idx = st.get("sel_idx", st.get("keep_idx"))
            if idx is not None:
                oracle = selection.oracle_pages(
                    cfg, FreeKVConfig(method=method, **fkv_base), q,
                    stf["k"][:, : st["length"][0]], st["length"], n_sel)
                hit = 0.0
                ai, bi = np.asarray(idx), np.asarray(oracle)
                for b in range(B):
                    for h in range(cfg.n_kv_heads):
                        sa = set(ai[b, h][ai[b, h] >= 0].tolist())
                        sb = set(bi[b, h][bi[b, h] >= 0].tolist())
                        hit += len(sa & sb) / max(len(sb), 1)
                overlaps.append(hit / (B * cfg.n_kv_heads))
        wall = time.perf_counter() - t0
        results[method] = {
            "out_err": float(np.mean(errs)),
            "overlap": float(np.mean(overlaps)) if overlaps else float("nan"),
            "corr_rate": float(np.mean(corrs)),
            "wall_s": wall,
        }
        if not quiet:
            csv_row(f"accuracy/{method}", wall / steps * 1e6,
                    f"out_err={results[method]['out_err']:.4f};"
                    f"overlap={results[method]['overlap']:.3f};"
                    f"corr_rate={results[method]['corr_rate']:.3f}")
    return results


def main():
    res = run()
    # sanity ordering expected from the paper: retrieval < dropping error
    return res


if __name__ == "__main__":
    main()
